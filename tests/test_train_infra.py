"""Training substrate: checkpoint/restore round-trips, crash consistency,
preemption resume (subprocess kill -9), data determinism, compression."""
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    latest_checkpoint, list_checkpoints, restore_checkpoint, save_checkpoint,
)
from repro.train.data import DataConfig, PrefetchIterator, TokenStream


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12).reshape(3, 4), "b": {"c": jnp.ones((5,))},
                "step": jnp.int32(7)}
        save_checkpoint(str(tmp_path), 7, tree)
        step, restored = restore_checkpoint(str(tmp_path), 7, tree)
        assert step == 7
        np.testing.assert_array_equal(restored["a"], np.asarray(tree["a"]))
        np.testing.assert_array_equal(restored["b"]["c"], np.ones((5,)))

    def test_retention(self, tmp_path):
        tree = {"x": jnp.zeros((2,))}
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, tree, keep=2)
        assert list_checkpoints(str(tmp_path)) == [4, 5]

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((5,))})

    def test_atomicity_tmpdir_invisible(self, tmp_path):
        save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros((2,))})
        names = os.listdir(tmp_path)
        assert all(not n.startswith(".tmp") for n in names)
        assert latest_checkpoint(str(tmp_path)) == 3


class TestData:
    def test_deterministic_and_seekable(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        s1, s2 = TokenStream(cfg), TokenStream(cfg)
        b5a, b5b = s1.batch_at(5), s2.batch_at(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
        # different steps differ
        assert not np.array_equal(s1.batch_at(6)["tokens"], b5a["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b5a["labels"][:, :-1], b5a["tokens"][:, 1:])

    def test_host_sharding_disjoint(self):
        a = TokenStream(DataConfig(100, 16, 8, host_index=0, host_count=2))
        b = TokenStream(DataConfig(100, 16, 8, host_index=1, host_count=2))
        assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])

    def test_prefetch_iterator(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
        it = PrefetchIterator(TokenStream(cfg), start_step=0)
        s0, b0 = next(it)
        s1, b1 = next(it)
        it.close()
        assert (s0, s1) == (0, 1)
        assert b0["tokens"].shape == (2, 8)


class TestPreemptionResume:
    """Kill -9 a training run mid-flight; resume must continue identically."""

    def test_kill_and_resume_bitwise(self, tmp_path):
        env = dict(os.environ, PYTHONPATH="src")
        ckpt = str(tmp_path / "ckpt")
        # uninterrupted run to step 6
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "llama3_2_1b",
             "--reduced", "--steps", "6", "--ckpt-dir", ckpt + "_full",
             "--ckpt-every", "2", "--batch", "2", "--seq", "16", "--quiet"],
            env=env, cwd="/root/repo", capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        # interrupted run: SIGKILL after ~step 3
        p = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.train", "--arch", "llama3_2_1b",
             "--reduced", "--steps", "6", "--ckpt-dir", ckpt,
             "--ckpt-every", "2", "--batch", "2", "--seq", "16", "--quiet",
             "--sleep-per-step", "0.4"],
            env=env, cwd="/root/repo", stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        deadline = time.time() + 120
        while time.time() < deadline and latest_checkpoint(ckpt) is None:
            time.sleep(0.3)
        time.sleep(0.5)
        p.send_signal(signal.SIGKILL)
        p.wait(timeout=30)
        assert latest_checkpoint(ckpt) is not None, "no checkpoint before kill"
        # resume to completion
        r2 = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch", "llama3_2_1b",
             "--reduced", "--steps", "6", "--ckpt-dir", ckpt,
             "--ckpt-every", "2", "--batch", "2", "--seq", "16", "--quiet"],
            env=env, cwd="/root/repo", capture_output=True, text=True, timeout=300)
        assert r2.returncode == 0, r2.stderr[-2000:]
        # final params identical to the uninterrupted run
        sf = latest_checkpoint(ckpt + "_full")
        sr = latest_checkpoint(ckpt)
        assert sf == sr == 6
        import json
        import numpy as np
        full = np.load(os.path.join(ckpt + "_full", f"step_{sf:08d}", "arrays.npz"))
        res = np.load(os.path.join(ckpt, f"step_{sr:08d}", "arrays.npz"))
        assert sorted(full.files) == sorted(res.files)
        for k in full.files:
            np.testing.assert_array_equal(full[k], res[k])


class TestCompression:
    def test_int8_allreduce_accuracy(self):
        """Compressed all-reduce mean ~= exact mean (single-device ring)."""
        from repro.distributed.compression import _quantize
        x = np.random.RandomState(0).randn(1000).astype(np.float32)
        q, s = _quantize(jnp.asarray(x))
        err = np.abs(np.asarray(q, np.float32) * float(s) - x).max()
        assert err <= float(s) * 0.5 + 1e-6
