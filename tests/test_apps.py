"""The paper's applications: OOC == reference, invariants, chain structure."""
import numpy as np
import pytest

from repro.apps import CloverLeaf2D, CloverLeaf3D, OpenSBLI
from repro.core import (
    OOCConfig, OutOfCoreExecutor, ReferenceRuntime, Runtime, analyze_chain,
)


@pytest.fixture(scope="module")
def cl2d_reference():
    app = CloverLeaf2D(40, 32, summary_every=3)
    summary = app.run(ReferenceRuntime(), steps=3)
    return app, summary


class TestCloverLeaf2D:
    def test_out_of_core_matches(self, cl2d_reference):
        ref_app, ref_summary = cl2d_reference
        app = CloverLeaf2D(40, 32, summary_every=3)
        ex = OutOfCoreExecutor(OOCConfig(num_tiles=4, capacity_bytes=float("inf"),
                                         prefetch=True))
        summary = app.run(Runtime(ex), steps=3)
        np.testing.assert_allclose(
            ref_app.d("density0").interior(), app.d("density0").interior(),
            rtol=1e-4, atol=1e-5)
        for k in ref_summary:
            np.testing.assert_allclose(ref_summary[k], summary[k], rtol=1e-3)

    def test_dataset_count_matches_paper(self):
        assert len(CloverLeaf2D(16, 16).dats) == 25  # §5.1: 25 variables

    def test_fields_finite_and_physical(self, cl2d_reference):
        app, summary = cl2d_reference
        rho = app.d("density0").interior()
        assert np.isfinite(rho).all()
        assert (rho > 0).all()
        assert summary["min_rho"] > 0

    def test_chain_structure(self):
        """One timestep chain (no breakers): 27 physics + 24 halo loops."""
        app = CloverLeaf2D(24, 24, summary_every=0)
        rt = ReferenceRuntime()
        app.record_init(rt)
        rt.flush()
        app.record_timestep(rt)
        assert len(rt.queue) == 51
        info = analyze_chain(rt.queue)
        assert info.skew_slope == 3  # halo mirror reads reach +/-3
        # the §4.1 temporaries exist and are write-first
        for tmp in ("pre_vol", "post_vol", "pre_mass", "ener_flux"):
            assert tmp in info.write_first


class TestCloverLeaf3D:
    def test_out_of_core_matches(self):
        ref = CloverLeaf3D(14, 12, 10, summary_every=2)
        s_ref = ref.run(ReferenceRuntime(), steps=2)
        app = CloverLeaf3D(14, 12, 10, summary_every=2)
        ex = OutOfCoreExecutor(OOCConfig(num_tiles=3, capacity_bytes=float("inf")))
        s = app.run(Runtime(ex), steps=2)
        np.testing.assert_allclose(ref.d("density0").interior(),
                                   app.d("density0").interior(),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(s_ref["sum_mass"], s["sum_mass"], rtol=1e-3)

    def test_dataset_count_matches_paper(self):
        assert len(CloverLeaf3D(8, 8, 8).dats) == 30  # §5.1: 30 variables


class TestOpenSBLI:
    def test_out_of_core_matches_and_multistep_chains(self):
        ref = OpenSBLI(16, chain_steps=1)
        ref.run(ReferenceRuntime(), steps=2)
        app = OpenSBLI(16, chain_steps=2)  # tile ACROSS both timesteps
        # NOTE: cyclic is NOT set here — app.run() enables it after the init
        # phase, per the paper §4.1 (enabling it for the init chain is the
        # documented unsafe case and corrupts the fields).
        ex = OutOfCoreExecutor(OOCConfig(num_tiles=3, capacity_bytes=float("inf"),
                                         prefetch=True))
        rt = Runtime(ex)
        app.run(rt, steps=2)
        np.testing.assert_allclose(ref.d("rho").interior(),
                                   app.d("rho").interior(), rtol=1e-4, atol=1e-5)
        # both timesteps flushed as ONE chain: init + 1 big chain + summary
        big = max(st.num_tiles for st in ex.history)
        assert rt.chains_flushed <= 4

    def test_dataset_count_matches_paper(self):
        assert len(OpenSBLI(8).dats) == 29  # §5.1: 29 datasets

    def test_27_loops_per_step(self):
        app = OpenSBLI(12)
        rt = ReferenceRuntime()
        app.record_init(rt)
        rt.flush()
        app.record_timestep(rt)
        assert len(rt.queue) == 24  # 3 stages x (prim + shear + 5 resid + rk)
