"""Static plan verification: PR 5 hazard regressions, clean-plan sweeps
over the apps/tiers/meshes, the transfer-graph checks, and the plan fuzzer's
zero-false-negative contract."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Compute,
    Download,
    Elide,
    ExecutionConfig,
    HaloExchange,
    OOCConfig,
    OutOfCoreExecutor,
    Plan,
    PlanVerificationError,
    Session,
    Upload,
    check_mutations,
    enumerate_mutations,
    verify_plan,
    verify_plans,
)
from repro.core.memory import P100_PCIE
from repro.core.verify import find_cycle

from test_plan import heat_loops

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- hand-built plans -------------------------------------------------------------


def mini_plan(ops, *, num_tiles=1, num_slots=2, cyclic=False,
              keep_live=(), spill_home=False, device=0, mesh_devices=1):
    return Plan(
        num_tiles=num_tiles, num_slots=num_slots, tiled_dim=0,
        early_submit=num_slots >= 2, cyclic=cyclic, prefetch=False,
        spill_home=spill_home, slot_bytes=0, pinned_bytes=0, loop_bytes=0,
        sig_hash="t" * 40,
        row_bytes=(("u", 8), ("tmp", 8)),
        codec_names=(("u", "identity"), ("tmp", "identity")),
        codec_ratios=(("u", 1.0), ("tmp", 1.0)),
        keep_live=tuple(keep_live),
        tile_origins=((),) * num_tiles,
        ops=tuple(ops), device=device, mesh_devices=mesh_devices)


def up(t, s, items, **kw):
    return Upload(tile=t, slot=s, items=tuple(items), raw=kw.get("raw", 0),
                  wire=kw.get("wire", 0))


def comp(t, s, writes):
    return Compute(tile=t, slot=s, nbytes=0, flops=0,
                   writes=tuple((n, tuple(r)) for n, r in writes),
                   pinned_writes=())


def down(t, s, items):
    return Download(tile=t, slot=s, items=tuple(items), raw=0, wire=0)


class TestPR5HazardRegressions:
    def test_warm_upload_clobber_is_uninit_download_error(self):
        """PR 5 hazard (a): a segmented chain's full-width download shipping
        slot rows that were never staged nor written — home halo columns
        get clobbered with zero-initialised slot content."""
        plan = mini_plan([
            up(0, 0, [("u", 0, 8)]),
            comp(0, 0, [("u", [(0, 8)])]),
            down(0, 0, [("u", -2, 10)]),     # wider than staged+written
        ], num_tiles=1)
        r = verify_plan(plan)
        errs = [d for d in r.errors if d.category == "uninit-download"]
        assert errs, r.summary()
        ivs = {d.interval for d in errs}
        assert (-2, 0) in ivs and (8, 10) in ivs
        assert all(d.dataset == "u" for d in errs)

    def test_stale_cross_segment_elision_is_flagged(self):
        """PR 5 hazard (b): a §4.1 elision applied to a dataset the chain's
        remainder still reads — both as the keep_live contract violation and
        as the stale home read the next segment's upload performs."""
        plan = mini_plan([
            up(0, 0, [("u", 0, 8)]),
            comp(0, 0, [("u", [(0, 8)])]),
            Elide(tile=0, slot=0, items=(("u", 0, 8),), rows=8),
            up(1, 1, [("u", 4, 12)]),        # reads home rows 4..8: stale
            comp(1, 1, [("u", [(8, 12)])]),
            down(1, 1, [("u", 8, 12)]),
        ], num_tiles=2, cyclic=True, keep_live=("u",))
        r = verify_plan(plan)
        cats = {d.category for d in r.errors}
        assert "illegal-elide" in cats, r.summary()
        stale = [d for d in r.errors if d.category == "stale-read"]
        assert stale and stale[0].interval == (4, 8)

    def test_elide_without_cyclic_contract_is_error(self):
        plan = mini_plan([
            up(0, 0, [("tmp", 0, 8)]),
            comp(0, 0, [("tmp", [(0, 8)])]),
            Elide(tile=0, slot=0, items=(("tmp", 0, 8),), rows=8),
        ], cyclic=False)
        assert any(d.category == "illegal-elide" for d in verify_plan(plan).errors)

    def test_dropped_writeback_is_dirty_loss(self):
        plan = mini_plan([
            up(0, 0, [("u", 0, 8)]),
            comp(0, 0, [("u", [(0, 8)])]),
        ])
        errs = verify_plan(plan).errors
        assert any(d.category == "dirty-loss" and d.dataset == "u"
                   for d in errs)


class TestStreamChecks:
    def test_download_before_compute_is_race(self):
        plan = mini_plan([
            up(0, 0, [("u", 0, 8)]),
            down(0, 0, [("u", 0, 8)]),
            comp(0, 0, [("u", [(0, 8)])]),
        ])
        r = verify_plan(plan)
        assert any(d.category == "missing-dep" for d in r.errors)

    def test_slot_conflict(self):
        plan = mini_plan([
            up(0, 1, [("u", 0, 8)]),         # tile 0 must use slot 0
            comp(0, 1, [("u", [(0, 8)])]),
            down(0, 1, [("u", 0, 8)]),
        ])
        assert any(d.category == "slot-conflict"
                   for d in verify_plan(plan).errors)

    def test_missing_ops_flagged(self):
        plan = mini_plan([up(0, 0, [("u", 0, 8)])], num_tiles=2)
        cats = [d.category for d in verify_plan(plan).errors]
        assert cats.count("missing-op") >= 2   # t0 compute, t1 upload+compute

    def test_unknown_dataset(self):
        plan = mini_plan([up(0, 0, [("ghost", 0, 8)])])
        assert any(d.category == "unknown-dataset"
                   for d in verify_plan(plan).errors)

    def test_find_cycle(self):
        assert find_cycle(3, [(0, 1), (1, 2)]) is None
        cyc = find_cycle(3, [(0, 1), (1, 2), (2, 0)])
        assert cyc is not None and len(set(cyc[:-1])) == 3

    def test_halo_depth_insufficient(self):
        plan = mini_plan([
            dataclasses.replace(
                HaloExchange(depth=1, messages=2, nbytes=64)),
            up(0, 0, [("u", -3, 8)]),        # consumes 3 skirt rows
            comp(0, 0, [("u", [(0, 8)])]),
            down(0, 0, [("u", 0, 8)]),
        ], device=1, mesh_devices=4)
        r = verify_plan(plan)
        # pack missing -> halo-order; depth 1 < reach 3 -> halo-depth
        assert any(d.category == "halo-depth" for d in r.errors), r.summary()

    def test_exchange_mismatch_across_devices(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                       mesh="sim:4")
        heat_loops(sess, 48, 24, 2)
        plans = sess.plan()
        assert verify_plans(plans).ok
        # Skew one device's exchange depth: neighbours now disagree on how
        # many rows cross the wire.
        tampered = []
        bumped = False
        for p in plans:
            if not bumped and p.mesh_devices > 1 and p.device == 1:
                ops = tuple(
                    dataclasses.replace(op, depth=op.depth + 1)
                    if isinstance(op, HaloExchange) else op
                    for op in p.ops)
                p = dataclasses.replace(p, ops=ops)
                bumped = True
            tampered.append(p)
        assert bumped
        r = verify_plans(tampered)
        assert any(d.category == "exchange-mismatch" for d in r.errors)


# -- every real plan verifies clean ------------------------------------------------


def _app_plans(app_name, tier, mesh):
    from repro.apps.cloverleaf2d import CloverLeaf2D
    from repro.apps.cloverleaf3d import CloverLeaf3D
    from repro.apps.opensbli import OpenSBLI

    app = {"cloverleaf2d": lambda: CloverLeaf2D(48, 32),
           "cloverleaf3d": lambda: CloverLeaf3D(16, 48, 10),
           "opensbli": lambda: OpenSBLI(24)}[app_name]()
    kw = {"num_tiles": 4}
    if tier == "spill":
        kw["hw"] = P100_PCIE.with_(host_capacity=app.total_bytes() * 0.4)
    else:
        kw["capacity_bytes"] = float("inf")
    if mesh:
        kw["mesh"] = mesh
    sess = Session("sim", **kw)
    app.record_init(sess)
    sess.queue.clear()
    app.dt = 1e-4
    app.record_timestep(sess)
    return sess.plan()


@pytest.mark.parametrize("app_name",
                         ["cloverleaf2d", "cloverleaf3d", "opensbli"])
@pytest.mark.parametrize("tier", ["ram", "spill"])
@pytest.mark.parametrize("mesh", [None, "sim:4"])
def test_all_app_plans_verify_clean(app_name, tier, mesh):
    plans = _app_plans(app_name, tier, mesh)
    assert plans
    r = verify_plans(plans)
    assert r.ok and not r.warnings, r.summary()


def test_segmented_warm_chain_verifies_clean():
    """The MemoryError-split path: warm tail segments with keep_live — the
    exact territory of both PR 5 hazards — must verify clean."""
    ex = OutOfCoreExecutor(OOCConfig(capacity_bytes=4500, cyclic=True))
    sess = Session(backend=ex)
    heat_loops(sess, 48, 10, 16)
    plans = sess.plan()
    assert len(plans) > 1 and any(p.warm for p in plans)
    r = verify_plans(plans)
    assert r.ok and not r.warnings, r.summary()


# -- session / executor wiring -----------------------------------------------------


class TestWiring:
    def test_session_verify_and_explain(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"))
        heat_loops(sess, 40, 24, 2)
        res = sess.verify()
        assert res.ok and res.plans == 1
        text = sess.explain(verify=True)
        assert "verify:" in text and "clean" in text

    def test_debug_mode_runs_clean_plans(self):
        ref = Session("sim", num_tiles=4, capacity_bytes=float("inf"))
        heat_loops(ref, 40, 24, 2)
        ref.flush()
        dbg = Session(ExecutionConfig(backend="ooc", num_tiles=4,
                                      capacity_bytes=float("inf"),
                                      debug=True))
        heat_loops(dbg, 40, 24, 2)
        dbg.flush()   # must not raise
        assert dbg.history    # it executed

    def test_debug_mode_rejects_corrupt_plan(self):
        ex = OutOfCoreExecutor(OOCConfig(num_tiles=4,
                                         capacity_bytes=float("inf"),
                                         debug=True))
        sess = Session(backend=ex)
        heat_loops(sess, 40, 24, 1)
        loops = list(sess.queue)
        ir = ex.plan_chain(loops).ir
        # Drop the last download: dirty rows are never retired.
        cut = tuple(op for op in ir.ops
                    if not (isinstance(op, Download)
                            and op.tile == ir.num_tiles - 1))
        bad = dataclasses.replace(ir, ops=cut)
        with pytest.raises(PlanVerificationError) as ei:
            ex.run_chain(loops, plan=bad)
        assert any(d.category == "dirty-loss"
                   for d in ei.value.result.errors)

    def test_debug_mode_sharded(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                       mesh="sim:4", debug=True)
        heat_loops(sess, 48, 24, 2)
        sess.flush()   # per-device verification + exchange pass, no raise
        assert sess.history


# -- the fuzzer --------------------------------------------------------------------


def _fuzz_corpus():
    corpus = {}
    s = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                cyclic=True, prefetch=True)
    heat_loops(s, 40, 24, 2)
    corpus["heat-cyclic"] = s.plan()
    s = Session("sim", num_tiles=3, num_slots=1,
                capacity_bytes=float("inf"))
    heat_loops(s, 40, 24, 1)
    corpus["heat-1slot"] = s.plan()
    corpus["cl2d"] = _app_plans("cloverleaf2d", "ram", None)
    corpus["cl2d-spill"] = _app_plans("cloverleaf2d", "spill", None)
    corpus["cl2d-mesh"] = _app_plans("cloverleaf2d", "ram", "sim:4")
    return corpus


def test_fuzzer_has_zero_false_negatives():
    total = 0
    missed = []
    for tag, plans in _fuzz_corpus().items():
        for p in plans:
            res = check_mutations(p)
            total += len(res)
            missed += [f"{tag}:{k}" for k, v in res.items() if not v]
    assert total > 500
    assert not missed, f"verifier missed {len(missed)}: {missed[:10]}"


def test_mutations_cover_the_major_categories():
    cats = set()
    for plans in _fuzz_corpus().values():
        for p in plans:
            for m in enumerate_mutations(p):
                cats.update(m.expect)
    assert {"missing-op", "dirty-loss", "uninit-download", "missing-dep",
            "slot-conflict", "illegal-elide", "halo-order", "halo-depth",
            "disk-unfetched", "disk-unspilled"} <= cats


if HAVE_HYPOTHESIS:
    _PAIR_PLANS = None

    def _pair_plans():
        global _PAIR_PLANS
        if _PAIR_PLANS is None:
            s = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                        cyclic=True)
            heat_loops(s, 40, 24, 2)
            (p,) = s.plan()
            _PAIR_PLANS = (p, enumerate_mutations(p))
        return _PAIR_PLANS

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_error_mutation_pairs_still_flagged(data):
        """Corruptions only add defects: applying a second op-dropping
        mutation on top of an error mutant must still be flagged."""
        plan, muts = _pair_plans()
        errors = [m for m in muts if m.severity == "error"]
        first = data.draw(st.sampled_from(errors))
        second = [m for m in enumerate_mutations(first.plan)
                  if m.severity == "error"]
        if second:
            m2 = data.draw(st.sampled_from(second))
            r = verify_plan(m2.plan)
        else:
            r = verify_plan(first.plan)
        assert r.errors
else:
    def test_error_mutation_pairs_still_flagged():
        """Seeded fallback (hypothesis not installed): random error-mutation
        pairs must still produce error diagnostics."""
        rng = np.random.default_rng(7)
        s = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                    cyclic=True)
        heat_loops(s, 40, 24, 2)
        (plan,) = s.plan()
        muts = [m for m in enumerate_mutations(plan)
                if m.severity == "error"]
        for _ in range(40):
            first = muts[rng.integers(len(muts))]
            second = [m for m in enumerate_mutations(first.plan)
                      if m.severity == "error"]
            target = (second[rng.integers(len(second))].plan
                      if second else first.plan)
            assert verify_plan(target).errors
