"""Plan IR tests: typed op streams, planner/interpreter equivalence, JSON
round-trips, Session.plan()/explain(), the sim-driven autotuner, and the
reduction-retention regression."""
import json

import numpy as np
import pytest

from repro.core import (
    Arg,
    CarryEdge,
    Compute,
    Download,
    Elide,
    Evict,
    OOCConfig,
    OutOfCoreExecutor,
    P100_PCIE,
    Plan,
    Prefetch,
    READ,
    RW,
    ReductionSpec,
    Session,
    Upload,
    WRITE,
    Block,
    make_dataset,
    plans_from_json,
    plans_to_json,
    point_stencil,
    simulate_plan,
    star_stencil,
)


def heat_loops(rt, n, m, steps, seed=7, reduce_=False):
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    blk = Block("grid", (n, m))
    u = make_dataset(blk, "u", halo=1, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=1)
    S, Z = star_stencil(2, 1), point_stencil(2)
    interior = ((1, n - 1), (1, m - 1))
    for s in range(steps):
        rt.par_loop(
            f"avg{s}", blk, interior, [Arg(u, S, READ), Arg(tmp, Z, WRITE)],
            lambda acc: {"tmp": 0.25 * (acc("u", (1, 0)) + acc("u", (-1, 0))
                                        + acc("u", (0, 1)) + acc("u", (0, -1)))})
        rt.par_loop(
            f"copy{s}", blk, interior, [Arg(tmp, Z, READ), Arg(u, Z, RW)],
            lambda acc: {"u": acc("tmp")})
    if reduce_:
        rt.par_loop(
            "sum", blk, interior, [Arg(u, Z, READ)],
            lambda acc: {"total": jnp.sum(acc("u"))},
            reductions=[ReductionSpec("total", "sum")])
    return u


def cl2d_step_session(backend, nx=40, ny=24, **kw):
    """A Session with one recorded CloverLeaf2D timestep chain (unflushed)."""
    from repro.apps import CloverLeaf2D

    app = CloverLeaf2D(nx, ny, summary_every=0)
    sess = Session(backend, num_tiles=4, capacity_bytes=float("inf"), **kw)
    app.record_init(sess)
    sess.queue.clear()          # plan/run the timestep chain only
    app.dt = 1e-4
    app.record_timestep(sess)
    return app, sess


class TestPlanStructure:
    def test_op_stream_shape(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"))
        heat_loops(sess, 40, 24, 2)
        (plan,) = sess.plan()
        kinds = [op.kind for op in plan.ops]
        assert plan.num_tiles == 4 and plan.num_slots == 3
        assert kinds.count("compute") == 4
        # pipelined: tile 1's upload is submitted before tile 0's compute
        assert kinds.index("upload") < kinds.index("compute")
        assert kinds[:3] == ["upload", "upload", "compute"]
        # one eviction: 4 tiles through 3 slots
        evicts = [op for op in plan.ops if isinstance(op, Evict)]
        assert [(e.tile, e.slot) for e in evicts] == [(3, 0)]
        # slot assignment is the round-robin the LRU pool degenerates to
        for op in plan.ops:
            if isinstance(op, (Upload, Compute, Download)):
                assert op.slot == op.tile % plan.num_slots
        counts = plan.counts()
        assert counts["computes"] == 4 and counts["evictions"] == 1
        assert counts["carries"] == 3            # every tile boundary
        assert plan.totals()["uploaded"] > 0

    def test_cyclic_elision_and_prefetch_ops(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                       cyclic=True, prefetch=True)
        heat_loops(sess, 40, 24, 2)
        (plan,) = sess.plan()
        assert plan.cyclic and plan.prefetch
        assert any(isinstance(op, Elide) for op in plan.ops)   # tmp is dead
        pf = [op for op in plan.ops if isinstance(op, Prefetch)]
        assert len(pf) == 1 and pf[0].wire > 0
        # elided temporaries never download
        for op in plan.ops:
            if isinstance(op, Download):
                assert all(name != "tmp" for name, _, _ in op.items)

    def test_one_slot_pool_orders_in_order(self):
        sess = Session("sim", num_tiles=3, num_slots=1,
                       capacity_bytes=float("inf"))
        heat_loops(sess, 40, 24, 1)
        (plan,) = sess.plan()
        assert not plan.early_submit
        kinds = [op.kind for op in plan.ops]
        # strict order: compute 0 retires before upload 1 is staged
        assert kinds.index("compute") < kinds.index("evict")
        for op in plan.ops:
            if isinstance(op, CarryEdge):
                assert op.dst_slot == 0    # the single slot continues

    def test_keep_live_blocks_elision(self):
        ex = OutOfCoreExecutor(OOCConfig(num_tiles=4,
                                         capacity_bytes=float("inf"),
                                         cyclic=True))
        sess = Session(backend=ex)
        heat_loops(sess, 40, 24, 2)
        loops = list(sess.queue)
        free = ex.plan_chain(loops).ir
        held = ex.plan_chain(loops, keep_live=frozenset({"tmp"})).ir
        assert any(isinstance(op, Elide) for op in free.ops)
        assert not any(isinstance(op, Elide) for op in held.ops)
        assert held.keep_live == ("tmp",)


class TestInterpreterEquivalence:
    def test_sim_and_real_share_the_op_stream(self):
        """The acceptance criterion: ooc, ooc-async and sim lower one chain
        to the *same* instruction stream, and (identity codec) the modelled
        makespans agree exactly."""
        plans = {}
        spans = {}
        for backend in ("ooc", "ooc-async", "sim"):
            app, sess = cl2d_step_session(backend)
            (plans[backend],) = sess.plan()
            sess.flush()
            spans[backend] = sess.history[-1].modelled_s
            sess.close()
        assert plans["ooc"] == plans["sim"] == plans["ooc-async"]
        assert spans["ooc"] == spans["sim"] == spans["ooc-async"]

    def test_plan_preview_matches_execution(self):
        """Session.plan() must predict exactly what run_chain interprets
        (same cached ChainPlan, no re-planning, queue untouched)."""
        app, sess = cl2d_step_session("sim")
        n_queued = len(sess.queue)
        (preview,) = sess.plan()
        assert len(sess.queue) == n_queued
        sess.flush()
        st = sess.history[-1]
        assert st.op_counts == preview.counts()
        assert sess.plan_stats()["plan_hits"] >= 1   # flush reused the plan

    def test_simulate_plan_matches_sim_backend(self):
        app, sess = cl2d_step_session("sim")
        (plan,) = sess.plan()
        res = simulate_plan(plan, sess.config.hw)
        sess.flush()
        st = sess.history[-1]
        assert res.makespan == pytest.approx(st.modelled_s)
        assert res.uploaded == st.uploaded
        assert res.downloaded == st.downloaded


class TestPlanJSON:
    def test_round_trip_equality(self):
        for backend, kw in (("sim", {}), ("sim", {"cyclic": True,
                                                  "prefetch": True})):
            app, sess = cl2d_step_session(backend, **kw)
            (plan,) = sess.plan()
            back = Plan.from_json(plan.to_json())
            assert back == plan
            assert back.counts() == plan.counts()

    def test_multi_plan_document(self):
        app, sess = cl2d_step_session("sim")
        plans = sess.plan()
        back = plans_from_json(plans_to_json(plans))
        assert back == plans

    def test_imported_plan_interprets_bit_identical(self):
        """export -> import -> interpret must produce bit-identical data."""
        def run(use_import):
            app, sess = cl2d_step_session("ooc")
            loops = list(sess.queue)
            sess.queue.clear()
            ex = sess.backend
            if use_import:
                ir = Plan.from_json(ex.plan_chain(loops).ir.to_json())
                ex.run_chain(loops, plan=ir)
            else:
                ex.run_chain(loops)
            out = {n: d.data.copy() for n, d in app.dats.items()}
            sess.close()
            return out
        a, b = run(False), run(True)
        assert set(a) == set(b)
        for name in a:
            np.testing.assert_array_equal(a[name], b[name])

    def test_mismatched_import_rejected(self):
        app, sess = cl2d_step_session("ooc")
        loops = list(sess.queue)
        sess.queue.clear()
        other = Session("sim", num_tiles=2, capacity_bytes=float("inf"))
        heat_loops(other, 32, 16, 1)
        (foreign,) = other.plan()
        with pytest.raises(ValueError, match="does not match"):
            sess.backend.run_chain(loops, plan=foreign)
        sess.close()

    def test_mismatched_geometry_rejected(self):
        """Same chain, different tile geometry: the imported stream must be
        rejected up front, not fail deep inside the transfer engine."""
        app, sess = cl2d_step_session("ooc")
        loops = list(sess.queue)
        sess.queue.clear()
        other = Session("sim", num_tiles=2, capacity_bytes=float("inf"))
        ir = other.backend.plan_chain(loops).ir   # 2 tiles vs session's 4
        with pytest.raises(ValueError, match="tile geometry"):
            sess.backend.run_chain(loops, plan=ir)
        sess.close()

    def test_bad_version_rejected(self):
        doc = {"version": 99, "meta": {}, "ops": []}
        with pytest.raises(ValueError, match="version"):
            Plan.from_json(json.dumps(doc))


class TestExplain:
    @pytest.mark.parametrize("app_name", ["cloverleaf2d", "cloverleaf3d",
                                          "opensbli"])
    def test_explain_and_json_on_all_apps(self, app_name):
        from repro.apps import CloverLeaf2D, CloverLeaf3D, OpenSBLI

        build = {"cloverleaf2d": lambda: CloverLeaf2D(32, 24, summary_every=0),
                 "cloverleaf3d": lambda: CloverLeaf3D(12, 10, 8),
                 "opensbli": lambda: OpenSBLI(16)}[app_name]
        app = build()
        sess = Session("sim", num_tiles=3, capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.queue.clear()
        app.dt = 1e-4
        app.record_timestep(sess)
        text = sess.explain()
        assert "tiles x" in text and "compute" in text
        assert "modelled makespan" in text
        for plan in sess.plan():
            assert Plan.from_json(plan.to_json()) == plan

    def test_explain_empty_queue(self):
        sess = Session("sim")
        assert "nothing queued" in sess.explain()

    def test_plan_requires_planning_backend(self):
        sess = Session("reference")
        heat_loops(sess, 16, 8, 1)
        with pytest.raises(ValueError, match="does not build plans"):
            sess.plan()


class TestTune:
    def _transfer_bound_session(self):
        from repro.apps import CloverLeaf2D

        hw = P100_PCIE.with_(link_latency=1e-6, up_bw=2e9, down_bw=2e9)
        app = CloverLeaf2D(48, 32, summary_every=0)
        sess = Session("sim", hw=hw, num_tiles=4,
                       capacity_bytes=app.total_bytes() / 2)
        app.record_init(sess)
        sess.queue.clear()
        app.dt = 1e-4
        app.record_timestep(sess)
        return sess

    def test_tune_never_worse_than_default(self):
        sess = self._transfer_bound_session()
        res = sess.tune()
        assert res.best_makespan <= res.baseline_makespan
        assert res.speedup >= 1.0
        assert any(r["feasible"] for r in res.rows)
        assert "best" in res.summary()

    def test_tune_respects_capacity(self):
        sess = self._transfer_bound_session()
        res = sess.tune(num_tiles=(1, 2, None), num_slots=(3,),
                        tiled_dims=(0,))
        one_tile = [r for r in res.rows if r["num_tiles"] == 1]
        assert one_tile and not one_tile[0]["feasible"]   # 1 tile can't fit
        assert res.best.num_tiles != 1

    def test_tune_apply_rebuilds_backend(self):
        sess = self._transfer_bound_session()
        res = sess.tune(apply=True)
        assert sess.config == res.best
        sess.flush()    # the queue survived and runs under the new config
        assert sess.history[-1].modelled_s > 0

    def test_tune_empty_queue_raises(self):
        sess = Session("sim")
        with pytest.raises(ValueError, match="record loops"):
            sess.tune()

    def test_tune_rejects_nonplanning_backend(self):
        sess = Session("reference")
        heat_loops(sess, 16, 8, 1)
        with pytest.raises(ValueError, match="no planner"):
            sess.tune()


class TestChainStatsOps:
    def test_op_counts_in_history(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"),
                       cyclic=True)
        heat_loops(sess, 40, 24, 2)
        sess.flush()
        ops = sess.history[-1].op_counts
        assert ops["computes"] == 4
        assert ops["uploads"] >= 1 and ops["downloads"] >= 1
        assert ops["elisions"] >= 1      # cyclic: tmp elided
        assert ops["evictions"] == 1


class TestReductionRetention:
    def test_second_read_returns_same_value(self):
        """Regression: Session.reduction() used to pop its result, so a
        second read of the same reduction raised KeyError."""
        sess = Session("reference")
        heat_loops(sess, 24, 16, 1, reduce_=True)
        first = sess.reduction("total")
        second = sess.reduction("total")
        np.testing.assert_array_equal(first, second)

    def test_next_flush_replaces_results(self):
        import jax.numpy as jnp

        sess = Session("reference")
        blk = Block("g", (8, 8))
        rng = np.random.RandomState(3)
        u = make_dataset(blk, "u", halo=1,
                         init=rng.rand(8, 8).astype(np.float32))
        Z = point_stencil(2)

        def record(scale):
            sess.par_loop(
                "s", blk, ((1, 7), (1, 7)), [Arg(u, Z, READ)],
                lambda acc: {"total": scale * jnp.sum(acc("u"))},
                reductions=[ReductionSpec("total", "sum")])

        record(1.0)
        t1 = float(sess.reduction("total"))
        record(2.0)
        t2 = float(sess.reduction("total"))
        assert t2 == pytest.approx(2 * t1, rel=1e-5)
        # the old result is gone after the new flush, not accumulated
        assert float(sess.reduction("total")) == t2


class TestPlanErrors:
    """Satellite: Plan.from_json raises typed PlanError naming the offending
    op/field instead of bare KeyError/TypeError on malformed documents."""

    def _plan(self):
        sess = Session("sim", num_tiles=4, capacity_bytes=float("inf"))
        heat_loops(sess, 40, 24, 2)
        (plan,) = sess.plan()
        return plan

    def test_truncated_json(self):
        from repro.core import PlanError

        text = self._plan().to_json()
        with pytest.raises(PlanError, match="truncated"):
            Plan.from_json(text[: len(text) // 2])

    def test_version_skew(self):
        from repro.core import PlanError

        doc = json.loads(self._plan().to_json())
        doc["version"] = 1
        with pytest.raises(PlanError, match="unsupported plan version 1"):
            Plan.from_json(json.dumps(doc))

    def test_missing_op_field_names_index(self):
        from repro.core import PlanError

        doc = json.loads(self._plan().to_json())
        del doc["ops"][3]["op"]
        with pytest.raises(PlanError, match="op 3"):
            Plan.from_json(json.dumps(doc))

    def test_unknown_op_kind(self):
        from repro.core import PlanError

        doc = json.loads(self._plan().to_json())
        doc["ops"][0]["op"] = "teleport"
        with pytest.raises(PlanError, match="unknown op kind 'teleport'"):
            Plan.from_json(json.dumps(doc))

    def test_op_field_mismatch_names_fields(self):
        from repro.core import PlanError

        doc = json.loads(self._plan().to_json())
        entry = next(e for e in doc["ops"] if e["op"] == "compute")
        del entry["flops"]
        entry["warp"] = 9
        with pytest.raises(PlanError, match="missing: flops.*unexpected: warp"):
            Plan.from_json(json.dumps(doc))

    def test_meta_field_mismatch(self):
        from repro.core import PlanError

        doc = json.loads(self._plan().to_json())
        del doc["meta"]["num_tiles"]
        with pytest.raises(PlanError, match="missing: num_tiles"):
            Plan.from_json(json.dumps(doc))

    def test_missing_sections(self):
        from repro.core import PlanError

        with pytest.raises(PlanError, match="no 'ops' section"):
            Plan.from_json('{"version": 3, "meta": {}}')
        with pytest.raises(PlanError, match="must be a JSON object"):
            Plan.from_json('[1, 2]')

    def test_plans_from_json_not_a_list(self):
        from repro.core import PlanError

        with pytest.raises(PlanError, match="JSON array"):
            plans_from_json('{"version": 3}')


class TestVerdictStability:
    """Satellite: the verifier's verdict is a plan property, so it must
    survive JSON round-trips — including v2 documents loaded under v3."""

    def _plans(self, app_name):
        from test_verify import _app_plans

        return _app_plans(app_name, "ram", None)

    @pytest.mark.parametrize("app_name",
                             ["cloverleaf2d", "cloverleaf3d", "opensbli"])
    def test_roundtrip_verdict_stable(self, app_name):
        from repro.core import verify_plans

        plans = self._plans(app_name)
        before = verify_plans(plans)
        back = plans_from_json(plans_to_json(plans))
        after = verify_plans(back)
        assert before.ok and after.ok
        assert before.diagnostics == after.diagnostics

    def test_v2_document_under_v3_same_verdict(self):
        from repro.core import verify_plan

        (plan,) = self._plans("cloverleaf2d")
        before = verify_plan(plan)
        doc = json.loads(plan.to_json())
        doc["version"] = 2
        for key in ("device", "mesh_devices", "shard_dim", "warm"):
            doc["meta"].pop(key, None)
        v2 = Plan.from_json(json.dumps(doc))
        assert v2.mesh_devices == 1 and v2.warm == ()
        after = verify_plan(v2)
        assert before.ok and after.ok
        assert ([d.category for d in before.diagnostics]
                == [d.category for d in after.diagnostics])

    def test_corrupt_plan_verdict_survives_roundtrip(self):
        """An *unsound* plan must stay flagged after export/import."""
        from repro.core import verify_plan

        (plan,) = self._plans("cloverleaf2d")
        import dataclasses

        cut = tuple(op for op in plan.ops
                    if not (isinstance(op, Download)
                            and op.tile == plan.num_tiles - 1))
        bad = dataclasses.replace(plan, ops=cut)
        before = verify_plan(bad)
        assert not before.ok
        back = Plan.from_json(bad.to_json())
        after = verify_plan(back)
        assert ({d.category for d in before.errors}
                == {d.category for d in after.errors})
