"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 real device
(the dry-run sets its own device count in its own process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
