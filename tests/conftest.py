"""Shared fixtures.

Multi-device tests (the sharded backend's jax meshes, in-process halo
exchanges) need several XLA host devices in the MAIN pytest process, so the
flag is forced here — conftest imports before any test module can import
jax, which is exactly the ordering the old per-module self-configuration
could not guarantee.  CI sets the same flag at the job level; an operator's
own XLA_FLAGS is never clobbered.  Subprocess-based tests (dry-run, the
distributed scripts) still set their own count in their own process.
"""
import os
import sys

if "XLA_FLAGS" not in os.environ and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
