"""Serving-layer tests: multi-tenant determinism, admission control,
cross-tenant plan sharing, preemption/restore, session-close semantics.

Everything runs on virtual ``sim:N`` lane pools with real data-plane
executors — deterministic on CPU, no accelerator needed.  The load-bearing
property throughout: concurrency and scheduling move *wall-clock* time only;
tenant results are bit-identical to serial runs because tenants own disjoint
datasets and kernels are pure.
"""
import threading

import numpy as np
import pytest

from repro.core import Block, Session, SessionClosedError, make_dataset
from repro.apps.cloverleaf2d import CloverLeaf2D
from repro.apps.cloverleaf3d import CloverLeaf3D
from repro.apps.opensbli import OpenSBLI
from repro.serve import (
    AdmissionError,
    ServeError,
    SharedPlanCache,
    StencilServer,
    available_policies,
    make_policy,
)

CAP = 2e6   # small enough to force real multi-tile streaming on test grids


def _serial(app_factory, steps):
    app = app_factory()
    rt = app.make_session("ooc", capacity_bytes=CAP)
    try:
        return app.run(rt, steps=steps)
    finally:
        rt.close()


def _assert_summaries_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"summary {k!r} diverged")


# -- concurrent determinism ---------------------------------------------------------

_WORKLOADS = [
    ("cl2d-a", lambda: CloverLeaf2D(nx=24, ny=24, summary_every=2), 2),
    ("cl2d-b", lambda: CloverLeaf2D(nx=20, ny=28, summary_every=2), 2),
    ("cl3d-a", lambda: CloverLeaf3D(nx=10, ny=10, nz=10, summary_every=2), 2),
    ("osbli-a", lambda: OpenSBLI(n=12), 2),
    ("cl2d-c", lambda: CloverLeaf2D(nx=24, ny=24, summary_every=2), 2),
    ("cl2d-d", lambda: CloverLeaf2D(nx=28, ny=20, summary_every=2), 2),
    ("cl3d-b", lambda: CloverLeaf3D(nx=12, ny=8, nz=10, summary_every=2), 2),
    ("osbli-b", lambda: OpenSBLI(n=10), 2),
]


@pytest.fixture(scope="module")
def serial_results():
    """Ground truth, computed once: each workload run alone on a plain
    single-session ooc backend."""
    return {name: _serial(factory, steps)
            for name, factory, steps in _WORKLOADS}


@pytest.mark.parametrize("policy", ["fifo", "sjf"])
def test_eight_tenants_bit_identical_to_serial(policy, serial_results):
    """8 mixed-app tenants submitted from threads against one sim:4 pool
    produce exactly the serial results, under both scheduling policies."""
    outs, errs = {}, []
    with StencilServer("sim:4", policy=policy, capacity_bytes=CAP) as srv:
        def work(name, factory, steps):
            try:
                app = factory()
                rt = srv.session(name)
                try:
                    outs[name] = app.run(rt, steps=steps)
                finally:
                    rt.close()
            except BaseException as e:  # surfaced after join
                errs.append((name, e))
        threads = [threading.Thread(target=work, args=w) for w in _WORKLOADS]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs, f"tenant failures: {errs}"
        st = srv.stats()
        # Identical cl2d tenants must have shared plans across tenants.
        assert st.cross_tenant_plan_hits > 0
        assert st.jobs_completed >= len(_WORKLOADS)
        assert st.jobs_rejected == 0
        # Every tenant's achieved ledger time matches what the admission
        # oracle predicted from the same plans.
        for name, t in st.tenants.items():
            if t.predicted_s > 0:
                assert t.achieved_modelled_s == pytest.approx(
                    t.predicted_s, rel=0.5), name
    for name, _, _ in _WORKLOADS:
        _assert_summaries_equal(outs[name], serial_results[name])


def test_single_tenant_matches_serial(serial_results):
    name, factory, steps = _WORKLOADS[0]
    with StencilServer("sim:2", capacity_bytes=CAP) as srv:
        app = factory()
        rt = srv.session("solo")
        out = app.run(rt, steps=steps)
        rt.close()
    _assert_summaries_equal(out, serial_results[name])


# -- cross-tenant plan sharing ------------------------------------------------------

def test_cross_tenant_plan_cache_hit_and_stats():
    with StencilServer("sim:2", capacity_bytes=CAP) as srv:
        for name in ("alice", "bob"):
            app = CloverLeaf2D(nx=24, ny=24, summary_every=2)
            rt = srv.session(name)
            app.run(rt, steps=2)
            rt.close()
        st = srv.stats()
        cache = st.plan_cache
        assert st.cross_tenant_plan_hits > 0
        assert cache["inserts"] > 0
        assert cache["hits"] >= cache["cross_tenant_hits"]
        # bob adopted alice's plans: far fewer inserts than total jobs
        assert st.tenants["bob"].chains > 0


def test_shared_cache_lru_and_counters():
    cache = SharedPlanCache(max_plans=2)
    sentinel = object()
    cache.insert(("k1",), sentinel, "a")
    cache.insert(("k2",), sentinel, "a")
    cache.insert(("k3",), sentinel, "a")       # evicts k1
    assert len(cache) == 2
    assert cache.lookup(("k1",), "b") is None
    assert cache.lookup(("k2",), "b") is sentinel
    assert cache.cross_tenant_hits == 1
    assert cache.lookup(("k2",), "a") is sentinel
    assert cache.cross_tenant_hits == 1        # same-tenant hit not counted
    s = cache.stats()
    assert s["inserts"] == 3 and s["hits"] == 2 and s["misses"] == 1


# -- admission control --------------------------------------------------------------

def test_admission_rejects_oversized_job_typed():
    with StencilServer("sim:1", capacity_bytes=1024) as srv:
        app = CloverLeaf2D(nx=64, ny=64, summary_every=1)
        rt = srv.session("big")
        with pytest.raises(AdmissionError) as ei:
            app.record_init(rt)
            rt.flush()
        assert isinstance(ei.value, RuntimeError)   # typed, not AttributeError
        assert srv.stats().jobs_rejected >= 1
        assert srv.stats().tenants["big"].rejected >= 1
        # the server survives a rejection: the session must close cleanly
        # (the rejected loops were consumed by the failed flush)
        rt.queue.clear()
        rt.close()


def test_admission_admits_and_predicts():
    with StencilServer("sim:1", capacity_bytes=CAP) as srv:
        app = CloverLeaf2D(nx=24, ny=24, summary_every=1)
        rt = srv.session("ok")
        app.record_init(rt)
        verdict = srv.oracle.predict(list(rt.queue), tenant="ok")
        assert verdict.admitted
        assert verdict.predicted_makespan_s > 0
        assert 0 < verdict.predicted_bytes <= CAP
        rt.flush()
        sla = srv.sla_estimate("ok")
        assert set(sla) == {"queued_jobs", "predicted_queue_wait_s",
                            "predicted_makespan_s"}
        rt.close()


# -- preemption / migration ---------------------------------------------------------

def _drive_cl2d(app, rt, steps, *, preempt=None):
    """app.run's loop, with an optional (server, tenant, step) preempt hook
    fired between chain boundaries — mid-workload, deterministically."""
    app.record_init(rt)
    rt.flush()
    rt.cyclic = True
    for s in range(steps):
        if preempt is not None and s == preempt[2]:
            preempt[0].preempt(preempt[1])
        app._ideal_gas(rt, "density0", "energy0", "_dt")
        app._viscosity(rt)
        app._calc_dt(rt)
        app.dt = float(min(1e-4, rt.reduction("dt")))
        app.record_timestep(rt)
    out = {}
    for name in app.record_summary(rt):
        out[name] = float(rt.reduction(name))
    rt.flush()
    return out


def test_preempt_checkpoint_resume_bit_identical(tmp_path):
    steps = 3
    plain_app = CloverLeaf2D(nx=24, ny=24, summary_every=0)
    plain = plain_app.make_session("ooc", capacity_bytes=CAP)
    want = _drive_cl2d(plain_app, plain, steps)
    plain.close()

    with StencilServer("sim:2", capacity_bytes=CAP,
                       spill_dir=str(tmp_path)) as srv:
        app = CloverLeaf2D(nx=24, ny=24, summary_every=0)
        rt = srv.session("victim", priority=0)
        got = _drive_cl2d(app, rt, steps, preempt=(srv, "victim", 1))
        rt.close()
        st = srv.stats()
        assert st.preemptions >= 1
        assert st.tenants["victim"].preemptions >= 1
    _assert_summaries_equal(got, want)


def test_auto_preempt_flags_lower_priority():
    """A high-priority tenant queued behind a busy pool flags the running
    low-priority tenant; the victim's next boundary pays a checkpoint/restore
    cycle and both finish bit-identical to serial."""
    results = {}
    with StencilServer("sim:1", capacity_bytes=CAP, policy="fifo") as srv:
        lo_app = CloverLeaf2D(nx=24, ny=24, summary_every=3)
        hi_app = CloverLeaf2D(nx=20, ny=20, summary_every=3)
        lo = srv.session("lo", priority=0)
        hi = srv.session("hi", priority=5)

        def lo_work():
            results["lo"] = lo_app.run(lo, steps=3)

        def hi_work():
            results["hi"] = hi_app.run(hi, steps=3)

        t1 = threading.Thread(target=lo_work)
        t2 = threading.Thread(target=hi_work)
        t1.start()
        t2.start()
        t1.join()
        t2.join()
        lo.close()
        hi.close()
        st = srv.stats()
        assert st.jobs_completed > 0
    _assert_summaries_equal(
        results["lo"],
        _serial(lambda: CloverLeaf2D(nx=24, ny=24, summary_every=3), 3))
    _assert_summaries_equal(
        results["hi"],
        _serial(lambda: CloverLeaf2D(nx=20, ny=20, summary_every=3), 3))


# -- session close semantics (satellite) --------------------------------------------

def _tiny_loop(rt, dat):
    rt.par_loop("scale", dat.block, dat.block.full_range(), [dat],
                lambda acc: {dat.name: acc(dat.name) * 0.5})


def test_session_close_is_idempotent():
    rt = Session("ooc", capacity_bytes=CAP)
    blk = Block("b", (16, 16))
    d = make_dataset(blk, "d", init=np.ones((16, 16), np.float32))
    _tiny_loop(rt, d)
    rt.flush()
    rt.close()
    rt.close()          # second close: no-op, no AttributeError
    rt.close()


def test_par_loop_after_close_raises_typed():
    rt = Session("ooc", capacity_bytes=CAP)
    blk = Block("b", (16, 16))
    d = make_dataset(blk, "d", init=np.ones((16, 16), np.float32))
    rt.close()
    with pytest.raises(SessionClosedError):
        _tiny_loop(rt, d)
    # reads of already-materialised data stay legal after close
    assert rt.fetch(d).shape == (16, 16)
    rt.flush()          # empty flush after close: explicit no-op
    with pytest.raises(SessionClosedError):
        rt.queue.append(object())   # hand-mutated queue must not run
        rt.flush()


def test_server_session_close_deregisters():
    with StencilServer("sim:1", capacity_bytes=CAP) as srv:
        app = CloverLeaf2D(nx=24, ny=24, summary_every=1)
        rt = srv.session("t")
        app.record_init(rt)
        rt.flush()
        backend = rt.backend
        rt.close()
        rt.close()      # idempotent through the client too
        assert srv.stats().tenants["t"].state == "closed"
        with pytest.raises(SessionClosedError):
            backend.run_chain([])   # use-after-close is typed, not AttributeError
        # a closed tenant's name is reusable
        rt2 = srv.session("t")
        rt2.close()


def test_duplicate_tenant_rejected():
    with StencilServer("sim:1", capacity_bytes=CAP) as srv:
        rt = srv.session("dup")
        with pytest.raises(ServeError):
            srv.session("dup")
        rt.close()


# -- registry / stats plumbing ------------------------------------------------------

def test_policy_registry():
    assert {"fifo", "sjf"} <= set(available_policies())
    with pytest.raises(ValueError):
        make_policy("nope")
    from repro.serve.policy import JobView
    a = JobView(tenant="a", seq=1, priority=0, predicted_makespan_s=5.0)
    b = JobView(tenant="b", seq=2, priority=0, predicted_makespan_s=1.0)
    c = JobView(tenant="c", seq=3, priority=9, predicted_makespan_s=9.0)
    assert make_policy("fifo").select([a, b]) is a
    assert make_policy("sjf").select([a, b]) is b
    # priority classes dominate under both policies
    assert make_policy("fifo").select([a, b, c]) is c
    assert make_policy("sjf").select([a, b, c]) is c


def test_server_stats_summary_renders():
    with StencilServer("sim:2", capacity_bytes=CAP) as srv:
        app = CloverLeaf2D(nx=24, ny=24, summary_every=2)
        rt = srv.session("s")
        app.run(rt, steps=2)
        rt.close()
        text = srv.stats().summary()
    assert "policy=fifo" in text
    assert "cross-tenant" in text
    assert "s:" in text
