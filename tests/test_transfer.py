"""repro.core.transfer: codec round-trips, residency invariants, and
threaded-vs-synchronous engine equivalence on real chains."""
import numpy as np
import pytest

try:  # optional test extra: example-based tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import Session, make_dataset
from repro.core.transfer import (
    ResidencyError,
    ResidencyManager,
    TransferEngine,
    TransferError,
    available_codecs,
    get_codec,
    resolve_codecs,
)


# -- codecs -----------------------------------------------------------------------


LOSSLESS = ("identity", "shuffle-rle")
LOSSY = ("fp16", "bf16")


def _sample_arrays():
    rng = np.random.RandomState(3)
    smooth = np.add.outer(np.linspace(0, 1, 24), np.linspace(0, 2, 17))
    return [
        rng.rand(19, 11).astype(np.float32),
        smooth.astype(np.float32),
        smooth.astype(np.float64),
        np.arange(60, dtype=np.int32).reshape(5, 12),
        np.zeros((4, 6), np.float32),
        np.zeros((0, 5), np.float32),
        np.full((31,), -7.25, np.float32),
    ]


class TestCodecs:
    @pytest.mark.parametrize("name", LOSSLESS)
    def test_lossless_roundtrip_exact(self, name):
        codec = get_codec(name)
        for arr in _sample_arrays():
            dec, raw, wire = codec.roundtrip(arr)
            assert dec.dtype == arr.dtype and dec.shape == arr.shape
            assert raw == arr.nbytes
            np.testing.assert_array_equal(np.asarray(dec), arr)
            # bit-exact, not just value-equal
            assert np.asarray(dec).tobytes() == arr.tobytes()

    @pytest.mark.parametrize("name", LOSSY)
    def test_lossy_roundtrip_within_tolerance(self, name):
        codec = get_codec(name)
        for arr in _sample_arrays():
            dec, raw, wire = codec.roundtrip(arr)
            assert dec.dtype == arr.dtype and dec.shape == arr.shape
            if arr.dtype.kind != "f":
                np.testing.assert_array_equal(np.asarray(dec), arr)  # passthrough
                assert wire == raw
            else:
                # half/bfloat16 keep ~3 decimal digits on unit-scale data
                np.testing.assert_allclose(np.asarray(dec), arr,
                                           rtol=1e-2, atol=1e-3)
                if arr.size:
                    # 16-bit payload: 2x on fp32, 4x on fp64
                    assert raw == wire * arr.dtype.itemsize // 2

    def test_downcast_halves_fp32_wire_bytes(self):
        arr = np.random.RandomState(0).rand(64, 64).astype(np.float32)
        for name in LOSSY:
            _, raw, wire = get_codec(name).roundtrip(arr)
            assert raw / wire == 2.0

    def test_shuffle_rle_compresses_smooth_fields(self):
        smooth = np.full((128, 64), 3.25, np.float32)
        _, raw, wire = get_codec("shuffle-rle").roundtrip(smooth)
        assert raw / wire > 4.0

    def test_registry(self):
        assert set(LOSSLESS + LOSSY) <= set(available_codecs())
        with pytest.raises(ValueError):
            get_codec("no-such-codec")
        cs = resolve_codecs({"u": "fp16", "*": "identity"}, ("u", "v"))
        assert cs["u"].name == "fp16" and cs["v"].name == "identity"
        cs = resolve_codecs("bf16", ("u", "v"))
        assert cs["u"].name == cs["v"].name == "bf16"

    def test_downcast_preserves_nan_and_inf(self):
        arr = np.array([np.nan, -np.nan, np.inf, -np.inf, 1.5, -2.25, 0.0],
                       np.float32)
        # include a worst-case NaN payload whose mantissa is all ones
        arr[1] = np.frombuffer(np.uint32(0x7FFFFFFF).tobytes(), np.float32)[0]
        for name in LOSSY:
            dec, _, _ = get_codec(name).roundtrip(arr)
            dec = np.asarray(dec)
            np.testing.assert_array_equal(np.isnan(dec), np.isnan(arr))
            np.testing.assert_array_equal(dec[2:], arr[2:])

    def test_nominal_ratios(self):
        assert get_codec("fp16").nominal_ratio(np.float32) == 2.0
        assert get_codec("fp16").nominal_ratio(np.float64) == 4.0
        assert get_codec("fp16").nominal_ratio(np.int32) == 1.0
        assert get_codec("identity").nominal_ratio(np.float32) == 1.0


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 16), st.integers(1, 400),
           st.sampled_from(["f4", "f8", "i4", "u1"]),
           st.sampled_from(LOSSLESS))
    @settings(max_examples=40, deadline=None)
    def test_lossless_roundtrip_property(seed, n, dtype, codec_name):
        rng = np.random.RandomState(seed)
        arr = (rng.rand(n) * 100).astype(dtype)
        dec, raw, wire = get_codec(codec_name).roundtrip(arr)
        assert np.asarray(dec).tobytes() == arr.tobytes()

    @given(st.integers(0, 2 ** 16), st.integers(1, 400),
           st.sampled_from(LOSSY))
    @settings(max_examples=40, deadline=None)
    def test_lossy_roundtrip_property(seed, n, codec_name):
        rng = np.random.RandomState(seed)
        arr = rng.randn(n).astype(np.float32)
        dec, raw, wire = get_codec(codec_name).roundtrip(arr)
        np.testing.assert_allclose(np.asarray(dec), arr, rtol=1e-2, atol=1e-3)


# -- residency manager -------------------------------------------------------------


class TestResidency:
    def test_check_fit_is_the_capacity_oracle(self):
        rm = ResidencyManager(capacity_bytes=1000, num_slots=3)
        assert rm.check_fit(300) == 900
        assert rm.check_fit(200, pinned_bytes=350) == 950
        with pytest.raises(MemoryError):
            rm.check_fit(400)
        with pytest.raises(MemoryError):
            rm.check_fit(300, pinned_bytes=200)

    def test_lru_order_and_eviction_requires_writeback(self):
        rm = ResidencyManager(capacity_bytes=float("inf"), num_slots=2)
        rm.begin_chain()
        a = rm.acquire()
        b = rm.acquire()
        rm.mark_dirty(a, "u", 0, 10)
        # LRU wants to hand slot a back, but its rows were never retired.
        with pytest.raises(ResidencyError):
            rm.acquire()
        rm.writeback(a, "u", 0, 10)
        c = rm.acquire()
        assert c is a  # LRU order: the failed acquire did not perturb it
        d = rm.acquire()
        assert d is b

    def test_dirty_writeback_ordering_with_carry_and_elide(self):
        rm = ResidencyManager(capacity_bytes=float("inf"), num_slots=2)
        rm.begin_chain()
        a = rm.acquire()
        b = rm.acquire()
        rm.mark_dirty(a, "u", 0, 20)
        rm.carry(a, b, "u", 12, 20)     # edge copy moved rows 12..20 onward
        with pytest.raises(ResidencyError):
            rm.acquire()                # rows 0..12 still dirty in a
        rm.writeback(a, "u", 0, 12)
        assert rm.acquire() is a
        # end_chain refuses while b still owes rows 12..20 ...
        with pytest.raises(ResidencyError):
            rm.end_chain()
        rm.begin_chain()                # reset after the failed end
        rm.mark_dirty(rm.acquire(), "tmp", 0, 8)
        with pytest.raises(ResidencyError):
            rm.end_chain()

    def test_elide_balances_the_books(self):
        rm = ResidencyManager(capacity_bytes=float("inf"), num_slots=1)
        rm.begin_chain()
        s = rm.acquire()
        rm.mark_dirty(s, "tmp", 0, 16)
        rm.elide(s, "tmp", 0, 16)       # §4.1: dead temporary, no traffic
        rm.end_chain()
        assert rm.stats["elided_rows"] == 16

    def test_single_slot_pool_allows_carried_rows(self):
        # One continuing slot never evicts: carried dirty rows are fine.
        rm = ResidencyManager(capacity_bytes=float("inf"), num_slots=1)
        rm.begin_chain()
        s = rm.acquire()
        rm.mark_dirty(s, "u", 0, 4)
        s2 = rm.acquire()
        assert s2 is s
        rm.writeback(s, "u", 0, 4)
        rm.end_chain()

    def test_home_write_conflict_tracking(self):
        rm = ResidencyManager(capacity_bytes=float("inf"), num_slots=2)
        rm.begin_chain()
        s = rm.acquire()
        rm.mark_dirty(s, "u", 0, 10)
        rm.writeback(s, "u", 0, 10, handle="H")
        assert rm.home_conflicts("u", 5, 15) == ["H"]
        assert rm.home_conflicts("u", 10, 15) == []
        assert rm.home_conflicts("v", 0, 10) == []


# -- transfer engine ---------------------------------------------------------------


class TestEngine:
    @pytest.mark.parametrize("mode", ["sync", "threaded"])
    def test_tasks_run_and_stats_accumulate(self, mode):
        eng = TransferEngine(mode)
        ups = [eng.submit("up", lambda i=i: (2 * i, i)) for i in range(10)]
        dns = [eng.submit("down", lambda i=i: (i, i)) for i in range(5)]
        eng.drain()
        assert [h.wait() for h in ups] == [(2 * i, i) for i in range(10)]
        st = eng.snapshot()
        assert st["tasks_up"] == 10 and st["tasks_down"] == 5
        assert st["bytes_up_raw"] == 2 * sum(range(10))
        assert st["bytes_up_wire"] == sum(range(10))
        assert st["queue_wait_s"] >= 0.0
        assert all(h.done for h in ups + dns)
        eng.close()

    def test_deps_complete_before_task_runs(self):
        order = []
        eng = TransferEngine("threaded")
        import time as _t

        def slow():
            _t.sleep(0.05)
            order.append("dep")
            return (1, 1)

        dep = eng.submit("down", slow)
        h = eng.submit("up", lambda: (order.append("task"), (1, 1))[1], deps=[dep])
        h.wait()
        assert order == ["dep", "task"]
        eng.close()

    @pytest.mark.parametrize("mode", ["sync", "threaded"])
    def test_errors_propagate(self, mode):
        eng = TransferEngine(mode)

        def boom():
            raise ValueError("staging failed")

        if mode == "sync":
            with pytest.raises(TransferError):
                eng.submit("up", boom)
        else:
            h = eng.submit("up", boom)
            with pytest.raises(TransferError):
                h.wait()
            eng.submit("up", boom)
            with pytest.raises(TransferError):
                eng.drain()
        eng.close()

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            TransferEngine("warp-drive")


# -- end-to-end: executor through the transfer subsystem ---------------------------


def _heat_loops(rt, blk, u, tmp, steps, tag=""):
    n, m = blk.size
    interior = ((1, n - 1), (1, m - 1))
    for s in range(steps):
        rt.par_loop(
            f"avg{tag}{s}", blk, interior, [u, tmp],
            lambda acc: {"tmp": 0.25 * (acc("u", (1, 0)) + acc("u", (-1, 0))
                                        + acc("u", (0, 1)) + acc("u", (0, -1)))})
        rt.par_loop(f"copy{tag}{s}", blk, interior, [tmp, u],
                    lambda acc: {"u": acc("tmp")})


def _heat(rt, n, m, steps, seed=7):
    import jax.numpy as jnp

    from repro.core import Block, ReductionSpec

    rng = np.random.RandomState(seed)
    blk = Block("grid", (n, m))
    u = make_dataset(blk, "u", halo=1, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=1)
    _heat_loops(rt, blk, u, tmp, steps)
    rt.par_loop("sum", blk, ((1, n - 1), (1, m - 1)), [u],
                lambda acc: {"total": jnp.sum(acc("u"))},
                reductions=[ReductionSpec("total", "sum")])
    total = rt.reduction("total")
    return rt.fetch(u), total


class TestExecutorIntegration:
    def test_threaded_equals_sync_bit_identical(self):
        u_sync, t_sync = _heat(Session("ooc", num_tiles=5,
                                       capacity_bytes=float("inf")), 48, 24, 4)
        u_thr, t_thr = _heat(Session("ooc-async", num_tiles=5,
                                     capacity_bytes=float("inf")), 48, 24, 4)
        np.testing.assert_array_equal(u_sync, u_thr)
        np.testing.assert_array_equal(np.asarray(t_sync), np.asarray(t_thr))

    def test_threaded_equals_sync_on_cloverleaf2d(self):
        """The acceptance bar: ooc-async with the identity codec is
        bit-identical to ooc on the CloverLeaf 2D chain."""
        from repro.apps import CloverLeaf2D

        results = {}
        for backend in ("ooc", "ooc-async"):
            app = CloverLeaf2D(36, 20, summary_every=0)
            rt = Session(backend, num_tiles=4, capacity_bytes=float("inf"))
            app.run(rt, steps=2)
            results[backend] = {
                name: rt.fetch(app.d(name))
                for name in ("density0", "energy0", "pressure", "xvel0", "yvel1")
            }
            if backend == "ooc-async":
                assert rt.history and rt.history[0].transfer_mode == "threaded"
        for name, ref in results["ooc"].items():
            np.testing.assert_array_equal(ref, results["ooc-async"][name])

    def test_fp16_codec_compresses_and_stays_close(self):
        u_ref, _ = _heat(Session("ooc", num_tiles=4,
                                 capacity_bytes=float("inf")), 40, 16, 3)
        sess = Session("ooc", num_tiles=4, capacity_bytes=float("inf"),
                       codec="fp16")
        u16, _ = _heat(sess, 40, 16, 3)
        np.testing.assert_allclose(u_ref, u16, rtol=1e-2, atol=1e-3)
        st = sess.transfer_stats()
        assert st["compression_ratio"] == pytest.approx(2.0)
        assert st["bytes_moved_wire"] * 2 == st["bytes_up_raw"] + st["bytes_down_raw"]

    def test_lossless_codec_bit_identical(self):
        u_ref, _ = _heat(Session("ooc", num_tiles=4,
                                 capacity_bytes=float("inf")), 40, 16, 3)
        u_rle, _ = _heat(Session("ooc", num_tiles=4, capacity_bytes=float("inf"),
                                 codec="shuffle-rle"), 40, 16, 3)
        np.testing.assert_array_equal(u_ref, u_rle)

    def test_fp16_reduces_modelled_makespan(self):
        spans = {}
        for codec in ("identity", "fp16"):
            sess = Session("ooc", num_tiles=6, capacity_bytes=float("inf"),
                           codec=codec)
            _heat(sess, 64, 24, 3)
            spans[codec] = sum(c.modelled_s for c in sess.history)
        assert spans["fp16"] < spans["identity"]

    def test_pinned_dataset_correct_and_cached_across_chains(self):
        from repro.core import Block

        def run(sess):
            rng = np.random.RandomState(11)
            blk = Block("grid", (40, 16))
            u = make_dataset(blk, "u", halo=1,
                             init=rng.rand(40, 16).astype(np.float32))
            tmp = make_dataset(blk, "tmp", halo=1)
            _heat_loops(sess, blk, u, tmp, 2, tag="a")
            mid = sess.fetch(u)          # chain break #1
            _heat_loops(sess, blk, u, tmp, 2, tag="b")
            return mid, sess.fetch(u)    # chain break #2, same datasets

        mid_ref, u_ref = run(Session("ooc", num_tiles=4,
                                     capacity_bytes=float("inf")))
        sess = Session("ooc", num_tiles=4, capacity_bytes=float("inf"),
                       pinned=("u",))
        mid_pin, u_pin = run(sess)
        np.testing.assert_array_equal(mid_ref, mid_pin)
        np.testing.assert_array_equal(u_ref, u_pin)
        ex = sess.backend
        # uploaded whole once; the second chain reuses the device copy
        assert ex.residency.stats["pinned_uploads"] == 1
        assert ex.residency.stats["pinned_hits"] >= 1

    def test_pinned_respects_home_mutation(self):
        """A user-space write between chains invalidates the pinned cache."""
        from repro.core import Block

        sess = Session("ooc", num_tiles=3, capacity_bytes=float("inf"),
                       pinned=("u",))
        blk = Block("grid", (24, 10))
        u = make_dataset(blk, "u", halo=1,
                         init=np.ones((24, 10), np.float32))
        tmp = make_dataset(blk, "tmp", halo=1)
        _heat_loops(sess, blk, u, tmp, 1, tag="a")
        sess.fetch(u)
        u.write(((0, 24), (0, 10)), np.full((24, 10), 5.0, np.float32))
        _heat_loops(sess, blk, u, tmp, 1, tag="b")
        got = sess.fetch(u)
        # reference: same sequence, no pinning
        ref_sess = Session("ooc", num_tiles=3, capacity_bytes=float("inf"))
        u2 = make_dataset(blk, "u", halo=1, init=np.ones((24, 10), np.float32))
        tmp2 = make_dataset(blk, "tmp", halo=1)
        _heat_loops(ref_sess, blk, u2, tmp2, 1, tag="a")
        ref_sess.fetch(u2)
        u2.write(((0, 24), (0, 10)), np.full((24, 10), 5.0, np.float32))
        _heat_loops(ref_sess, blk, u2, tmp2, 1, tag="b")
        np.testing.assert_array_equal(ref_sess.fetch(u2), got)
        assert sess.backend.residency.stats["pinned_uploads"] == 2

    def test_prefetch_hit_restores_real_data(self):
        """Speculative prefetch on the REAL data plane: the second of two
        structurally identical chains must hit the capture AND produce the
        same result as without prefetch (regression: the hit used to skip
        the upload while slots start zeroed, silently reading zeros)."""
        from repro.core import Block

        def run(prefetch):
            rng = np.random.RandomState(13)
            blk = Block("grid", (48, 16))
            u = make_dataset(blk, "u", halo=1,
                             init=rng.rand(48, 16).astype(np.float32))
            tmp = make_dataset(blk, "tmp", halo=1)
            sess = Session("ooc", num_tiles=4, capacity_bytes=float("inf"),
                           prefetch=prefetch)
            outs = []
            for _ in range(3):  # identical chain shape every flush
                _heat_loops(sess, blk, u, tmp, 2)
                outs.append(sess.fetch(u))
            return outs, sess

        ref, _ = run(False)
        got, sess = run(True)
        for r, g in zip(ref, got):
            np.testing.assert_array_equal(r, g)
        assert sum(c.prefetch_hits for c in sess.history) > 0

    def test_ledger_totals_consistent_with_codec(self, monkeypatch):
        """TransferLedger.summary() byte totals must reflect post-codec wire
        bytes, matching the patched events and the modelled makespan."""
        import repro.core.interp as interpmod

        captured = []

        class CapturingLedger(interpmod.TransferLedger):
            def __init__(self, hw):
                super().__init__(hw)
                captured.append(self)

        monkeypatch.setattr(interpmod, "TransferLedger", CapturingLedger)
        sess = Session("ooc", num_tiles=4, capacity_bytes=float("inf"),
                       codec="fp16")
        _heat(sess, 40, 16, 3)
        st = sess.transfer_stats()
        assert st["compression_ratio"] == pytest.approx(2.0)
        assert captured
        led = captured[0]
        s = led.summary()
        chain = sess.history[0]
        assert s["bytes_upload"] == chain.uploaded_wire
        assert s["bytes_download"] == chain.downloaded_wire
        # events agree with the totals (the patch shifts both)
        assert sum(ev.nbytes for ev in led.events if ev.kind == "upload") \
            == chain.uploaded_wire

    def test_single_slot_multi_tile_executes_correctly(self):
        """Regression: a 1-slot pool with many tiles (degenerate but legal)
        must rebase the continuing slot via the edge copy, not crash on the
        not-yet-acquired next slot."""
        ref_u, ref_t = _heat(Session("reference"), 40, 16, 3)
        sess = Session("ooc", num_slots=1, num_tiles=4,
                       capacity_bytes=float("inf"))
        got_u, got_t = _heat(sess, 40, 16, 3)
        np.testing.assert_allclose(ref_u, got_u, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ref_t), np.asarray(got_t),
                                   rtol=1e-4)
        assert sess.history[0].num_tiles == 4

    def test_session_close_stops_worker_threads(self):
        import threading

        before = {th.name for th in threading.enumerate()}
        sess = Session("ooc-async", num_tiles=4, capacity_bytes=float("inf"))
        _heat(sess, 32, 12, 2)
        assert any(th.name.startswith("transfer-")
                   for th in threading.enumerate())
        sess.close()
        leftover = {th.name for th in threading.enumerate()} - before
        assert not any(n.startswith("transfer-") for n in leftover)

    def test_threaded_queue_wait_reported(self):
        sess = Session("ooc-async", num_tiles=6, capacity_bytes=float("inf"))
        _heat(sess, 64, 24, 4)
        st = sess.transfer_stats()
        assert st["mode"] == "threaded"
        assert st["queue_wait_s"] >= 0.0
        assert st["bytes_up_raw"] > 0 and st["bytes_down_raw"] > 0
