"""Multi-device behaviour (subprocess with 8 forced host devices, so the
main pytest process keeps its single real device): halo exchange vs periodic
reference, and the int8 compressed all-reduce vs exact mean."""
import subprocess
import sys
import textwrap

import pytest

_SCRIPT_HALO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.compat import make_mesh, shard_map
    from repro.core.distributed import exchange_halos, chain_halo_depth

    mesh = make_mesh((8,), ("x",))
    N, M, halo = 16, 64, 2
    per = M // 8
    rng = np.random.RandomState(0)
    g = rng.rand(N, M).astype(np.float32)
    ref = g.copy()
    for _ in range(2):
        ref = 0.5 * ref + 0.25 * (np.roll(ref, 1, 1) + np.roll(ref, -1, 1))
    locs = []
    for r in range(8):
        lo = (r * per - halo) % M
        idx = [(lo + i) % M for i in range(per + 2 * halo)]
        locs.append(g[:, idx])
    garr = jax.device_put(np.concatenate(locs, 1), NamedSharding(mesh, P(None, "x")))

    def local(arrays):
        arrays = exchange_halos(arrays, halo, "x", dim=1)
        u = arrays["u"]
        for _ in range(2):
            u = 0.5 * u + 0.25 * (jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
        return {"u": u}

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=P(None, "x"),
                           out_specs=P(None, "x"), check_vma=False))
    res = np.asarray(fn({"u": garr})["u"])
    outs = [res[:, r * (per + 2 * halo) + halo: r * (per + 2 * halo) + halo + per]
            for r in range(8)]
    got = np.concatenate(outs, 1)
    assert np.allclose(got, ref, atol=1e-6), np.abs(got - ref).max()
    assert chain_halo_depth([], dim=1) == 0
    print("HALO_OK")
""")

_SCRIPT_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.compat import make_mesh, shard_map
    from repro.distributed.compression import compressed_allreduce_mean

    mesh = make_mesh((8,), ("pod",))
    rng = np.random.RandomState(1)
    per_dev = rng.randn(8, 1000).astype(np.float32)
    x = jax.device_put(per_dev, NamedSharding(mesh, P("pod", None)))

    fn = jax.jit(shard_map(
        lambda g: compressed_allreduce_mean(g[0], "pod")[None],
        mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None),
        check_vma=False))
    out = np.asarray(fn(x))
    exact = per_dev.mean(axis=0)
    for r in range(8):
        rel = np.abs(out[r] - exact).max() / (np.abs(exact).max() + 1e-9)
        assert rel < 0.05, rel
    # all shards agree (it IS an all-reduce)
    assert np.allclose(out, out[0][None], atol=1e-6)
    print("COMPRESS_OK")
""")


def test_depth0_exchange_skips_collective():
    """A 0-depth chain (no reads along the decomposed dim) must skip the
    halo collective entirely.  Regression: the fast path needs no axis
    context, so calling it OUTSIDE shard_map must work — the old code
    always issued ``axis_index``/``ppermute`` and would raise here."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Block, make_dataset, point_stencil, Arg, RW
    from repro.core.distributed import chain_halo_depth, exchange_halos
    from repro.core.loop import ParallelLoop

    arrays = {"u": jnp.arange(12.0).reshape(3, 4),
              "v": jnp.ones((3, 4))}
    out = exchange_halos(arrays, 0, "nonexistent-axis", dim=1)
    assert set(out) == {"u", "v"}
    for k in arrays:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(arrays[k]))

    # A pointwise chain really does have accumulated halo depth 0.
    blk = Block("g", (8, 8))
    a = make_dataset(blk, "a", halo=1)
    Z = point_stencil(2)
    loops = [
        ParallelLoop("scale", blk, blk.full_range(), (Arg(a, Z, RW),),
                     lambda acc: {"a": acc("a") * 2.0}),
        ParallelLoop("damp", blk, blk.full_range(), (Arg(a, Z, RW),),
                     lambda acc: {"a": acc("a") * 0.5}),
    ]
    assert chain_halo_depth(loops, dim=1) == 0


@pytest.mark.parametrize("script,token", [
    (_SCRIPT_HALO, "HALO_OK"),
    (_SCRIPT_COMPRESS, "COMPRESS_OK"),
])
def test_multidevice_subprocess(script, token):
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300,
                       # JAX_PLATFORMS=cpu: the forced host-device count only
                       # exists on the CPU platform, and without it JAX may
                       # stall probing for accelerators (TPU metadata fetch).
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert token in r.stdout
