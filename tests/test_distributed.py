"""Multi-device behaviour (subprocess with 8 forced host devices, so the
main pytest process keeps its single real device): halo exchange vs periodic
reference, and the int8 compressed all-reduce vs exact mean."""
import subprocess
import sys
import textwrap

import pytest

_SCRIPT_HALO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.compat import make_mesh, shard_map
    from repro.core.distributed import exchange_halos, chain_halo_depth

    mesh = make_mesh((8,), ("x",))
    N, M, halo = 16, 64, 2
    per = M // 8
    rng = np.random.RandomState(0)
    g = rng.rand(N, M).astype(np.float32)
    ref = g.copy()
    for _ in range(2):
        ref = 0.5 * ref + 0.25 * (np.roll(ref, 1, 1) + np.roll(ref, -1, 1))
    locs = []
    for r in range(8):
        lo = (r * per - halo) % M
        idx = [(lo + i) % M for i in range(per + 2 * halo)]
        locs.append(g[:, idx])
    garr = jax.device_put(np.concatenate(locs, 1), NamedSharding(mesh, P(None, "x")))

    def local(arrays):
        # np.roll reference == periodic boundaries: ask for the wrap.
        arrays = exchange_halos(arrays, halo, "x", dim=1, periodic=True)
        u = arrays["u"]
        for _ in range(2):
            u = 0.5 * u + 0.25 * (jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))
        return {"u": u}

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=P(None, "x"),
                           out_specs=P(None, "x"), check_vma=False))
    res = np.asarray(fn({"u": garr})["u"])
    outs = [res[:, r * (per + 2 * halo) + halo: r * (per + 2 * halo) + halo + per]
            for r in range(8)]
    got = np.concatenate(outs, 1)
    assert np.allclose(got, ref, atol=1e-6), np.abs(got - ref).max()
    assert chain_halo_depth([], dim=1) == 0
    print("HALO_OK")
""")

_SCRIPT_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.compat import make_mesh, shard_map
    from repro.distributed.compression import compressed_allreduce_mean

    mesh = make_mesh((8,), ("pod",))
    rng = np.random.RandomState(1)
    per_dev = rng.randn(8, 1000).astype(np.float32)
    x = jax.device_put(per_dev, NamedSharding(mesh, P("pod", None)))

    fn = jax.jit(shard_map(
        lambda g: compressed_allreduce_mean(g[0], "pod")[None],
        mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None),
        check_vma=False))
    out = np.asarray(fn(x))
    exact = per_dev.mean(axis=0)
    for r in range(8):
        rel = np.abs(out[r] - exact).max() / (np.abs(exact).max() + 1e-9)
        assert rel < 0.05, rel
    # all shards agree (it IS an all-reduce)
    assert np.allclose(out, out[0][None], atol=1e-6)
    print("COMPRESS_OK")
""")


def test_depth0_exchange_skips_collective():
    """A 0-depth chain (no reads along the decomposed dim) must skip the
    halo collective entirely.  Regression: the fast path needs no axis
    context, so calling it OUTSIDE shard_map must work — the old code
    always issued ``axis_index``/``ppermute`` and would raise here."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Block, make_dataset, point_stencil, Arg, RW
    from repro.core.distributed import chain_halo_depth, exchange_halos
    from repro.core.loop import ParallelLoop

    arrays = {"u": jnp.arange(12.0).reshape(3, 4),
              "v": jnp.ones((3, 4))}
    out = exchange_halos(arrays, 0, "nonexistent-axis", dim=1)
    assert set(out) == {"u", "v"}
    for k in arrays:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(arrays[k]))

    # A pointwise chain really does have accumulated halo depth 0.
    blk = Block("g", (8, 8))
    a = make_dataset(blk, "a", halo=1)
    Z = point_stencil(2)
    loops = [
        ParallelLoop("scale", blk, blk.full_range(), (Arg(a, Z, RW),),
                     lambda acc: {"a": acc("a") * 2.0}),
        ParallelLoop("damp", blk, blk.full_range(), (Arg(a, Z, RW),),
                     lambda acc: {"a": acc("a") * 0.5}),
    ]
    assert chain_halo_depth(loops, dim=1) == 0


def _make_mesh(n):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} XLA devices (conftest forces 8)")
    return Mesh(np.asarray(jax.devices()[:n]), ("x",))


def test_exchange_halos_nonperiodic_keeps_edge_halos():
    """Regression (2-device mesh): with the default non-periodic semantics
    the edge ranks must NOT receive wrapped-around data — their outer halo
    slots keep the caller's boundary values, while the interior boundary
    still exchanges.  The old periodic-ring behaviour handed rank 0 the
    opposite edge's interior."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.distributed import exchange_halos

    mesh = _make_mesh(2)
    depth, per, nrows = 2, 6, 4
    w = per + 2 * depth
    rng = np.random.RandomState(3)
    local = rng.rand(2, nrows, w).astype(np.float32)  # [rank, rows, cols]
    stacked = np.concatenate([local[0], local[1]], axis=1)
    garr = jax.device_put(stacked, NamedSharding(mesh, P(None, "x")))

    def run(periodic):
        fn = jax.jit(shard_map(
            lambda a: exchange_halos({"u": a}, depth, "x", dim=1,
                                     periodic=periodic)["u"],
            mesh=mesh, in_specs=P(None, "x"), out_specs=P(None, "x"),
            check_vma=False))
        out = np.asarray(fn(garr))
        return out[:, :w], out[:, w:]

    r0, r1 = run(False)
    # Edge halos untouched; interiors untouched.
    np.testing.assert_array_equal(r0[:, :depth], local[0][:, :depth])
    np.testing.assert_array_equal(r1[:, -depth:], local[1][:, -depth:])
    np.testing.assert_array_equal(r0[:, depth:-depth],
                                  local[0][:, depth:-depth])
    # Interior boundary exchanged: r0's high halo = r1's low interior etc.
    np.testing.assert_array_equal(r0[:, -depth:],
                                  local[1][:, depth:2 * depth])
    np.testing.assert_array_equal(r1[:, :depth],
                                  local[0][:, -2 * depth:-depth])
    # periodic=True restores the wrap for grids that want it.
    p0, p1 = run(True)
    np.testing.assert_array_equal(p0[:, :depth],
                                  local[1][:, -2 * depth:-depth])
    np.testing.assert_array_equal(p1[:, -depth:],
                                  local[0][:, depth:2 * depth])


class TestShardedChainStep:
    """make_sharded_chain_step: correctness vs the reference runtime and the
    §5.2 per-chain vs per-loop message accounting (previously untested)."""

    N, M, DEPTH = 8, 32, 2  # two loops x stencil extent 1 -> chain depth 2

    def _loops(self):
        """A 2-loop ping-pong smoothing chain on the repro.core DSL."""
        import numpy as np

        from repro.core import Arg, Block, READ, WRITE, make_dataset
        from repro.core import point_stencil, star_stencil
        from repro.core.loop import ParallelLoop

        blk = Block("g", (self.N, self.M))
        rng = np.random.RandomState(7)
        u0 = rng.rand(self.N, self.M).astype(np.float32)
        u = make_dataset(blk, "u", halo=self.DEPTH, init=u0)
        v = make_dataset(blk, "v", halo=self.DEPTH)
        S = star_stencil(2, 1)
        Z = point_stencil(2)

        def k_uv(acc):
            return {"v": 0.5 * acc("u") + 0.25 * (acc("u", (0, -1))
                                                  + acc("u", (0, 1)))}

        def k_vu(acc):
            return {"u": 0.5 * acc("v") + 0.25 * (acc("v", (0, -1))
                                                  + acc("v", (0, 1)))}

        rng_box = ((0, self.N), (0, self.M))
        loops = [
            ParallelLoop("uv", blk, rng_box,
                         (Arg(u, S, READ), Arg(v, Z, WRITE)), k_uv),
            ParallelLoop("vu", blk, rng_box,
                         (Arg(v, S, READ), Arg(u, Z, WRITE)), k_vu),
        ]
        return u0, u, v, loops

    def _sharded_step(self, n_ranks, per_loop):
        import jax.numpy as jnp
        from jax import lax

        from repro.core.distributed import make_sharded_chain_step

        mesh = _make_mesh(n_ranks)
        per = self.M // n_ranks
        D = self.DEPTH
        W = per + 2 * D

        def smooth(arr):
            return (0.5 * arr + 0.25 * (jnp.roll(arr, 1, 1)
                                        + jnp.roll(arr, -1, 1)))

        def masked(write_to, read_from):
            def fn(arrays):
                rank = lax.axis_index("x")
                cols = rank * per + jnp.arange(W) - D
                mask = ((cols >= 0) & (cols < self.M))[None, :]
                out = dict(arrays)
                out[write_to] = jnp.where(mask, smooth(arrays[read_from]),
                                          arrays[write_to])
                return out
            return fn

        loop_fns = [masked("v", "u"), masked("u", "v")]

        def chain(arrays):
            for fn in loop_fns:
                arrays = fn(arrays)
            return arrays

        # per_loop_depth must equal the buffers' halo padding: exchange_halos
        # indexes send/recv regions by depth, so a shallower exchange on a
        # deeper-padded buffer would move the wrong columns.
        return make_sharded_chain_step(
            chain, mesh, "x", depth=D, per_loop=per_loop,
            loop_fns=loop_fns, per_loop_depth=D, dim=1), per

    @pytest.mark.parametrize("n_ranks", [2, 8])
    @pytest.mark.parametrize("per_loop", [False, True])
    def test_matches_reference_runtime(self, n_ranks, per_loop):
        import jax
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.reference import run_chain_reference

        u0, u, v, loops = self._loops()
        run_chain_reference(loops)
        expect = u.interior().copy()

        step, per = self._sharded_step(n_ranks, per_loop)
        D = self.DEPTH
        padded = np.zeros((self.N, self.M + 2 * D), np.float32)
        padded[:, D:-D] = u0
        locs = [padded[:, r * per: r * per + per + 2 * D]
                for r in range(n_ranks)]
        mesh = _make_mesh(n_ranks)
        garr = jax.device_put(np.concatenate(locs, 1),
                              NamedSharding(mesh, P(None, "x")))
        zeros = jax.device_put(np.zeros_like(np.concatenate(locs, 1)),
                               NamedSharding(mesh, P(None, "x")))
        res = np.asarray(step({"u": garr, "v": zeros})["u"])
        W = per + 2 * D
        got = np.concatenate(
            [res[:, r * W + D: r * W + D + per] for r in range(n_ranks)], 1)
        np.testing.assert_allclose(got, expect, rtol=1e-6, atol=1e-6)

    def test_message_count_accounting(self):
        """§5.2 policy trade-off, in numbers: the tiled policy's one deep
        exchange vs the untiled policy's per-loop shallow exchanges."""
        from repro.core.distributed import chain_message_count

        tiled, per = self._sharded_step(2, per_loop=False)
        untiled, _ = self._sharded_step(2, per_loop=True)
        assert tiled.exchanges == 1
        assert untiled.exchanges == 2
        assert tiled.messages_per_array == chain_message_count(2, 1) == 2
        assert untiled.messages_per_array == chain_message_count(
            2, 1, n_loops=2, per_loop=True) == 4
        assert untiled.messages_per_array > tiled.messages_per_array
        # periodic rings close the loop: 2 extra wrap messages per exchange
        assert chain_message_count(8, 3, periodic=True) == 48
        assert chain_message_count(8, 3) == 42


@pytest.mark.parametrize("script,token", [
    (_SCRIPT_HALO, "HALO_OK"),
    (_SCRIPT_COMPRESS, "COMPRESS_OK"),
])
def test_multidevice_subprocess(script, token):
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300,
                       # JAX_PLATFORMS=cpu: the forced host-device count only
                       # exists on the CPU platform, and without it JAX may
                       # stall probing for accelerators (TPU metadata fetch).
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert token in r.stdout
