"""repro.core.store: backing-store round-trips, chunk-cache eviction, mmap
persistence, the disk-tier plan ops, and Session checkpoint/restore."""
import os

import numpy as np
import pytest

try:  # optional test extra: example-based tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (
    Block,
    P100_PCIE,
    Session,
    StoreConfig,
    StoreError,
    make_dataset,
)
from repro.core.dataset import Dataset
from repro.core.store import ChunkedStore, MmapStore, available_stores


# -- store round-trips -------------------------------------------------------------


def _specs(tmp_path):
    return [
        None,
        "ram",
        StoreConfig(kind="mmap", directory=str(tmp_path / "mm")),
        StoreConfig(kind="chunked", directory=str(tmp_path / "ch"),
                    chunk_bytes=256, cache_bytes=1 << 10),
        StoreConfig(kind="chunked", directory=str(tmp_path / "ch-id"),
                    chunk_bytes=512, cache_bytes=1 << 20, codec="identity"),
    ]


class TestRoundTrip:
    def test_registry_has_all_three(self):
        assert set(available_stores()) >= {"ram", "mmap", "chunked"}

    def test_box_roundtrip_every_kind(self, tmp_path, rng):
        blk = Block("b", (13, 9))
        ref = rng.rand(13 + 2, 9 + 2).astype(np.float32)
        boxes = [((0, 13), (0, 9)), ((-1, 3), (2, 9)), ((5, 14), (-1, 4)),
                 ((0, 1), (0, 1))]
        for spec in _specs(tmp_path):
            dat = make_dataset(blk, "d", halo=1, init=ref, store=spec)
            assert np.array_equal(dat.materialize(), ref)
            for box in boxes:
                idx = tuple(slice(a + 1, b + 1) for a, b in box)
                assert np.array_equal(dat.read(box), ref[idx]), (spec, box)
            patch = rng.rand(4, 5).astype(np.float32)
            dat.write(((2, 6), (1, 6)), patch)
            ref2 = ref.copy()
            ref2[3:7, 2:7] = patch
            assert np.array_equal(dat.materialize(), ref2), spec

    def test_row_slab_api_matches_ram(self, tmp_path, rng):
        blk = Block("b", (12, 7))
        init = rng.rand(16, 11).astype(np.float32)
        for spec in _specs(tmp_path)[2:]:
            ram = make_dataset(blk, "d", halo=2, init=init)
            other = make_dataset(blk, "d", halo=2, init=init, store=spec)
            for lo, hi in ((-2, 3), (0, 12), (7, 14)):
                assert np.array_equal(other.read_rows(0, lo, hi),
                                      ram.read_rows(0, lo, hi))
            vals = rng.rand(*np.shape(ram.read_rows(0, 1, 5))).astype(np.float32)
            ram.write_rows(0, 1, 5, vals)
            other.write_rows(0, 1, 5, vals)
            assert np.array_equal(other.materialize(), ram.materialize())

    def test_chunked_data_property_raises(self, tmp_path):
        dat = make_dataset(Block("b", (8, 8)), "d",
                           store=StoreConfig(kind="chunked",
                                             directory=str(tmp_path)))
        with pytest.raises(StoreError):
            dat.data
        # store-agnostic access still works
        assert dat.materialize().shape == dat.padded_shape

    def test_from_store_validates_shape(self, tmp_path):
        st_ = ChunkedStore(str(tmp_path / "c"), (10, 10), np.float32)
        with pytest.raises(StoreError):
            Dataset.from_store(Block("b", (4, 4)), "d", st_, halo=1)
        dat = Dataset.from_store(Block("b", (8, 8)), "d", st_, halo=1)
        assert dat.store is st_

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(
            rows=st.integers(3, 24),
            cols=st.integers(1, 9),
            chunk_bytes=st.integers(16, 512),
            cache_bytes=st.integers(64, 2048),
            ops=st.lists(
                st.tuples(st.booleans(), st.integers(0, 23), st.integers(1, 9),
                          st.integers(0, 10 ** 6)),
                min_size=1, max_size=12),
        )
        def test_chunked_matches_ram_oracle(self, tmp_path_factory, rows, cols,
                                            chunk_bytes, cache_bytes, ops):
            """Random interleaved reads/writes against a plain array oracle."""
            tmp = tmp_path_factory.mktemp("chunk-prop")
            oracle = np.zeros((rows, cols), np.float32)
            store = ChunkedStore(str(tmp), (rows, cols), np.float32,
                                 chunk_bytes=chunk_bytes,
                                 cache_bytes=cache_bytes)
            for is_write, lo, ln, seed in ops:
                lo = lo % rows
                hi = min(rows, lo + ln)
                if hi <= lo:
                    continue
                idx = (slice(lo, hi), slice(0, cols))
                if is_write:
                    vals = np.random.RandomState(seed).rand(
                        hi - lo, cols).astype(np.float32)
                    oracle[idx] = vals
                    store.write(idx, vals)
                else:
                    assert np.array_equal(store.read(idx), oracle[idx])
            assert np.array_equal(store.materialize(), oracle)


# -- chunk cache -------------------------------------------------------------------


class TestChunkCache:
    def _store(self, tmp_path, nchunks=6, rows_per_chunk=2, cols=8,
               cache_chunks=2):
        chunk_nb = rows_per_chunk * cols * 4
        return ChunkedStore(
            str(tmp_path), (nchunks * rows_per_chunk, cols), np.float32,
            chunk_bytes=chunk_nb, cache_bytes=cache_chunks * chunk_nb)

    def test_eviction_is_lru_ordered(self, tmp_path):
        store = self._store(tmp_path)
        assert store.num_chunks == 6
        row = lambda c: (slice(c * 2, c * 2 + 1), slice(None))
        store.read(row(0))
        store.read(row(1))
        assert store.cache_keys() == (0, 1)
        store.read(row(0))              # 0 becomes MRU
        assert store.cache_keys() == (1, 0)
        store.read(row(2))              # budget 2: LRU chunk 1 evicted
        assert store.cache_keys() == (0, 2)
        assert store.stats["chunk_evictions"] == 1
        # clean eviction writes nothing
        assert store.stats["disk_bytes_written"] == 0

    def test_dirty_eviction_compresses_out_and_reloads(self, tmp_path, rng):
        store = self._store(tmp_path)
        vals = rng.rand(2, 8).astype(np.float32)
        store.write((slice(0, 2), slice(0, 8)), vals)      # chunk 0 dirty
        store.read((slice(2, 4), slice(None)))
        store.read((slice(4, 6), slice(None)))             # evicts dirty 0
        assert store.stats["disk_bytes_written"] > 0
        assert os.path.exists(os.path.join(str(tmp_path), "chunk_000000.npz"))
        got = store.read((slice(0, 2), slice(0, 8)))       # reload from disk
        assert np.array_equal(got, vals)
        assert store.stats["disk_bytes_read"] > 0

    def test_budget_bounds_resident_bytes(self, tmp_path, rng):
        store = self._store(tmp_path, cache_chunks=3)
        for c in range(6):
            store.write((slice(c * 2, c * 2 + 2), slice(None)),
                        rng.rand(2, 8).astype(np.float32))
        assert store.cache_resident_bytes() <= store.cache_bytes
        assert len(store.cache_keys()) == 3
        # flush persists the stragglers; full contents still correct
        store.flush()
        assert store.materialize().shape == (12, 8)

    def test_reopen_with_different_geometry_rejected(self, tmp_path, rng):
        store = self._store(tmp_path)
        store.write((slice(0, 4), slice(None)),
                    rng.rand(4, 8).astype(np.float32))
        store.flush()
        # same directory, different chunk_bytes -> chunk shapes disagree
        bad = ChunkedStore(str(tmp_path), (12, 8), np.float32,
                           chunk_bytes=4 * 8 * 4, cache_bytes=1 << 16)
        with pytest.raises(StoreError):
            bad.read((slice(0, 4), slice(None)))

    def test_spill_evicts_fully_covered_chunks_only(self, tmp_path, rng):
        store = self._store(tmp_path, cache_chunks=6)
        store.write((slice(0, 5), slice(None)),
                    rng.rand(5, 8).astype(np.float32))   # chunks 0,1,2 dirty
        written = store.spill((slice(0, 4), slice(None)))
        assert written > 0
        keys = store.cache_keys()
        assert 0 not in keys and 1 not in keys   # fully covered: dropped
        assert 2 in keys                         # partially covered: kept
        # nothing lost
        assert store.read((slice(0, 5), slice(None))).shape == (5, 8)


# -- mmap persistence --------------------------------------------------------------


class TestMmapPersistence:
    def test_reopen_sees_written_data(self, tmp_path, rng):
        path = str(tmp_path / "d.mmap")
        vals = rng.rand(10, 6).astype(np.float32)
        store = MmapStore(path, (10, 6), np.float32, mode="w+")
        store.write((slice(None), slice(None)), vals)
        store.close()
        again = MmapStore.open(path, (10, 6), np.float32)
        assert np.array_equal(again.materialize(), vals)

    def test_dataset_home_survives_reopen(self, tmp_path, rng):
        blk = Block("b", (6, 6))
        cfg = StoreConfig(kind="mmap", directory=str(tmp_path))
        init = rng.rand(8, 8).astype(np.float32)
        dat = make_dataset(blk, "field", halo=1, init=init, store=cfg)
        dat.flush_store()
        reopened = Dataset.from_store(
            blk, "field",
            MmapStore.open(str(tmp_path / "field.mmap"), (8, 8), np.float32),
            halo=1)
        assert np.array_equal(reopened.materialize(), init)

    def test_reopen_size_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "d.mmap")
        MmapStore(path, (4, 4), np.float32, mode="w+").close()
        with pytest.raises(StoreError):
            MmapStore.open(path, (5, 4), np.float32)


# -- dataset satellite: empty writes -----------------------------------------------


class TestVersionSemantics:
    def test_empty_write_does_not_bump_version(self):
        dat = make_dataset(Block("b", (6, 6)), "d", halo=1)
        v = dat.version
        dat.write(((3, 3), (0, 6)), np.empty((0, 6), np.float32))
        dat.write(((0, 6), (4, 4)), np.empty((6, 0), np.float32))
        assert dat.version == v      # no-op writes must not invalidate caches
        dat.write(((0, 1), (0, 6)), np.ones((1, 6), np.float32))
        assert dat.version == v + 1


# -- the disk tier through the executor --------------------------------------------


def _mini_app(store=None, nx=20, ny=14):
    from repro.apps import CloverLeaf2D

    return CloverLeaf2D(nx, ny, summary_every=0, store=store)


def _chunked_cfg(tmp_path, tag, cache_bytes=48 << 10):
    return StoreConfig(kind="chunked", directory=str(tmp_path / tag),
                       chunk_bytes=4 << 10, cache_bytes=cache_bytes)


def _oversubscribed_hw(app, frac=0.3):
    return P100_PCIE.with_(host_capacity=app.total_bytes() * frac)


class TestDiskTier:
    def test_chunked_bit_identical_to_ram_when_host_oversubscribed(
            self, tmp_path):
        """The acceptance criterion: a problem larger than the host budget
        completes from a chunked store, bit-identical to the ram-store run,
        with FetchHome/SpillHome in the plan and nonzero disk bytes."""
        ram_app = _mini_app()
        s_ram = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        ram_app.run(s_ram, steps=2)

        ch_app = _mini_app(store=_chunked_cfg(tmp_path, "ch"))
        hw = _oversubscribed_hw(ch_app)
        s_ch = Session("ooc", hw=hw, num_tiles=2, capacity_bytes=float("inf"))
        ch_app.run(s_ch, steps=2)

        for name, dat in ram_app.dats.items():
            assert np.array_equal(s_ram.fetch_raw(dat),
                                  s_ch.fetch_raw(ch_app.dats[name])), name
        st = s_ch.transfer_stats()
        assert st["bytes_disk_written"] > 0
        assert sum(c.op_counts["home_fetches"] for c in s_ch.history) > 0
        assert sum(c.op_counts["home_spills"] for c in s_ch.history) > 0
        ch_app.record_timestep(s_ch)
        text = s_ch.explain()
        assert "fetch-home" in text and "spill-home" in text
        s_ch.queue.clear()

    def test_threaded_matches_sync_with_disk_tier(self, tmp_path):
        outs = {}
        for mode in ("sync", "threaded"):
            app = _mini_app(store=_chunked_cfg(tmp_path, mode,
                                               cache_bytes=16 << 10))
            s = Session("ooc", hw=_oversubscribed_hw(app), num_tiles=2,
                        capacity_bytes=float("inf"), transfer=mode)
            app.run(s, steps=2)
            outs[mode] = {n: s.fetch_raw(d) for n, d in app.dats.items()}
            s.close()
        for name in outs["sync"]:
            assert np.array_equal(outs["sync"][name],
                                  outs["threaded"][name]), name

    def test_sim_mode_costs_disk_traffic(self):
        app = _mini_app()
        hw = _oversubscribed_hw(app)
        s = Session("sim", hw=hw, num_tiles=2, capacity_bytes=float("inf"))
        app.record_init(s)
        s.flush()
        chain = s.history[-1]
        # the init chain writes everything: spills only, no fetches
        assert chain.disk_written > 0
        assert chain.op_counts["home_spills"] > 0
        app.record_timestep(s)
        s.flush()
        chain = s.history[-1]
        assert chain.disk_read > 0
        assert chain.op_counts["home_fetches"] > 0
        app.record_timestep(s)
        plans = s.plan()
        tot = plans[-1].totals()
        assert tot["disk_read"] > 0 and tot["disk_written"] > 0
        s.queue.clear()
        # host_capacity=inf (default) plans no disk ops for the same chain
        s2 = Session("sim", num_tiles=2, capacity_bytes=float("inf"))
        app.record_timestep(s2)
        assert all(p.counts()["home_fetches"] == 0 for p in s2.plan())
        s2.queue.clear()

    def test_host_capacity_override_wins_over_hw(self):
        app = _mini_app()
        s = Session("sim", num_tiles=2, capacity_bytes=float("inf"),
                    host_capacity=app.total_bytes() * 0.5)
        app.record_init(s)
        assert any(p.spill_home for p in s.plan())
        s.queue.clear()


# -- checkpoint / restore ----------------------------------------------------------


class TestCheckpointRestore:
    def _continue(self, app, sess, steps=1):
        for _ in range(steps):
            app.record_timestep(sess)
        sess.flush()
        return {n: sess.fetch_raw(d) for n, d in app.dats.items()}

    @pytest.mark.parametrize("store_kind", ["ram", "chunked"])
    def test_resume_is_bit_identical_on_cloverleaf2d(self, tmp_path,
                                                     store_kind):
        store = (None if store_kind == "ram"
                 else _chunked_cfg(tmp_path, "ckpt-src"))
        app = _mini_app(store=store)
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        app.run(sess, steps=1)
        ckpt = str(tmp_path / "state.npz")
        manifest = sess.checkpoint(ckpt)
        # covers every dataset a recorded loop touched (post_ener never is)
        touched = set(manifest["datasets"])
        assert touched <= set(app.dats) and "density0" in touched
        dt, step_count = app.dt, app.step_count    # app-level scalars

        final_a = self._continue(app, sess, steps=1)

        # "kill": a fresh app + session, as a restarted process would build
        app2 = _mini_app(store=(None if store_kind == "ram"
                                else _chunked_cfg(tmp_path, "ckpt-dst")))
        sess2 = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        sess2.restore(ckpt, datasets=app2.dats.values())
        app2.dt, app2.step_count = dt, step_count
        sess2.cyclic = True                        # app.run sets it post-init
        final_b = self._continue(app2, sess2, steps=1)

        for name in final_a:
            assert np.array_equal(final_a[name], final_b[name]), name
        for name, dat in app.dats.items():
            assert dat.version == app2.dats[name].version

    def test_checkpoint_is_atomic_write_then_rename(self, tmp_path):
        app = _mini_app()
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.flush()
        ckpt = tmp_path / "state.npz"
        sess.checkpoint(str(ckpt))
        assert ckpt.exists()
        leftovers = [p for p in os.listdir(tmp_path) if ".tmp." in p]
        assert not leftovers

    def test_restore_into_wrong_shape_rejected(self, tmp_path):
        app = _mini_app()
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.flush()
        ckpt = str(tmp_path / "state.npz")
        sess.checkpoint(ckpt)
        other = _mini_app(nx=24, ny=18)
        s2 = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        with pytest.raises(ValueError):
            s2.restore(ckpt, datasets=other.dats.values())

    def test_restore_missing_dataset_rejected(self, tmp_path):
        app = _mini_app()
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.flush()
        ckpt = str(tmp_path / "state.npz")
        sess.checkpoint(ckpt)
        s2 = Session("ooc")
        with pytest.raises(KeyError):
            s2.restore(ckpt, datasets=[list(app.dats.values())[0]])
