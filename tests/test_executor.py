"""Integration + property tests: out-of-core executor == reference oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra: example-based tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (
    Arg, Block, INC, OOCConfig, OutOfCoreExecutor, ParallelLoop, READ,
    ReductionSpec, ReferenceRuntime, ResidentExecutor, RW, Runtime, WRITE,
    make_dataset, offset_stencil, point_stencil, star_stencil,
)


def heat_app(runtime, n, m, steps, halo=1):
    rng = np.random.RandomState(7)
    blk = Block("grid", (n, m))
    u = make_dataset(blk, "u", halo=halo, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=halo)
    S = star_stencil(2, 1)
    Z = point_stencil(2)
    interior = ((1, n - 1), (1, m - 1))
    for s in range(steps):
        runtime.par_loop(
            f"avg{s}", blk, interior, [Arg(u, S, READ), Arg(tmp, Z, WRITE)],
            lambda acc: {"tmp": 0.25 * (acc("u", (1, 0)) + acc("u", (-1, 0))
                                         + acc("u", (0, 1)) + acc("u", (0, -1)))})
        runtime.par_loop(
            f"copy{s}", blk, interior, [Arg(tmp, Z, READ), Arg(u, Z, RW)],
            lambda acc: {"u": acc("tmp")})
    runtime.par_loop(
        "sum", blk, interior, [Arg(u, Z, READ)],
        lambda acc: {"total": jnp.sum(acc("u"))},
        reductions=[ReductionSpec("total", "sum")])
    total = runtime.reduction("total")
    return runtime.fetch(u), total


class TestEquivalence:
    @pytest.mark.parametrize("tiles,cyclic,prefetch", [
        (1, False, False), (3, False, False), (5, True, True), (7, True, False),
    ])
    def test_heat_matches_reference(self, tiles, cyclic, prefetch):
        ref_u, ref_t = heat_app(ReferenceRuntime(), 40, 24, 4)
        ex = OutOfCoreExecutor(OOCConfig(
            num_tiles=tiles, capacity_bytes=float("inf"),
            cyclic=cyclic, prefetch=prefetch))
        got_u, got_t = heat_app(Runtime(ex), 40, 24, 4)
        np.testing.assert_allclose(ref_u, got_u, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ref_t, got_t, rtol=1e-4)

    def test_capacity_forces_tiling(self):
        ref_u, _ = heat_app(ReferenceRuntime(), 64, 16, 2)
        # capacity < 3 full-size slots -> executor must pick tiles > 1
        # (full footprint per slot here is 9072B; 3 slots need 27216B)
        ex = OutOfCoreExecutor(OOCConfig(capacity_bytes=24000))
        got_u, _ = heat_app(Runtime(ex), 64, 16, 2)
        assert ex.history[0].num_tiles > 1
        np.testing.assert_allclose(ref_u, got_u, rtol=1e-5, atol=1e-6)

    def test_resident_executor_raises_beyond_capacity(self):
        ex = ResidentExecutor(capacity_bytes=1024)  # absurdly small
        with pytest.raises(MemoryError):
            heat_app(Runtime(ex), 32, 16, 1)

    def test_transfer_elision_reduces_bytes(self):
        """cyclic ON must move strictly fewer bytes down, same result."""
        ex_off = OutOfCoreExecutor(OOCConfig(num_tiles=4, capacity_bytes=float("inf")))
        u_off, _ = heat_app(Runtime(ex_off), 40, 24, 4)
        ex_on = OutOfCoreExecutor(OOCConfig(num_tiles=4, capacity_bytes=float("inf"),
                                            cyclic=True))
        u_on, _ = heat_app(Runtime(ex_on), 40, 24, 4)
        np.testing.assert_allclose(u_off, u_on, rtol=1e-5, atol=1e-6)
        assert ex_on.history[0].downloaded < ex_off.history[0].downloaded

    def test_split_chain_preserves_cyclic_liveness(self):
        """A chain too long to fit splits on MemoryError; write-first dats of
        the first half that the second half still reads must be downloaded
        even under Cyclic (regression: stale-home re-upload read zeros)."""
        n, m, steps = 48, 10, 16
        ref_u, ref_t = heat_app(ReferenceRuntime(), n, m, steps)
        # capacity small enough that the 32-loop skewed chain cannot fit at
        # any tile count (skew span ~ chain length), forcing a split.
        ex = OutOfCoreExecutor(OOCConfig(capacity_bytes=4500, cyclic=True))
        got_u, got_t = heat_app(Runtime(ex), n, m, steps)
        assert len(ex.history) > 1  # the chain did split
        np.testing.assert_allclose(ref_u, got_u, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ref_t, got_t, rtol=1e-4)

    def test_inc_mode(self):
        blk = Block("g", (16, 8))
        a = make_dataset(blk, "a", halo=0, init=np.ones((16, 8), np.float32))
        Z = point_stencil(2)
        rt_ref = ReferenceRuntime()
        rt_ref.par_loop("inc", blk, blk.full_range(), [Arg(a, Z, INC)],
                        lambda acc: {"a": jnp.full(acc.shape, 2.0)})
        ref = rt_ref.fetch(a)
        b = make_dataset(blk, "a", halo=0, init=np.ones((16, 8), np.float32))
        rt = Runtime(OutOfCoreExecutor(OOCConfig(num_tiles=3, capacity_bytes=float("inf"))))
        rt.par_loop("inc", blk, blk.full_range(), [Arg(b, Z, INC)],
                    lambda acc: {"a": jnp.full(acc.shape, 2.0)})
        got = rt.fetch(b)
        np.testing.assert_allclose(ref, got)
        assert float(ref[0, 0]) == 3.0


# -- property-based: random chains, random tiling == reference -------------------
if HAVE_HYPOTHESIS:
    @st.composite
    def random_chain_spec(draw):
        n = draw(st.integers(16, 48))
        m = draw(st.integers(6, 14))
        n_loops = draw(st.integers(1, 6))
        ops = draw(st.lists(
            st.sampled_from(["blur", "shift", "copyback", "scale"]),
            min_size=n_loops, max_size=n_loops))
        tiles = draw(st.integers(1, 7))
        seed = draw(st.integers(0, 2 ** 16))
        return n, m, ops, tiles, seed


def _build(ops, blk, u, tmp):
    S = star_stencil(2, 1)
    Z = point_stencil(2)
    n, m = blk.size
    interior = ((1, n - 1), (1, m - 1))
    loops = []
    for i, kind in enumerate(ops):
        if kind == "blur":
            loops.append((f"blur{i}", interior,
                          [Arg(u, S, READ), Arg(tmp, Z, WRITE)],
                          lambda acc: {"tmp": 0.2 * (acc("u") + acc("u", (1, 0))
                                                     + acc("u", (-1, 0)) + acc("u", (0, 1))
                                                     + acc("u", (0, -1)))}))
        elif kind == "shift":
            loops.append((f"shift{i}", interior,
                          [Arg(u, offset_stencil((0, 0), (1, 1)), READ),
                           Arg(tmp, Z, WRITE)],
                          lambda acc: {"tmp": acc("u", (1, 1)) * 0.5 + acc("u")}))
        elif kind == "copyback":
            loops.append((f"cb{i}", interior,
                          [Arg(tmp, Z, READ), Arg(u, Z, RW)],
                          lambda acc: {"u": acc("tmp") + 0.1 * acc("u")}))
        else:
            loops.append((f"scale{i}", interior,
                          [Arg(u, Z, RW)], lambda acc: {"u": acc("u") * 0.9}))
    return loops


def _random_chain_body(spec):
    n, m, ops, tiles, seed = spec
    rng = np.random.RandomState(seed)
    init = rng.rand(n, m).astype(np.float32)

    results = []
    for runtime_kind in ("ref", "ooc"):
        blk = Block("g", (n, m))
        u = make_dataset(blk, "u", halo=1, init=init)
        tmp = make_dataset(blk, "tmp", halo=1)
        rt = (ReferenceRuntime() if runtime_kind == "ref"
              else Runtime(OutOfCoreExecutor(OOCConfig(
                  num_tiles=tiles, capacity_bytes=float("inf")))))
        for name, rng_, args, kern in _build(ops, blk, u, tmp):
            rt.par_loop(name, blk, rng_, args, kern)
        results.append(rt.fetch(u))
    np.testing.assert_allclose(results[0], results[1], rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @given(random_chain_spec())
    @settings(max_examples=15, deadline=None)
    def test_random_chains_match_reference(spec):
        _random_chain_body(spec)
else:
    @pytest.mark.parametrize("spec", [
        (32, 10, ["blur", "copyback", "scale"], 3, 7),
        (48, 14, ["shift", "copyback", "blur", "copyback"], 5, 123),
        (16, 6, ["scale"], 1, 999),
    ])
    def test_random_chains_match_reference(spec):
        """Fixed-seed fallback when hypothesis is not installed."""
        _random_chain_body(spec)
