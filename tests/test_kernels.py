"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra: example-based tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.kernels import chain2d, stencil2d, stencil3d
from repro.kernels.ref import chain2d_ref, stencil2d_ref, stencil3d_ref

C2 = np.array([0.5, 0.125, 0.125], np.float32)
C3 = np.array([0.4, 0.1, 0.1, 0.1], np.float32)


class TestStencil2D:
    @pytest.mark.parametrize("shape", [(8, 8), (33, 47), (128, 128), (65, 130), (7, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype, rng):
        H, W = shape
        x = jnp.asarray(rng.rand(H + 2, W + 2), dtype=dtype)
        got = stencil2d(x, C2)
        want = stencil2d_ref(x, C2)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    @pytest.mark.parametrize("block_rows", [8, 16, 64])
    def test_block_size_invariance(self, block_rows, rng):
        x = jnp.asarray(rng.rand(50, 34), jnp.float32)
        a = stencil2d(x, C2, block_rows=block_rows)
        b = stencil2d_ref(x, C2)
        np.testing.assert_allclose(a, b, atol=1e-6)


class TestStencil3D:
    @pytest.mark.parametrize("shape", [(4, 8, 8), (9, 17, 21), (16, 32, 32)])
    def test_matches_ref(self, shape, rng):
        D, H, W = shape
        x = jnp.asarray(rng.rand(D + 2, H + 2, W + 2), jnp.float32)
        np.testing.assert_allclose(stencil3d(x, C3), stencil3d_ref(x, C3), atol=1e-6)


class TestChain2D:
    @pytest.mark.parametrize("steps", [1, 2, 4, 6])
    def test_matches_ref(self, steps, rng):
        H, W = 40, 56
        x = jnp.asarray(rng.rand(H + 2 * steps, W + 2 * steps), jnp.float32)
        np.testing.assert_allclose(chain2d(x, C2, steps),
                                   chain2d_ref(x, C2, steps), atol=1e-5)

    def test_equals_repeated_single_sweeps(self, rng):
        """Fused K-sweep == K applications of the single-sweep kernel."""
        K, H, W = 3, 24, 32
        x = jnp.asarray(rng.rand(H + 2 * K, W + 2 * K), jnp.float32)
        fused = chain2d(x, C2, K)
        seq = x
        for _ in range(K):
            seq = stencil2d(seq, C2)
        np.testing.assert_allclose(fused, seq, atol=1e-5)


def _chain2d_case(h, w, steps, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(h + 2 * steps, w + 2 * steps), jnp.float32)
    np.testing.assert_allclose(chain2d(x, C2, steps), chain2d_ref(x, C2, steps),
                               atol=1e-5)


if HAVE_HYPOTHESIS:
    @given(h=st.integers(4, 40), w=st.integers(4, 40), steps=st.integers(1, 4),
           seed=st.integers(0, 999))
    @settings(max_examples=10, deadline=None)
    def test_chain2d_property(h, w, steps, seed):
        _chain2d_case(h, w, steps, seed)
else:
    @pytest.mark.parametrize("h,w,steps,seed", [
        (4, 4, 1, 0), (17, 9, 2, 3), (40, 23, 4, 42),
    ])
    def test_chain2d_property(h, w, steps, seed):
        """Fixed-seed fallback when hypothesis is not installed."""
        _chain2d_case(h, w, steps, seed)
