"""Observability-spine tests: the span tracer, metrics instruments, Chrome
export, the modelled-vs-achieved drift audit, and the end-to-end wiring
through executor / transfer lanes / sharded mesh / serve.

Two load-bearing properties:

* **Disabled is free, enabled is inert.**  Untraced sessions pay one
  attribute check; traced runs are *bit-identical* to untraced runs on all
  three bundled apps (tracing only observes, never perturbs).
* **The sim interpreter is its own oracle.**  Modelled spans are emitted at
  the simulated ledger events' exact timestamps, so the drift audit must
  report a per-stream achieved/modelled ratio of exactly 1.0 — not
  approximately.
"""
import json
import threading

import numpy as np
import pytest

from repro.apps.cloverleaf2d import CloverLeaf2D
from repro.apps.cloverleaf3d import CloverLeaf3D
from repro.apps.opensbli import OpenSBLI
from repro.core import Session
from repro.obs import (
    NULL_TRACER,
    MetricsRegistry,
    NullTracer,
    Tracer,
    as_tracer,
    chrome_trace,
    compare,
    merge_histogram_snapshots,
    spans_from_chrome,
    validate_chrome_trace,
)
from repro.serve import StencilServer


# -- tracer core --------------------------------------------------------------------

def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit(f"s{i}", t_start=float(i), t_end=float(i) + 0.5)
    assert len(tr) == 4
    assert tr.dropped == 6
    # Oldest spans were evicted, newest retained.
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_emit_is_thread_safe():
    tr = Tracer(capacity=1 << 14)
    n_threads, per_thread = 8, 200

    def work(k):
        for i in range(per_thread):
            tr.emit("e", track=f"t{k}", t_start=float(i), t_end=float(i + 1))

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == n_threads * per_thread
    assert tr.dropped == 0
    per_track = {}
    for s in tr.spans():
        per_track[s.track] = per_track.get(s.track, 0) + 1
    assert all(v == per_thread for v in per_track.values())


def test_span_context_manager_nests():
    ticks = iter(range(100))
    tr = Tracer(clock=lambda: float(next(ticks)))
    with tr.span("outer", track="a"):
        with tr.span("inner", track="a", args={"k": 1}):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner"}
    # Inner closes first (emit-on-exit) and sits inside outer's interval.
    inner, outer = spans["inner"], spans["outer"]
    assert outer.t_start <= inner.t_start <= inner.t_end <= outer.t_end
    assert inner.args == {"k": 1}
    assert inner.duration == inner.t_end - inner.t_start


def test_null_tracer_fast_path_allocates_nothing():
    nt = as_tracer(None)
    assert nt is NULL_TRACER and nt is as_tracer(False)
    assert not nt.enabled
    # span() returns one module-level singleton: no per-call allocation.
    assert nt.span("a") is nt.span("b")
    assert nt.emit("x", t_start=0.0, t_end=1.0) is None
    assert nt.spans() == [] and len(nt) == 0
    # Shared instances pass through; fresh tracer on True; junk rejected.
    tr = Tracer()
    assert as_tracer(tr) is tr
    assert isinstance(as_tracer(True), Tracer)
    assert isinstance(as_tracer(NullTracer()), NullTracer)
    with pytest.raises(TypeError):
        as_tracer("yes")


def test_untraced_session_exposes_no_trace():
    sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
    try:
        assert sess.trace() is None
    finally:
        sess.close()


# -- metrics ------------------------------------------------------------------------

def test_metrics_registry_instruments():
    mr = MetricsRegistry()
    mr.counter("jobs").inc()
    mr.counter("jobs").inc(2.0)
    mr.gauge("depth").set(3)
    mr.histogram("wait").observe(1e-5)
    mr.histogram("wait").observe(2.0)
    snap = mr.snapshot()
    assert snap["counters"]["jobs"] == 3.0
    assert snap["gauges"]["depth"] == 3.0
    h = snap["histograms"]["wait"]
    assert h["count"] == 2 and h["min"] == 1e-5 and h["max"] == 2.0
    assert sum(c for _, c in h["buckets"]) + h["overflow"] == 2
    # snapshot is JSON-able as-is
    assert json.loads(mr.to_json())["counters"]["jobs"] == 3.0
    # same-name accessor returns the same instrument
    assert mr.counter("jobs") is mr.counter("jobs")


def test_histogram_snapshots_merge():
    from repro.obs import Histogram

    a, b = Histogram(), Histogram()
    a.observe(1e-4)
    b.observe(0.5)
    b.observe(50.0)
    m = merge_histogram_snapshots(a.snapshot(), b.snapshot())
    assert m["count"] == 3
    assert m["min"] == 1e-4 and m["max"] == 50.0
    assert sum(c for _, c in m["buckets"]) + m["overflow"] == 3
    # empty snapshots pass through; mismatched bounds refuse
    assert merge_histogram_snapshots({}, a.snapshot())["count"] == 1
    with pytest.raises(ValueError):
        merge_histogram_snapshots(a.snapshot(),
                                  Histogram(bounds=(1.0, 2.0)).snapshot())


# -- chrome export ------------------------------------------------------------------

def test_chrome_trace_round_trip():
    tr = Tracer()
    tr.emit("up", cat="lane", track="upload", t_start=0.25, t_end=1.5,
            args={"eid": 3, "bytes": 4096})
    tr.emit("k0", cat="model", track="compute", t_start=1.5, t_end=2.75)
    doc = tr.chrome()
    validate_chrome_trace(doc)
    # one metadata record per track + process name, then the X events
    names = [e["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"]
    assert len(names) == 2
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(xs) == 2
    back = spans_from_chrome(doc)
    got = {s.name: s for s in back}
    assert got["up"].track == "upload"
    assert got["up"].args["bytes"] == 4096
    assert got["up"].t_start == pytest.approx(0.25, abs=1e-6)
    assert got["up"].duration == pytest.approx(1.25, abs=1e-6)
    # serialisable end to end
    json.dumps(doc)


def test_chrome_validation_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": "nope"})
    bad = chrome_trace([])
    bad["traceEvents"].append({"ph": "X", "name": "x"})  # missing ts/dur/tid
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)


# -- drift audit: the sim interpreter is its own oracle -----------------------------

def _sim_traced_session(app):
    sess = Session("sim", num_tiles=4,
                   capacity_bytes=app.total_bytes() * 0.5, trace=True)
    app.record_init(sess)
    sess.flush()
    app.dt = 1e-4
    app.record_timestep(sess)
    sess.flush()
    return sess


def test_sim_drift_audit_is_oracle_exact():
    app = CloverLeaf2D(40, 24, summary_every=0)
    sess = _sim_traced_session(app)
    tr = sess.trace()
    assert tr is not None and len(tr) > 0
    ledgers = sess.backend.ledgers
    assert len(ledgers) == len(sess.history)
    seen_streams = set()
    for ci, ledger in enumerate(ledgers):
        rep = compare(ledger, tr, chain=ci)
        assert rep.unmatched_events == 0
        assert rep.overall_ratio == 1.0
        for sd in rep.streams.values():
            # Exact equality is the whole point: modelled spans *are* the
            # simulated events, so the sums agree bitwise.
            assert sd.ratio == 1.0, (ci, sd.name)
            assert sd.matched == sd.events
            seen_streams.add(sd.name)
        # every audited op cites a plan op index >= 0 (format_plan's #N)
        assert all(o.op >= 0 for o in rep.ops)
        assert rep.summary(top_k=3)  # renders without error
    assert {"compute", "upload", "download"} <= seen_streams
    sess.close()


def test_drift_audit_tolerates_foreign_spans():
    """Spans from other chains/layers must not leak into a chain's audit."""
    app = CloverLeaf2D(40, 24, summary_every=0)
    sess = _sim_traced_session(app)
    tr = sess.trace()
    tr.emit("noise", cat="serve", track="tenant/x", t_start=0.0, t_end=9.9)
    rep = compare(sess.backend.ledgers[-1], tr,
                  chain=len(sess.backend.ledgers) - 1)
    assert rep.overall_ratio == 1.0
    sess.close()


# -- data-plane wiring --------------------------------------------------------------

def test_threaded_run_traces_all_streams():
    app = CloverLeaf2D(48, 32, summary_every=0)
    sess = Session("ooc-async", num_tiles=4, capacity_bytes=float("inf"),
                   trace=True)
    app.run(sess, steps=2)
    tr = sess.trace()
    tracks = {s.track for s in tr.spans()}
    assert {"chain", "compute", "upload", "download"} <= tracks
    # lane spans carry their ledger event id and queue-wait
    lane_spans = [s for s in tr.spans() if s.cat == "lane"]
    assert lane_spans
    for s in lane_spans:
        assert "eid" in s.args and "queue_wait_s" in s.args
    validate_chrome_trace(tr.chrome())
    # per-lane queue-wait/service histograms ride transfer_stats()
    lanes = sess.transfer_stats()["lanes"]
    assert lanes, "threaded engine reported no lane histograms"
    for lane, hists in lanes.items():
        assert hists["queue_wait"]["count"] > 0, lane
        assert hists["service"]["count"] > 0, lane
    # wall-clock achieved vs TPU-modelled: wildly different scales, but the
    # audit must still match every handle-backed event it can see
    rep = compare(sess.backend.ledgers[0], tr, chain=0)
    assert rep.spans_seen > 0
    assert all(sd.ratio > 0.0 for sd in rep.streams.values()
               if sd.achieved_s > 0)
    sess.close()


def test_traced_chain_records_ledger_and_chain_spans():
    app = CloverLeaf2D(32, 24, summary_every=0)
    sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"),
                   trace=True)
    app.record_init(sess)
    sess.flush()
    tr = sess.trace()
    chain_spans = [s for s in tr.spans() if s.cat == "chain"]
    assert len(chain_spans) == len(sess.history) == 1
    assert chain_spans[0].args["chain"] == 0
    assert len(sess.backend.ledgers) == 1
    sess.close()


# -- bit-identity: tracing observes, never perturbs ---------------------------------

@pytest.mark.parametrize("factory", [
    lambda: CloverLeaf2D(32, 24, summary_every=0),
    lambda: CloverLeaf3D(12, 10, 8, summary_every=0),
    lambda: OpenSBLI(16),
], ids=["cloverleaf2d", "cloverleaf3d", "opensbli"])
def test_traced_run_bit_identical(factory):
    def run(trace):
        app = factory()
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"),
                       trace=trace)
        try:
            app.record_init(sess)
            sess.flush()
            app.dt = 1e-4
            app.record_timestep(sess)
            sess.flush()
            return {k: d.materialize() for k, d in app.dats.items()}
        finally:
            sess.close()

    plain, traced = run(False), run(True)
    assert set(plain) == set(traced)
    for k in plain:
        np.testing.assert_array_equal(plain[k], traced[k],
                                      err_msg=f"tracing perturbed {k!r}")


# -- plan-op indices ----------------------------------------------------------------

def test_format_plan_numbers_ops():
    app = CloverLeaf2D(40, 24, summary_every=0)
    sess = Session("sim", num_tiles=4,
                   capacity_bytes=app.total_bytes() * 0.5)
    app.record_init(sess)
    sess.queue.clear()
    app.dt = 1e-4
    app.record_timestep(sess)
    text = sess.explain()
    assert "#0" in text, "format_plan lost its op indices"
    plans = sess.plan()
    # the highest printed index addresses a real op in some chain's plan
    idx = max(int(tok[1:]) for tok in text.split() if tok.startswith("#")
              and tok[1:].isdigit())
    assert idx < max(len(p.ops) for p in plans)
    # verifier diagnostics still render alongside the indices
    assert "modelled makespan" in sess.explain(verify=True)


# -- sharded mesh -------------------------------------------------------------------

def test_sharded_trace_tags_devices():
    app = CloverLeaf2D(32, 24, summary_every=0)
    sess = Session("ooc", mesh="sim:2", num_tiles=2,
                   capacity_bytes=float("inf"), trace=True)
    app.record_init(sess)
    sess.flush()
    tr = sess.trace()
    tracks = {s.track for s in tr.spans()}
    assert any(t.startswith("dev0/") for t in tracks)
    assert any(t.startswith("dev1/") for t in tracks)
    assert "mesh" in tracks  # scatter/gather (+ halo when depth > 0)
    lanes = sess.transfer_stats()["lanes"]
    assert lanes and all(h["queue_wait"]["count"] >= 0
                         for h in lanes.values())
    sess.close()


# -- serve layer --------------------------------------------------------------------

def test_serve_spans_metrics_and_shared_clock():
    """One injected clock feeds tenant queue-wait stats *and* serve spans:
    with time frozen, every serve-layer duration is exactly zero."""
    frozen = 1234.5

    with StencilServer("sim:1", capacity_bytes=2e6, trace=True,
                       clock=lambda: frozen) as srv:
        app = CloverLeaf2D(24, 24, summary_every=0)
        rt = srv.session("t0")
        app.record_init(rt)
        rt.flush()
        st = srv.stats()
        assert st.tenants["t0"].queue_wait_s == 0.0
        tr = srv.tracer
        assert rt.trace() is tr  # server-backed sessions see the spine
        serve_spans = [s for s in tr.spans() if s.cat in ("serve", "lease")]
        assert {s.name for s in serve_spans} >= {"admit", "queue-wait", "t0"}
        for s in serve_spans:
            assert s.t_start == frozen and s.t_end == frozen
        lease = [s for s in serve_spans if s.cat == "lease"]
        assert lease and lease[0].track == "lane0"
        m = srv.metrics()
        assert m["counters"]["jobs_completed"] == 1.0
        assert m["histograms"]["queue_wait_s"]["count"] == 1
        assert m["histograms"]["queue_wait_s"]["sum"] == 0.0
        assert m["gauges"]["free_lanes"] == 1.0
        rt.close()


def test_serve_lane_tags_and_oracle_stays_untraced():
    with StencilServer("sim:2", capacity_bytes=2e6, trace=True) as srv:
        app = CloverLeaf2D(24, 24, summary_every=2)
        rt = srv.session("t0")
        app.run(rt, steps=1)
        rt.close()
        tracks = {s.track for s in srv.tracer.spans()}
        assert any(t.startswith("lane0/") for t in tracks)
        # The admission oracle shares the lanes' config but must not leak
        # phantom sim runs into the trace: every span is tagged by a lane,
        # a tenant, or the serve layer itself.
        for s in srv.tracer.spans():
            assert (s.track.startswith(("lane", "tenant/"))
                    or s.cat == "lease"), s.track


def test_serve_untraced_by_default():
    with StencilServer("sim:1", capacity_bytes=2e6) as srv:
        assert not srv.tracer.enabled
        rt = srv.session("t0")
        assert rt.trace() is None
        rt.close()
        assert srv.metrics()["counters"] == {}
