"""Unit tests: dependency analysis, skew schedule, footprint algebra."""
import numpy as np
import pytest

from repro.core import (
    Arg, Block, READ, RW, WRITE, analyze_chain, make_dataset,
    make_tile_schedule, choose_num_tiles, offset_stencil, point_stencil,
    star_stencil,
)
from repro.core.tiling import Interval


def _chain(n=64, m=16, loops=4, radius=1):
    blk = Block("b", (n, m))
    u = make_dataset(blk, "u", halo=radius)
    tmp = make_dataset(blk, "tmp", halo=radius)
    S = star_stencil(2, radius)
    Z = point_stencil(2)
    out = []
    import jax.numpy as jnp

    for i in range(loops):
        def k1(acc):
            return {"tmp": acc("u", (1, 0)) + acc("u", (-1, 0))}

        def k2(acc):
            return {"u": acc("tmp")}

        from repro.core import ParallelLoop
        out.append(ParallelLoop(f"a{i}", blk, ((radius, n - radius), (radius, m - radius)),
                                (Arg(u, S, READ), Arg(tmp, Z, WRITE)), k1))
        out.append(ParallelLoop(f"b{i}", blk, ((radius, n - radius), (radius, m - radius)),
                                (Arg(tmp, Z, READ), Arg(u, Z, RW)), k2))
    return out


class TestDependency:
    def test_classification(self):
        loops = _chain()
        info = analyze_chain(loops)
        assert "tmp" in info.write_first
        assert "u" in info.modified
        assert not info.read_only
        assert info.skew_slope == 1

    def test_cold_reads(self):
        loops = _chain(radius=2)
        info = analyze_chain(loops)
        # u is read at +/-2 around [2, 62) before first being written -> cold
        assert info.cold["u"][0][0] == 0
        # tmp is written before any read: no cold rows
        assert info.cold.get("tmp", []) == []

    def test_written_regions(self):
        info = analyze_chain(_chain())
        assert info.written["u"] == [(1, 63)]


class TestSchedule:
    @pytest.mark.parametrize("num_tiles", [1, 2, 3, 5, 8])
    def test_ranges_partition(self, num_tiles):
        """Each loop's per-tile sub-ranges exactly partition its range."""
        loops = _chain()
        info = analyze_chain(loops)
        sched = make_tile_schedule(info, num_tiles)
        for k, lp in enumerate(info.loops):
            covered = []
            for tile in sched.tiles:
                box = tile.loop_ranges[k]
                if box is not None:
                    covered.append(box[0])
            # contiguous, ordered, exactly covering
            assert covered[0][0] == lp.range_[0][0]
            assert covered[-1][1] == lp.range_[0][1]
            for (a0, b0), (a1, b1) in zip(covered, covered[1:]):
                assert b0 == a1

    def test_skew_monotone(self):
        """Earlier loops extend further right within a tile (skewing)."""
        info = analyze_chain(_chain())
        sched = make_tile_schedule(info, 4)
        tile = sched.tiles[0]
        ends = [box[0][1] for box in tile.loop_ranges if box is not None]
        assert all(e0 >= e1 for e0, e1 in zip(ends, ends[1:]))

    def test_footprint_covers_accesses(self):
        info = analyze_chain(_chain())
        sched = make_tile_schedule(info, 4)
        for tile in sched.tiles:
            for k, box in enumerate(tile.loop_ranges):
                if box is None:
                    continue
                lp = info.loops[k]
                for arg in lp.args:
                    lo, hi = box[0]
                    if arg.mode.reads:
                        mn, mx = arg.stencil.extent(0)
                        lo, hi = lo + mn, hi + mx
                    blo, bhi = arg.dat.bounds(0)
                    lo, hi = max(lo, blo), min(hi, bhi)
                    f = tile.footprint[arg.dat.name]
                    assert f.lo <= lo and hi <= f.hi

    def test_upload_download_cover_footprint(self):
        """Per dat: union(uploads) + union(edges-in) == footprint; downloads
        cover every written row exactly once."""
        info = analyze_chain(_chain())
        sched = make_tile_schedule(info, 5)
        for name in info.datasets:
            downloaded = []
            for tile in sched.tiles:
                for iv in tile.download.get(name, ()):
                    if not iv.empty:
                        downloaded.append((iv.lo, iv.hi))
            downloaded.sort()
            for (a0, b0), (a1, b1) in zip(downloaded, downloaded[1:]):
                assert b0 <= a1, "overlapping downloads"
            if name in info.modified:
                lo = min(a for a, _ in info.written[name])
                hi = max(b for _, b in info.written[name])
                assert downloaded[0][0] <= lo and downloaded[-1][1] >= hi

    def test_choose_num_tiles_fits(self):
        loops = _chain(n=256)
        info = analyze_chain(loops)
        full = make_tile_schedule(info, 1).slot_bytes()
        nt = choose_num_tiles(info, capacity_bytes=full, num_slots=3)
        sched = make_tile_schedule(info, nt)
        assert 3 * sched.slot_bytes() <= full
        assert nt > 1


class TestInterval:
    def test_difference_two_pieces(self):
        a, b = Interval(0, 10), Interval(3, 7)
        assert a.difference(b) == (Interval(0, 3), Interval(7, 10))

    def test_difference_disjoint(self):
        assert Interval(0, 5).difference(Interval(7, 9)) == (Interval(0, 5),)

    def test_difference_covered(self):
        assert Interval(3, 5).difference(Interval(0, 9)) == ()
