"""The StencilProgram/Session frontend: stencil inference vs hand-declared
access, backend-registry dispatch, and chain-plan memoisation."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.apps import CloverLeaf2D, CloverLeaf3D, OpenSBLI
from repro.core import (
    READ,
    RW,
    WRITE,
    AccessMode,
    Arg,
    Block,
    ExecutionConfig,
    Session,
    StencilProgram,
    StencilValidationError,
    available_backends,
    make_dataset,
    offset_stencil,
    point_stencil,
    star_stencil,
)
from repro.kernels import star2d_kernel


def _heat_loops(sess, n=40, m=20, steps=3, declared=False):
    blk = Block("grid", (n, m))
    rng = np.random.RandomState(7)
    u = make_dataset(blk, "u", halo=1, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=1)
    interior = ((1, n - 1), (1, m - 1))
    S, Z = star_stencil(2, 1), point_stencil(2)
    diffuse = lambda acc: {"tmp": 0.25 * (acc("u", (1, 0)) + acc("u", (-1, 0))
                                          + acc("u", (0, 1)) + acc("u", (0, -1)))}
    commit = lambda acc: {"u": acc("tmp")}
    for s in range(steps):
        if declared:
            sess.par_loop(f"d{s}", blk, interior,
                          [Arg(u, S, READ), Arg(tmp, Z, WRITE)], diffuse)
            sess.par_loop(f"c{s}", blk, interior,
                          [Arg(tmp, Z, READ), Arg(u, Z, RW)], commit)
        else:
            sess.par_loop(f"d{s}", blk, interior, [u, tmp], diffuse)
            sess.par_loop(f"c{s}", blk, interior, [tmp, u], commit)
    return sess.fetch(u)


# -- stencil inference -----------------------------------------------------------


class TestInference:
    def test_inferred_equals_declared_execution(self):
        a = _heat_loops(Session("reference"), declared=True)
        b = _heat_loops(Session("reference"), declared=False)
        np.testing.assert_array_equal(a, b)

    def test_inferred_modes_and_stencils(self):
        sess = Session("reference")
        _heat_loops(sess, steps=1)
        # fetch flushed the queue; re-record to inspect
        blk = Block("g", (8, 8))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        sess.par_loop("d", blk, ((1, 7), (1, 7)), [u, t],
                      lambda acc: {"t": acc("u", (1, 0)) + acc("u", (0, -1))})
        lp = sess.queue[-1]
        by = {(a.dat.name, a.mode): a for a in lp.args}
        assert set(by[("u", READ)].stencil.points) == {(1, 0), (0, -1)}
        assert by[("t", WRITE)].stencil.is_zero()

    def test_rw_and_split_read_write(self):
        blk = Block("g", (10, 6))
        u = make_dataset(blk, "u", halo=2)
        sess = Session("reference")
        # zero-offset read + write -> RW
        sess.par_loop("scale", blk, ((0, 10), (0, 6)), [u],
                      lambda acc: {"u": acc("u") * 0.5})
        assert [a.mode for a in sess.queue[-1].args] == [RW]
        # halo-mirror style: offset read + write over disjoint regions ->
        # READ(stencil) + WRITE(zero) pair
        sess.par_loop("halo", blk, ((-1, 0), (0, 6)), [u],
                      lambda acc: {"u": acc("u", (1, 0))})
        modes = [(a.mode, tuple(a.stencil.points)) for a in sess.queue[-1].args]
        assert (AccessMode.READ, ((1, 0),)) in modes
        assert (AccessMode.WRITE, ((0, 0),)) in modes

    def test_inc_hint(self):
        blk = Block("g", (8, 4))
        u = make_dataset(blk, "u", halo=0, init=np.ones((8, 4), np.float32))
        sess = Session("reference")
        sess.par_loop("inc", blk, blk.full_range(), [u],
                      lambda acc: {"u": jnp.full(acc.shape, 2.0)}, inc=["u"])
        assert sess.queue[-1].args[0].mode is AccessMode.INC
        got = sess.fetch(u)
        assert float(got[0, 0]) == 3.0

    def test_unused_dataset_rejected(self):
        blk = Block("g", (8, 4))
        u = make_dataset(blk, "u", halo=0)
        v = make_dataset(blk, "v", halo=0)
        sess = Session("reference")
        with pytest.raises(ValueError, match="neither reads nor writes"):
            sess.par_loop("l", blk, blk.full_range(), [u, v],
                          lambda acc: {"u": acc("u") * 2})

    def test_unknown_read_rejected(self):
        blk = Block("g", (8, 4))
        u = make_dataset(blk, "u", halo=0)
        sess = Session("reference")
        with pytest.raises(KeyError, match="not passed to"):
            sess.par_loop("l", blk, blk.full_range(), [u],
                          lambda acc: {"u": acc("ghost")})

    def test_explicit_stencil_must_cover_traced_reads(self):
        blk = Block("g", (8, 8))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        sess = Session("reference")
        with pytest.raises(StencilValidationError, match="does not cover"):
            sess.par_loop("l", blk, ((1, 7), (1, 7)), [u, t],
                          lambda acc: {"t": acc("u", (1, 0))},
                          explicit_stencil={"u": point_stencil(2)})

    def test_explicit_stencil_typo_rejected(self):
        blk = Block("g", (8, 8))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        sess = Session("reference")
        with pytest.raises(ValueError, match="not among the inferred"):
            sess.par_loop("l", blk, ((1, 7), (1, 7)), [u, t],
                          lambda acc: {"t": acc("u")},
                          explicit_stencil={"uu": star_stencil(2, 1)})

    def test_inc_with_offset_self_read_rejected(self):
        blk = Block("g", (8, 8))
        w = make_dataset(blk, "w", halo=2)
        sess = Session("reference")
        with pytest.raises(ValueError, match="split the loop"):
            sess.par_loop("h", blk, ((-1, 0), (0, 8)), [w],
                          lambda acc: {"w": acc("w", (1, 0))}, inc=["w"])

    def test_explicit_stencil_escape_hatch(self):
        blk = Block("g", (12, 6))
        u = make_dataset(blk, "u", halo=2)
        t = make_dataset(blk, "t", halo=2)
        wide = offset_stencil((-2, 0), (-1, 0), (0, 0), (1, 0), (2, 0))
        sess = Session("reference")
        sess.par_loop("l", blk, ((2, 10), (0, 6)), [u, t],
                      lambda acc: {"t": acc("u", (-1, 0)) + acc("u")},
                      explicit_stencil={"u": wide})
        arg = next(a for a in sess.queue[-1].args if a.dat.name == "u")
        assert set(arg.stencil.points) == set(wide.points)


class TestInferenceOnApps:
    """Inference reproduces the hand-declared access patterns of the apps."""

    def _loops(self, app):
        rt = Session("reference")
        app.record_init(rt)
        rt.queue.clear()
        app.dt = 1e-4
        app.record_timestep(rt)
        return {lp.name: lp for lp in rt.queue}

    @staticmethod
    def _read_points(lp, dat_name):
        pts = set()
        for a in lp.args:
            if a.dat.name == dat_name and a.mode.reads:
                pts |= set(a.stencil.points)
        return pts

    def test_cloverleaf2d(self):
        app = CloverLeaf2D(24, 24, summary_every=0)
        loops = self._loops(app)
        assert self._read_points(loops["viscosity"], "xvel0") == {(0, 0), (1, 0)}
        assert self._read_points(loops["accelerate"], "density0") == set(
            app.S_node.points)
        # escape hatch preserved the paper's 5-point donor stencil
        assert self._read_points(loops["advec_cell_x_flux"], "density1") == set(
            app.S_adv_x.points)
        # halo loops split into offset READ + zero WRITE
        halo = loops["update_halo_eos_0"]
        assert self._read_points(halo, "pressure") == {(1, 0)}
        assert any(a.dat.name == "pressure" and a.mode is WRITE
                   and a.stencil.is_zero() for a in halo.args)
        # every write-mode arg is zero-stencil (the OPS restriction)
        for lp in loops.values():
            for a in lp.args:
                if a.mode.writes:
                    assert a.stencil.is_zero()

    def test_cloverleaf3d(self):
        app = CloverLeaf3D(10, 8, 8, summary_every=0)
        loops = self._loops(app)
        assert self._read_points(loops["viscosity3d"], "xvel0") == {
            (0, 0, 0), (1, 0, 0)}
        assert self._read_points(loops["accelerate3d"], "density0") == set(
            app.S_node.points)
        # pressure gradient only reads the three negative-axis neighbours
        assert self._read_points(loops["accelerate3d"], "pressure") == {
            (0, 0, 0), (-1, 0, 0), (0, -1, 0), (0, 0, -1)}

    def test_opensbli(self):
        app = OpenSBLI(12)
        loops = self._loops(app)
        # shear reads u at +/-1 along every axis (one merged stencil)
        expect = {(0, 0, 0)} | {
            tuple(s * o for o in ax)
            for s in (1, -1) for ax in ((1, 0, 0), (0, 1, 0), (0, 0, 1))}
        got = self._read_points(loops["shear_s0"], "u")
        assert got == expect - {(0, 0, 0)} or got == expect
        # rho residual: central +/-1 derivative stencil on rho
        rho_pts = self._read_points(loops["residual_rho_s0"], "rho")
        assert (1, 0, 0) in rho_pts and (-1, 0, 0) in rho_pts
        # rk_update is pure zero-stencil RW on conserved + work arrays
        rk = loops["rk_update_s0"]
        assert all(a.stencil.is_zero() for a in rk.args)


class TestValidation:
    def test_declared_too_narrow_rejected(self):
        blk = Block("g", (10, 6))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        Z = point_stencil(2)
        sess = Session(ExecutionConfig(backend="reference",
                                       validate_stencils=True))
        with pytest.raises(StencilValidationError, match="not covered"):
            sess.par_loop("l", blk, ((1, 9), (1, 5)),
                          [Arg(u, Z, READ), Arg(t, Z, WRITE)],
                          lambda acc: {"t": acc("u", (1, 0))})

    def test_declared_wider_accepted(self):
        blk = Block("g", (10, 6))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        sess = Session(ExecutionConfig(backend="reference",
                                       validate_stencils=True))
        sess.par_loop("l", blk, ((1, 9), (1, 5)),
                      [Arg(u, star_stencil(2, 1), READ),
                       Arg(t, point_stencil(2), WRITE)],
                      lambda acc: {"t": acc("u", (1, 0))})
        assert len(sess.queue) == 1

    def test_mixed_declared_and_inferred_still_validated(self):
        blk = Block("g", (10, 6))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        sess = Session(ExecutionConfig(backend="reference",
                                       validate_stencils=True))
        with pytest.raises(StencilValidationError, match="not covered"):
            sess.par_loop("l", blk, ((1, 9), (1, 5)),
                          [Arg(u, point_stencil(2), READ), t],
                          lambda acc: {"t": acc("u", (1, 0))})

    def test_undeclared_write_rejected(self):
        blk = Block("g", (10, 6))
        u = make_dataset(blk, "u", halo=1)
        t = make_dataset(blk, "t", halo=1)
        Z = point_stencil(2)
        sess = Session(ExecutionConfig(backend="reference",
                                       validate_stencils=True))
        with pytest.raises(StencilValidationError, match="undeclared"):
            sess.par_loop("l", blk, ((1, 9), (1, 5)),
                          [Arg(u, Z, RW), Arg(t, Z, READ)],
                          lambda acc: {"u": acc("u") + acc("t"), "t": acc("t")})


# -- backend registry -------------------------------------------------------------


class TestBackends:
    def test_registry_lists_builtins(self):
        names = available_backends()
        for want in ("reference", "resident", "ooc", "ooc-cyclic", "sim",
                     "pallas"):
            assert want in names

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown backend"):
            Session("no-such-backend")

    def test_hw_preset_by_name(self):
        sess = Session("ooc", hw="p100-nvlink")
        assert sess.config.hw.name == "p100-nvlink"
        with pytest.raises(ValueError, match="preset"):
            Session("ooc", hw="not-a-preset")

    def test_ooc_matches_reference(self):
        ref = _heat_loops(Session("reference"))
        got = _heat_loops(Session("ooc", num_tiles=4,
                                  capacity_bytes=float("inf")))
        np.testing.assert_allclose(ref, got, rtol=1e-5, atol=1e-6)

    def test_ooc_cyclic_and_sim(self):
        ref = _heat_loops(Session("reference"))
        cyc = Session("ooc-cyclic", num_tiles=4, capacity_bytes=float("inf"))
        got = _heat_loops(cyc)
        np.testing.assert_allclose(ref, got, rtol=1e-5, atol=1e-6)
        assert cyc.cyclic
        sim = Session("sim", num_tiles=4, capacity_bytes=float("inf"))
        _heat_loops(sim)          # no data plane; just runs & ledgers
        assert sim.history[-1].num_tiles == 4

    def test_pallas_backend_fast_path(self):
        def star_prog(sess, steps=2):
            blk = Block("g", (24, 16))
            rng = np.random.RandomState(3)
            u = make_dataset(blk, "u", halo=1,
                             init=rng.rand(24, 16).astype(np.float32))
            t = make_dataset(blk, "t", halo=1)
            interior = ((1, 23), (1, 15))
            k = star2d_kernel("u", "t", (0.5, 0.25, 0.25))
            for s in range(steps):
                sess.par_loop("sweep", blk, interior, [u, t], k)
                sess.par_loop("commit", blk, interior, [t, u],
                              lambda acc: {"u": acc("t")})
            return sess.fetch(u)

        ref = star_prog(Session("reference"))
        sp = Session("pallas")
        got = star_prog(sp)
        np.testing.assert_allclose(ref, got, rtol=1e-5, atol=1e-6)
        assert sp.backend.pallas_loops == 2      # both sweeps fast-pathed
        assert sp.backend.fallback_loops == 2    # commits via reference

    def test_runtime_shims_deprecated(self):
        from repro.core import ReferenceRuntime, Runtime

        with pytest.warns(DeprecationWarning):
            rt = ReferenceRuntime()
        assert isinstance(rt, Session)
        with pytest.warns(DeprecationWarning):
            rt2 = Runtime()
        assert isinstance(rt2, Session)
        assert StencilProgram is Session


# -- chain-plan memoisation -------------------------------------------------------


class TestPlanCache:
    def test_identical_chains_planned_once(self):
        sess = Session("ooc", num_tiles=3, capacity_bytes=float("inf"))
        blk = Block("g", (30, 12))
        u = make_dataset(blk, "u", halo=1,
                         init=np.random.RandomState(0).rand(30, 12).astype(np.float32))
        t = make_dataset(blk, "t", halo=1)
        k1 = lambda acc: {"t": acc("u", (1, 0)) + acc("u", (-1, 0))}
        k2 = lambda acc: {"u": acc("t")}
        for step in range(5):
            sess.par_loop("a", blk, ((1, 29), (0, 12)), [u, t], k1)
            sess.par_loop("b", blk, ((1, 29), (0, 12)), [t, u], k2)
            sess.fetch(u)
        st = sess.plan_stats()
        assert st["plan_misses"] == 1
        assert st["plan_hits"] == 4

    def test_changed_kernel_constant_forces_replan(self):
        """A captured scalar change must re-plan (stale-closure safety)."""
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        blk = Block("g", (16, 8))
        u = make_dataset(blk, "u", halo=0,
                         init=np.ones((16, 8), np.float32))

        def record(scale):
            def k(acc):
                return {"u": acc("u") * scale}
            sess.par_loop("scale", blk, blk.full_range(), [u], k)
            return sess.fetch(u)

        record(2.0)
        got = record(3.0)
        assert sess.plan_stats()["plan_misses"] == 2
        np.testing.assert_allclose(got[0, 0], 6.0)

    def test_same_line_kernels_do_not_collide(self):
        """co_code references constants/globals by index: two kernels defined
        on one source line must still fingerprint differently."""
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        blk = Block("g", (16, 8))
        u = make_dataset(blk, "u", halo=1, init=np.ones((16, 8), np.float32))
        t = make_dataset(blk, "t", halo=1)
        ks = [lambda acc: {"u": acc("u") * 2.0}, lambda acc: {"u": acc("u") * 3.0}]
        for k in ks:
            sess.par_loop("k", blk, ((1, 15), (1, 7)), [u], k)
            sess.fetch(u)
        np.testing.assert_allclose(sess.fetch(u)[1, 1], 6.0)
        # same-line kernels with different read offsets: inference must not
        # serve the first kernel's stencil to the second
        rs = [lambda acc: {"t": acc("u", (1, 0))}, lambda acc: {"t": acc("u", (0, 1))}]
        sref = Session("reference")
        for i, k in enumerate(rs):
            sref.par_loop(f"r{i}", blk, ((1, 15), (1, 7)), [u, t], k)
        pts = [next(a for a in lp.args if a.dat.name == "u").stencil.points
               for lp in sref.queue]
        assert pts[0] == ((1, 0),) and pts[1] == ((0, 1),)

    def test_changed_array_capture_forces_replan(self):
        """Captured ndarrays fingerprint by content, not type — a changed
        coefficient array must not replay the cached plan."""
        sess = Session("ooc", num_tiles=2, capacity_bytes=float("inf"))
        blk = Block("g", (16, 8))
        u = make_dataset(blk, "u", halo=0, init=np.ones((16, 8), np.float32))

        def record(coeffs):
            c = np.asarray(coeffs, np.float32)

            def k(acc):
                return {"u": acc("u") * c[0]}
            sess.par_loop("scale", blk, blk.full_range(), [u], k)
            return sess.fetch(u)

        record([2.0])
        got = record([5.0])
        assert sess.plan_stats()["plan_misses"] == 2
        np.testing.assert_allclose(got[0, 0], 10.0)

    def test_cloverleaf_repeated_timesteps_analyzed_once(self):
        """N>1 timesteps: analysis/scheduling once per distinct chain shape,
        independent of N — every further step is a cache hit."""
        def run(steps):
            app = CloverLeaf2D(28, 20, summary_every=0)
            sess = Session("ooc", num_tiles=3, capacity_bytes=float("inf"))
            app.run(sess, steps=steps)
            return sess.plan_stats(), sess.chains_flushed

        st4, chains4 = run(4)
        st6, chains6 = run(6)
        # distinct chain shapes don't grow with step count
        assert st6["plan_misses"] == st4["plan_misses"]
        assert st6["plan_hits"] == st4["plan_hits"] + (chains6 - chains4)
        assert st6["plan_hits"] > 0
