"""HLO analyzer: scan-trip-count correction + collective wire-byte model,
validated against a freshly compiled module with KNOWN analytic costs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo_analysis import analyze_hlo_text, parse_hlo, _multipliers


@pytest.fixture(scope="module")
def scan_module_text():
    N, L = 64, 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    x = jax.ShapeDtypeStruct((N, N), jnp.float32)
    w = jax.ShapeDtypeStruct((N, N), jnp.float32)
    return jax.jit(f).lower(x, w).compile().as_text(), N, L


def test_trip_count_multiplier(scan_module_text):
    text, N, L = scan_module_text
    a = analyze_hlo_text(text, total_devices=1)
    expect = 2 * N * N * N * L  # L matmuls, counted L times (not once!)
    assert a["dot_flops"] == pytest.approx(expect, rel=1e-6), (
        f"scan correction broken: {a['dot_flops']} vs {expect}")


def test_multiplier_graph(scan_module_text):
    text, N, L = scan_module_text
    mod = parse_hlo(text)
    mult = _multipliers(mod)
    assert mult[mod.entry] == 1.0
    assert max(mult.values()) >= L  # the while body reached L


def test_memory_bytes_reasonable(scan_module_text):
    text, N, L = scan_module_text
    a = analyze_hlo_text(text, total_devices=1)
    # at least L reads+writes of the carry, at most a loose upper bound
    lower = L * 2 * N * N * 4
    assert lower <= a["hbm_bytes"] <= 100 * lower


def test_collective_wire_bytes():
    # hand-written module text exercises the ring conventions
    text = """HloModule m

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %cp = f32[64]{0} copy(%ar)
}
"""
    a = analyze_hlo_text(text, total_devices=16)
    expect = 64 * 4 * 2 * (8 - 1) / 8  # ring all-reduce, group 8
    assert a["collective_bytes_ici"] == pytest.approx(expect)
    assert a["collective_bytes_dcn"] == 0.0


def test_dcn_bucketing():
    text = """HloModule m

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), channel_id=1, replica_groups=[256,2]<=[512], to_apply=%add
  ROOT %cp = f32[64]{0} copy(%ar)
}
"""
    a = analyze_hlo_text(text, total_devices=512)
    assert a["collective_bytes_dcn"] > 0  # group size 2 -> pod/DCN bucket
    assert a["collective_bytes_ici"] == 0.0
