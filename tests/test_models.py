"""Per-arch smoke tests (reduced configs, CPU): forward/train-step/decode —
shapes + finiteness; plus algorithmic equivalence tests (SSD, MLA, flash)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config, shape_cells
from repro.models import decode_step, forward, init_params, loss_fn
from repro.models.transformer import init_cache
from repro.train import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _inputs(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["patches"] = jax.random.normal(KEY, (B, cfg.vision_patches, cfg.d_model))
    if cfg.encdec:
        kw["enc_inputs"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, KEY)
        tokens, kw = _inputs(cfg)
        logits = forward(params, cfg, tokens, **kw)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_one_train_step(self, arch):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, KEY)
        tokens, kw = _inputs(cfg)
        labels = jnp.roll(tokens, -1, 1)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, tokens, labels, **kw))(params)
        assert np.isfinite(float(loss))
        state = adamw_init(params)
        new_params, state, metrics = adamw_update(
            params, grads, state, AdamWConfig(peak_lr=1e-3, warmup_steps=1))
        assert np.isfinite(float(metrics["grad_norm"]))
        # parameters actually moved
        moved = jax.tree.reduce(
            lambda acc, ab: acc + float(jnp.sum(jnp.abs(ab))),
            jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, new_params),
            0.0)
        assert moved > 0

    def test_decode_steps(self, arch):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, KEY)
        tokens, kw = _inputs(cfg)
        cache = init_cache(cfg, B, 8, enc_len=S)
        if cfg.encdec:
            cache["enc_k"] = jnp.ones_like(cache["enc_k"]) * 0.01
            cache["enc_v"] = jnp.ones_like(cache["enc_v"]) * 0.01
        for t in range(3):
            logits, cache = decode_step(params, cfg, cache, tokens[:, t])
            assert logits.shape == (B, cfg.vocab_size)
            assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(cache["len"]) == 3


class TestFullConfigsAnalytic:
    """Full configs are exercised via the dry-run; here: analytic sanity."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("llama3_2_1b", 1.0e9, 1.6e9),
        ("tinyllama_1_1b", 0.9e9, 1.4e9),
        # 47B with our uniform SwiGLU substrate; the real granite-34b-code is
        # GPT-BigCode (2-proj MLP) => 34B.  Noted in DESIGN.md.
        ("granite_34b", 30e9, 50e9),
        ("qwen2_5_14b", 12e9, 17e9),
        ("qwen3_moe_30b_a3b", 26e9, 34e9),
        ("deepseek_v2_lite_16b", 13e9, 19e9),
        ("mamba2_1_3b", 1.0e9, 1.7e9),
        ("zamba2_1_2b", 1.0e9, 1.7e9),
        ("internvl2_76b", 66e9, 84e9),
    ])
    def test_param_counts(self, arch, lo, hi):
        assert lo <= get_config(arch).param_count() <= hi

    def test_moe_active_params(self):
        cfg = get_config("qwen3_moe_30b_a3b")
        assert cfg.active_param_count() < 0.2 * cfg.param_count()

    def test_cells_assignment(self):
        # 8 archs x 3 shapes + 2 archs x 4 shapes = 32 runnable cells
        total = sum(len(shape_cells(a)) for a in ARCH_IDS)
        assert total == 32
        assert len(shape_cells("mamba2_1_3b")) == 4
        assert len(shape_cells("llama3_2_1b")) == 3


class TestPrefillDecodeConsistency:
    """Greedy decode after teacher-forced prefill == full forward argmax."""

    @pytest.mark.parametrize("arch", ["llama3_2_1b", "mamba2_1_3b"])
    def test_incremental_equals_full(self, arch):
        cfg = get_reduced_config(arch)
        params = init_params(cfg, KEY)
        tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
        full_logits = forward(params, cfg, tokens)
        cache = init_cache(cfg, 1, 8)
        step_logits = []
        for t in range(8):
            lg, cache = decode_step(params, cfg, cache, tokens[:, t])
            step_logits.append(lg)
        inc = jnp.stack(step_logits, axis=1)
        np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                                   np.asarray(inc, np.float32),
                                   rtol=2e-2, atol=2e-3)
