"""Device-mesh sharded execution (the ooc-sharded backend): decomposition
geometry, halo ops in the Plan IR, per-device interpreters, exchange
accounting, and the Session surface (mesh=, context manager, tune meshes)."""
import threading

import jax
import numpy as np
import pytest

from repro.apps import CloverLeaf2D
from repro.core import (
    DeviceMesh,
    HaloExchange,
    MeshError,
    Plan,
    Session,
    parse_mesh,
)
from repro.core.mesh import shard_geometries
from repro.core.sharded import ShardingError, split_segments

LIVE_FIELDS = ("density0", "energy0", "pressure", "viscosity", "soundspeed",
               "xvel0", "yvel0", "volume", "xarea", "yarea")


def drive(rt, app, steps=1):
    """Init + timesteps without the cyclic flag or dt chain breakers, so
    every dataset's home copy is fully defined (no elided temporaries)."""
    app.record_init(rt)
    rt.flush()
    for _ in range(steps):
        app.dt = 1e-4
        app.record_timestep(rt)
        rt.flush()


def assert_all_dats_equal(ref_app, app):
    for name in ref_app.dats:
        np.testing.assert_array_equal(
            ref_app.d(name).materialize(), app.d(name).materialize(),
            err_msg=name)


# -- mesh / geometry ---------------------------------------------------------------


class TestMesh:
    def test_parse_specs(self):
        assert parse_mesh(None) is None
        assert parse_mesh(4) == DeviceMesh.sim(4)
        assert parse_mesh("sim:4") == DeviceMesh.sim(4)
        assert parse_mesh("jax:2") == DeviceMesh(2, kind="jax")
        m = DeviceMesh.sim(3)
        assert parse_mesh(m) is m
        with pytest.raises(MeshError):
            parse_mesh("nope:4")
        with pytest.raises(MeshError):
            parse_mesh("sim:0")

    def test_geometries_partition_and_skirts(self):
        geos = shard_geometries(34, 4, skirt=5)
        assert [(g.lo, g.hi) for g in geos] == [(0, 9), (9, 18), (18, 26),
                                                (26, 34)]
        assert geos[0].skirt_lo == 0 and geos[0].skirt_hi == 5
        assert geos[1].skirt_lo == 5 and geos[1].skirt_hi == 5
        assert geos[-1].skirt_hi == 0
        assert geos[2].to_local(geos[2].lo) == 5
        with pytest.raises(MeshError):
            shard_geometries(3, 4, skirt=1)

    def test_jax_mesh_needs_devices(self):
        with pytest.raises(MeshError):
            DeviceMesh.sim(2).jax_mesh()
        if len(jax.devices()) >= 2:
            mesh = DeviceMesh.devices(2).jax_mesh()
            assert mesh.shape["shard"] == 2


class TestSegmentation:
    def test_budget_split(self):
        app = CloverLeaf2D(24, 24, summary_every=0)
        rt = Session("reference")
        app.record_init(rt)
        rt.queue.clear()
        app.record_timestep(rt)
        loops = list(rt.queue)
        segs = split_segments(loops, dim=1, budget=6)
        assert sum(len(s) for s in segs) == len(loops)
        from repro.core.sharded import loop_halo_extent

        for seg in segs:
            assert sum(loop_halo_extent(lp, 1) for lp in seg) <= 6

    def test_loop_wider_than_budget_raises(self):
        app = CloverLeaf2D(24, 24, summary_every=0)
        rt = Session("reference")
        app.record_timestep(rt)
        with pytest.raises(ShardingError):
            split_segments(list(rt.queue), dim=1, budget=1)


# -- the backend -------------------------------------------------------------------


class TestShardedBackend:
    def test_one_device_mesh_bit_identical_to_ooc(self):
        """Acceptance: ooc-sharded on a 1-device mesh == ooc, bitwise,
        through the full app driver (cyclic + dt breakers + summaries)."""
        ref = CloverLeaf2D(40, 32, summary_every=2)
        s_ref = ref.run(Session("ooc", num_tiles=4,
                                capacity_bytes=float("inf")), steps=2)
        app = CloverLeaf2D(40, 32, summary_every=2)
        s = app.run(Session("ooc-sharded", num_tiles=4,
                            capacity_bytes=float("inf")), steps=2)
        assert_all_dats_equal(ref, app)
        assert s_ref == s

    def test_virtual_mesh_bit_identical_to_ooc(self):
        """Acceptance: a 4-virtual-device data-plane run reproduces the
        unsharded executor bitwise (redundant skirt compute is the same
        arithmetic on the same values)."""
        ref = CloverLeaf2D(40, 32, summary_every=0)
        drive(Session("ooc", num_tiles=4, capacity_bytes=float("inf")), ref,
              steps=2)
        app = CloverLeaf2D(40, 32, summary_every=0)
        sess = Session("ooc-sharded", mesh="sim:4", num_tiles=4,
                       capacity_bytes=float("inf"))
        drive(sess, app, steps=2)
        assert_all_dats_equal(ref, app)

    def test_virtual_mesh_matches_reference_runtime(self):
        """Acceptance: the 4-device data plane matches the eager NumPy
        oracle within the usual JAX-vs-NumPy float32 tolerance, including
        cross-shard (min exact / sum combined) reductions."""
        ref = CloverLeaf2D(40, 32, summary_every=2)
        s_ref = ref.run(Session("reference"), steps=2)
        app = CloverLeaf2D(40, 32, summary_every=2)
        sess = Session("ooc-sharded", mesh="sim:4", num_tiles=4,
                       capacity_bytes=float("inf"))
        s = app.run(sess, steps=2)
        for name in LIVE_FIELDS:
            np.testing.assert_allclose(
                ref.d(name).interior(), app.d(name).interior(),
                rtol=1e-4, atol=1e-5, err_msg=name)
        for k in s_ref:
            np.testing.assert_allclose(s_ref[k], s[k], rtol=1e-3)

    @pytest.mark.skipif(len(jax.devices()) < 4,
                        reason="needs 4 XLA devices (conftest forces 8)")
    def test_jax_mesh_ppermute_path_bit_identical(self):
        """Real-device mesh: the exchange runs the exchange_halos ppermute
        collective under shard_map and still reproduces ooc bitwise."""
        ref = CloverLeaf2D(40, 32, summary_every=0)
        drive(Session("ooc", num_tiles=4, capacity_bytes=float("inf")), ref)
        app = CloverLeaf2D(40, 32, summary_every=0)
        sess = Session("ooc-sharded", mesh="jax:4", num_tiles=4,
                       capacity_bytes=float("inf"))
        drive(sess, app)
        assert sess.backend.exchange_path == "ppermute"
        assert_all_dats_equal(ref, app)
        st = sess.transfer_stats()
        assert st["halo_messages"] == sess.backend.halo_stats.messages
        assert st["halo_bytes"] == sess.backend.halo_stats.bytes

    def test_ledger_model_agrees_with_achieved_halo_stats(self):
        """Acceptance: halo message/byte counts from the per-device ledger
        plans equal the collective runtime's achieved HaloExchangeStats."""
        app = CloverLeaf2D(40, 32, summary_every=0)
        sess = Session("ooc-sharded", mesh="sim:4", num_tiles=4,
                       capacity_bytes=float("inf"))
        drive(sess, app)
        st = sess.transfer_stats()
        assert st["halo_messages"] > 0 and st["halo_bytes"] > 0
        assert st["halo_messages"] == sess.backend.halo_stats.messages
        assert st["halo_bytes"] == sess.backend.halo_stats.bytes

    def test_mesh_on_plain_ooc_backend_routes_to_sharded(self):
        from repro.core.sharded import ShardedOutOfCoreExecutor

        sess = Session("ooc", mesh=2)
        assert isinstance(sess.backend, ShardedOutOfCoreExecutor)
        sess.close()

    def test_plan_cache_hits_across_steps(self):
        """Localised loops must replay cached per-device plans: a repeated
        identical timestep pays no re-analysis.  (Sweep direction alternates
        per step, so step 3 is the first structural repeat of step 1.)"""
        app = CloverLeaf2D(40, 32, summary_every=0)
        sess = Session("ooc-sharded", mesh="sim:2", num_tiles=3,
                       capacity_bytes=float("inf"))
        drive(sess, app, steps=3)
        assert sess.history[-1].plan_cache_hit
        assert sess.backend.plan_hit_rate > 0.3

    def test_too_many_devices_raises(self):
        app = CloverLeaf2D(12, 6, summary_every=0)
        sess = Session("ooc-sharded", mesh="sim:8", num_tiles=2,
                       capacity_bytes=float("inf"))
        with pytest.raises(MeshError):
            drive(sess, app)

    def test_threaded_transfer_bit_identical(self):
        """ooc-async (threaded staging workers) composed with a mesh still
        reproduces ooc bitwise — per-shard engines drain before the next
        shard runs, so the exchange/gather ordering holds."""
        ref = CloverLeaf2D(32, 24, summary_every=0)
        drive(Session("ooc", num_tiles=3, capacity_bytes=float("inf")), ref,
              steps=2)
        app = CloverLeaf2D(32, 24, summary_every=0)
        with Session("ooc-async", mesh="sim:3", num_tiles=3,
                     capacity_bytes=float("inf")) as sess:
            drive(sess, app, steps=2)
            assert_all_dats_equal(ref, app)

    def test_checkpoint_restore_resume_bit_identical(self):
        """A sharded run killed after checkpoint() resumes bitwise: restore
        resets the shard version tracking so locals re-scatter from the
        restored globals, and the manifest carries the inner executors'
        plan signatures."""
        import os
        import tempfile

        app = CloverLeaf2D(32, 24, summary_every=0)
        with Session("ooc-sharded", mesh="sim:3", num_tiles=3,
                     capacity_bytes=float("inf")) as sess:
            drive(sess, app, steps=1)
            with tempfile.TemporaryDirectory() as td:
                path = os.path.join(td, "ck.npz")
                manifest = sess.checkpoint(path)
                assert manifest["plan_signatures"]
                app.dt = 1e-4
                app.record_timestep(sess)
                sess.flush()
                after = {n: app.d(n).materialize().copy() for n in app.dats}
                sess.restore(path, datasets=list(app.dats.values()))
                app.step_count -= 1   # sweep direction rewinds with restore
                app.dt = 1e-4
                app.record_timestep(sess)
                sess.flush()
                for n in app.dats:
                    np.testing.assert_array_equal(
                        after[n], app.d(n).materialize(), err_msg=n)

    def test_app_mesh_knob(self):
        from repro.core.sharded import ShardedOutOfCoreExecutor

        app = CloverLeaf2D(24, 16, summary_every=0, mesh="sim:2")
        sess = app.make_session(num_tiles=2, capacity_bytes=float("inf"))
        assert isinstance(sess.backend, ShardedOutOfCoreExecutor)
        drive(sess, app)
        assert np.isfinite(app.d("density0").interior()).all()
        sess.close()


# -- plans, explain, tune ----------------------------------------------------------


class TestShardedPlans:
    def _session(self):
        app = CloverLeaf2D(40, 32, summary_every=0)
        sess = Session("sim", mesh="sim:4", num_tiles=4,
                       capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.queue.clear()
        app.dt = 1e-4
        app.record_timestep(sess)
        return app, sess

    def test_plan_per_device_with_halo_ops(self):
        _, sess = self._session()
        plans = sess.plan()
        assert {p.device for p in plans} == {0, 1, 2, 3}
        assert all(p.mesh_devices == 4 for p in plans)
        halos = [op for p in plans for op in p.ops
                 if isinstance(op, HaloExchange)]
        assert halos and all(op.messages > 0 and op.nbytes > 0
                             for op in halos)
        # Plan-level totals, ledger interpretation and ChainStats agree.
        total = sum(p.totals()["halo_messages"] for p in plans)
        sess.flush()
        assert total == sum(c.halo_messages for c in sess.history)

    def test_capacity_split_plans_match_execution(self):
        """When a shard-local segment doesn't fit fast memory, plan_chain
        must mirror run_chain's MemoryError split: the planned streams'
        totals equal what execution records."""
        def build(cap_frac):
            app = CloverLeaf2D(40, 32, summary_every=0)
            sess = Session("sim", mesh="sim:2",
                           capacity_bytes=app.total_bytes() * cap_frac)
            app.record_init(sess)
            sess.queue.clear()
            app.dt = 1e-4
            app.record_timestep(sess)
            return sess

        sess = build(0.1)   # tight: forces per-shard chain splitting
        plans = sess.plan()
        planned_halo = sum(p.totals()["halo_messages"] for p in plans)
        planned_computes = sum(p.counts()["computes"] for p in plans)
        sess.flush()
        assert planned_halo == sum(c.halo_messages for c in sess.history)
        assert planned_computes == sum(
            c.op_counts["computes"] for c in sess.history)

    def test_plan_json_v3_roundtrip(self):
        _, sess = self._session()
        for p in sess.plan():
            back = Plan.from_json(p.to_json())
            assert back == p

    def test_explain_per_device_makespans(self):
        """Acceptance: explain() on a sharded plan shows per-device
        makespans and nonzero halo message/byte counts."""
        _, sess = self._session()
        text = sess.explain()
        assert "device 0/4" in text and "device 3/4" in text
        assert "halo-exchange" in text
        assert "mesh summary: per-device makespans" in text
        assert "modelled makespan (device" in text

    def test_tune_enumerates_shard_counts(self):
        _, sess = self._session()
        res = sess.tune(meshes=[1, 2, 4], num_tiles=(4,), num_slots=(3,),
                        tiled_dims=(0,))
        meshes = {r["mesh"] for r in res.rows}
        assert {"sim:2", "sim:4"} <= meshes
        assert res.best_makespan <= res.baseline_makespan

    def test_sim_and_data_plane_model_identically(self):
        """The sim backend and the data plane interpret the same sharded
        instruction streams: modelled makespans and halo counters match."""
        app1 = CloverLeaf2D(40, 32, summary_every=0)
        sim = Session("sim", mesh="sim:2", num_tiles=4,
                      capacity_bytes=float("inf"))
        drive(sim, app1)
        app2 = CloverLeaf2D(40, 32, summary_every=0)
        real = Session("ooc-sharded", mesh="sim:2", num_tiles=4,
                       capacity_bytes=float("inf"))
        drive(real, app2)
        assert len(sim.history) == len(real.history)
        for a, b in zip(sim.history, real.history):
            assert a.halo_messages == b.halo_messages
            assert a.halo_bytes == b.halo_bytes
            assert a.modelled_s == pytest.approx(b.modelled_s)


# -- session lifecycle -------------------------------------------------------------


class TestSessionContextManager:
    def test_exit_closes_worker_threads(self):
        app = CloverLeaf2D(24, 16, summary_every=0)
        with Session("ooc-async", num_tiles=2,
                     capacity_bytes=float("inf")) as sess:
            drive(sess, app)
            workers = [t for t in threading.enumerate()
                       if t.name.startswith("transfer-")]
            assert workers, "threaded engine should have spawned workers"
            backend = sess.backend
        assert backend.transfer._workers == {}
        for t in workers:
            t.join(timeout=5)
            assert not t.is_alive()

    def test_exception_drops_queue_without_executing(self):
        """A with-body that dies mid-recording must NOT execute the
        half-recorded queue during unwinding (and must still release the
        backend)."""
        app = CloverLeaf2D(16, 8, summary_every=0)
        with pytest.raises(RuntimeError, match="boom"):
            with Session("ooc", num_tiles=2,
                         capacity_bytes=float("inf")) as sess:
                app.record_init(sess)
                raise RuntimeError("boom")
        assert not sess.queue
        assert sess.chains_flushed == 0
        # Home copies untouched: density0 still zeros.
        assert not app.d("density0").interior().any()

    def test_enter_returns_session_and_flushes_on_exit(self):
        app = CloverLeaf2D(16, 8, summary_every=0)
        with Session("ooc", num_tiles=2,
                     capacity_bytes=float("inf")) as sess:
            assert isinstance(sess, Session)
            app.record_init(sess)
            assert sess.queue
        assert not sess.queue          # __exit__ flushed
        assert sess.chains_flushed >= 1
