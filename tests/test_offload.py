"""Out-of-core LM serving (paper's technique on weights): streamed == resident."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models import decode_step, init_params
from repro.models.offload import StreamedDecoder
from repro.models.transformer import init_cache


def test_streamed_decode_matches_resident():
    cfg = get_reduced_config("llama3_2_1b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, T = 2, 6
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)

    cache_a = init_cache(cfg, B, T)
    cache_b = init_cache(cfg, B, T)
    streamer = StreamedDecoder(params, cfg, window=2)
    for t in range(T):
        la, cache_a = decode_step(params, cfg, cache_a, tokens[:, t])
        lb, cache_b = streamer.decode(cache_b, tokens[:, t])
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=1e-4, atol=1e-5)

    # the out-of-core claim: device-resident weights bounded by the window,
    # not by the model (2 of 2 layers here, but ratio < full for real L)
    assert streamer.stats.uploaded_bytes > 0
    assert streamer.stats.modelled_step_s > 0


def test_streaming_window_bounds_memory():
    cfg = get_reduced_config("llama3_2_1b").with_(num_layers=6)
    params = init_params(cfg, jax.random.PRNGKey(1))
    streamer = StreamedDecoder(params, cfg, window=2)
    cache = init_cache(cfg, 1, 4)
    tok = jnp.zeros((1,), jnp.int32)
    _, cache = streamer.decode(cache, tok)
    total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(streamer.host_blocks))
    assert streamer.device_resident_bytes() < total / 2
    assert len(streamer._ring) <= 2
