"""Paper Figures 7–9: P100 problem scaling with explicit memory management,
and the Cyclic / Prefetch optimisation ablations (PCIe vs NVLink).

The 3-slot executor RUNS for real (data plane on CPU); per-transfer and
per-tile timings come from the calibrated P100 hardware models, composed by
the ledger's 3-stream timeline — so overlap quality (the thing the paper
measures) is emergent, not assumed.

Headline paper claims reproduced:
  * beyond 16 GB, NVLink keeps ~84% of baseline bandwidth on CloverLeaf and
    ~100% on OpenSBLI (enough compute per byte when tiling across 3 steps);
    PCIe keeps ~48% (2D) / 68% (3D) — transfer-bound;
  * Cyclic (skip write-first downloads) matters most on PCIe/2D;
  * Prefetch matters most at small sizes (few tiles).
"""
from __future__ import annotations

from typing import Dict, List

from repro.apps import CloverLeaf2D, CloverLeaf3D, OpenSBLI
from repro.core import P100_NVLINK, P100_PCIE, Session

CAPACITY = 8 << 20  # scaled-down 16 GB

APPS = {
    "cloverleaf2d": (lambda nx: CloverLeaf2D(nx, nx, summary_every=10), 470e9, 2),
    "cloverleaf3d": (lambda nx: CloverLeaf3D(nx, nx, nx, summary_every=10), 380e9, 2),
    "opensbli": (lambda nx: OpenSBLI(nx, chain_steps=3), 170e9, 1),
}


def _size_for(build, ratio: float) -> int:
    lo, hi = 8, 4096
    while lo < hi:
        mid = (lo + hi) // 2
        if build(mid).total_bytes() < ratio * CAPACITY:
            lo = mid + 1
        else:
            hi = mid
    return lo


def _drive(app, rt, steps: int, cyclic: bool) -> None:
    """Uniform driver: init chain (never cyclic), then the measured cyclic
    phase with the flag as requested (paper §4.1 ablation switch).  dt is
    fixed (simulate-only mode has no data plane), but the calc_dt loop is
    still recorded — it is the chain breaker that shapes the schedule."""
    app.record_init(rt)
    rt.flush()
    rt.cyclic = cyclic
    chain_steps = getattr(app, "chain_steps", 1)
    app.dt = 1e-4
    for s in range(steps):
        if hasattr(app, "_calc_dt"):  # CloverLeaf: dt reduction chain breaker
            app._ideal_gas(rt, "density0", "energy0", "_dt")
            app._viscosity(rt)
            app._calc_dt(rt)
            rt.flush()
        app.record_timestep(rt)
        if (s + 1) % chain_steps == 0:
            rt.flush()
    rt.flush()


def run_one(app_name: str, ratio: float, link: str, *, cyclic: bool,
            prefetch: bool, steps: int = 2) -> Dict:
    build, fast_bw, _ = APPS[app_name]
    base_hw = P100_PCIE if link == "pcie" else P100_NVLINK
    hw = base_hw.with_(fast_capacity=CAPACITY, fast_bw=fast_bw, dd_bw=509.7e9)
    nx = _size_for(build, ratio)
    app = build(nx)
    rt = Session("sim", hw=hw, prefetch=prefetch)
    _drive(app, rt, steps, cyclic)
    # drop the init chain from the bandwidth average (paper measures the
    # cyclic main phase)
    hist = rt.history[1:] if len(rt.history) > 1 else rt.history
    tot_b = sum(c.loop_bytes for c in hist)
    tot_t = sum(c.modelled_s for c in hist)
    bw = tot_b / tot_t if tot_t else 0.0
    plan = rt.plan_stats()
    return {"app": app_name, "ratio": ratio, "link": link, "cyclic": cyclic,
            "prefetch": prefetch, "avg_bw_gbs": bw / 1e9,
            "baseline_gbs": fast_bw / 1e9,
            "efficiency": bw / fast_bw,
            "tiles": max(c.num_tiles for c in rt.history),
            "prefetch_hits": sum(c.prefetch_hits for c in rt.history),
            "plan_hits": plan["plan_hits"],
            "plan_misses": plan["plan_misses"],
            "plan_hit_rate": plan["plan_hit_rate"],
            "plan_time_s": plan["plan_time_s"]}


def run(ratios=(0.5, 1.5, 3.0)) -> List[Dict]:
    rows = []
    for app in APPS:
        for link in ("pcie", "nvlink"):
            for ratio in ratios:
                rows.append(run_one(app, ratio, link, cyclic=True, prefetch=True))
    # Fig 8/9 ablations at 3x capacity
    for app in ("cloverleaf2d", "cloverleaf3d"):
        for link in ("pcie", "nvlink"):
            for cyc, pre in ((False, False), (True, False), (True, True)):
                rows.append(run_one(app, 3.0, link, cyclic=cyc, prefetch=pre))
    return rows


def main():
    rows = run()
    print("app,ratio,link,cyclic,prefetch,avg_bw_gbs,efficiency")
    for r in rows:
        print(f"{r['app']},{r['ratio']},{r['link']},{int(r['cyclic'])},"
              f"{int(r['prefetch'])},{r['avg_bw_gbs']:.0f},{r['efficiency']:.2f}")
    return rows


if __name__ == "__main__":
    main()
