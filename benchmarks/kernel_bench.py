"""Pallas kernel benchmarks: wall time (interpret mode on CPU — a correctness
path, not a perf claim) + the HBM-traffic model for the chain2d fused kernel
(the paper's cache-blocking win at the VMEM level, derived analytically from
BlockSpec geometry: this is the number that matters for the TPU target).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import chain2d, stencil2d, stencil3d
from repro.kernels.ref import chain2d_ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def chain_traffic_model(H: int, W: int, K: int, block_rows: int,
                        dtype_bytes: int = 4) -> Dict:
    """HBM bytes for K sweeps: unfused (2 passes/sweep) vs fused chain kernel
    (1 read + 1 write total, plus the halo skirt re-reads per block)."""
    unfused = K * 2 * H * W * dtype_bytes
    n_blocks = -(-H // block_rows)
    fused_read = n_blocks * (block_rows + 2 * K) * (W + 2 * K) * dtype_bytes
    fused = fused_read + H * W * dtype_bytes
    redundant_compute = ((block_rows + 2 * K) / block_rows - 1)
    return {
        "unfused_bytes": unfused,
        "fused_bytes": fused,
        "traffic_reduction": unfused / fused,
        "redundant_compute_frac": redundant_compute,
    }


def run() -> List[Dict]:
    rng = np.random.RandomState(0)
    rows = []
    c2 = jnp.asarray([0.5, 0.125, 0.125], jnp.float32)
    c3 = jnp.asarray([0.4, 0.1, 0.1, 0.1], jnp.float32)

    x2 = jnp.asarray(rng.rand(258, 258), jnp.float32)
    rows.append({"name": "stencil2d_256", "us": _time(stencil2d, x2, c2)})
    x3 = jnp.asarray(rng.rand(34, 66, 66), jnp.float32)
    rows.append({"name": "stencil3d_32", "us": _time(stencil3d, x3, c3)})
    for K in (2, 4, 8):
        xk = jnp.asarray(rng.rand(256 + 2 * K, 256 + 2 * K), jnp.float32)
        us_fused = _time(lambda x: chain2d(x, c2, K), xk)
        us_ref = _time(lambda x: chain2d_ref(x, c2, K), xk)
        m = chain_traffic_model(4096, 4096, K, block_rows=256)
        rows.append({
            "name": f"chain2d_K{K}", "us": us_fused, "ref_us": us_ref,
            "traffic_reduction_4k": round(m["traffic_reduction"], 2),
            "redundant_compute": round(m["redundant_compute_frac"], 3),
        })
    return rows


def main():
    for r in run():
        extra = ",".join(f"{k}={v}" for k, v in r.items() if k not in ("name", "us"))
        print(f"{r['name']},{r['us']:.0f}us,{extra}")
    return run()


if __name__ == "__main__":
    main()
