"""§Perf iteration driver: re-lowers the hillclimbed cells in their BEFORE
and AFTER configurations and prints the roofline-term deltas side by side
(the numbers quoted in EXPERIMENTS.md §Perf).

This recomputes everything from scratch (each variant is a fresh
lower+compile on the 256-chip mesh), so it takes a few minutes:

  PYTHONPATH=src python -m benchmarks.perf_iterations
"""
from __future__ import annotations

import os
import sys


def main():
    # Must run in a fresh interpreter state: dryrun sets the 512-device flag.
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax  # noqa: F401  (locks device count)

    from repro.analysis.roofline import roofline_terms, _fmt_s
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell
    from repro.models.config import SHAPES

    out = "reports/perf"
    os.makedirs(out, exist_ok=True)

    cells = [
        # (label, arch, shape, kwargs-variants {tag: flags})
        ("Cell C (llama train): TP16+FSDP baseline vs no-TP vs no-FSDP",
         "llama3_2_1b", "train_4k",
         {"baseline": {}, "notp": {"tp": False}, "nofsdp": {"fsdp": False}}),
        ("Cell A (qwen prefill): seq-par attention is now the default; "
         "the BEFORE number requires reverting transformer._attn_sublayer — "
         "recorded in EXPERIMENTS.md from reports/perf artifacts",
         "qwen2_5_14b", "prefill_32k", {"baseline": {}}),
    ]

    for label, arch, shape_name, variants in cells:
        print(f"\n== {label} ==")
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        for tag, flags in variants.items():
            res, compiled = run_cell(
                arch, shape_name, False, out, tag=f"_iter_{tag}", **flags)
            hlo = compiled.as_text()
            t = roofline_terms(hlo, res["devices"], cfg, shape)
            peak = res["memory"]["peak_estimate_per_device"] / 1e9
            print(f"  {tag:10s} compute={_fmt_s(t['compute_s'])} "
                  f"memory={_fmt_s(t['memory_s'])} "
                  f"collective={_fmt_s(t['collective_s'])} "
                  f"useful={t.get('useful_ratio', 0):.2f} "
                  f"frac={t.get('roofline_fraction', 0) * 100:.1f}% "
                  f"peak={peak:.1f}GB")
            del compiled
    print("\nartifacts -> reports/perf/*_iter_*.json|hlo.txt")
    return 0


if __name__ == "__main__":
    sys.exit(main())
