"""Benchmark orchestrator: one section per paper table/figure.

  figs 3-6  -> paper_scaling  (KNL flat/cache/tiled + hit rates)
  figs 7-9  -> gpu_scaling    (P100 explicit 3-slot streaming + ablations)
  fig 11    -> um_scaling     (unified-memory model)
  kernels   -> kernel_bench   (Pallas stencil kernels + VMEM-chain model)

Prints ``name,value,derived`` CSV lines; writes reports/bench_results.json.

Flags:
  ``--tune``      add the Plan-IR autotuner section (sim-costed config sweep
                  on the transfer-bound CloverLeaf2D setup)
  ``--simulate``  sim-mode smoke only: plan/explain/JSON round-trip + (with
                  ``--tune``) the tuner, on a small grid, no data plane and
                  no Pallas — the CI guard against planner/tuner regressions.
                  Writes reports/bench_sim.json instead.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def plan_cache_bench(steps: int = 8):
    """Chain-plan memoisation on repeated CloverLeaf2D timesteps: dependency
    analysis + tile scheduling run once per distinct chain shape; every
    further step replays a cached plan.  Reports the hit rate and the
    schedule-construction time the cache amortises."""
    from repro.apps import CloverLeaf2D
    from repro.core import Session

    app = CloverLeaf2D(48, 32, summary_every=0)
    rt = Session("ooc", num_tiles=4, capacity_bytes=float("inf"))
    t0 = time.perf_counter()
    app.run(rt, steps=steps)
    wall = time.perf_counter() - t0
    st = rt.plan_stats()
    misses = max(st["plan_misses"], 1)
    avg_plan = st["plan_time_s"] / misses
    return {
        "steps": steps,
        "chains": rt.chains_flushed,
        "plan_hits": st["plan_hits"],
        "plan_misses": st["plan_misses"],
        "plan_hit_rate": st["plan_hit_rate"],
        "plan_time_s": st["plan_time_s"],
        "plan_time_per_chain_s": avg_plan,
        "plan_time_saved_s": avg_plan * st["plan_hits"],
        "wall_s": wall,
    }


def transfer_bench(steps: int = 2):
    """Transfer engine + codecs on a real (data-plane) CloverLeaf2D run:
    identity vs fp16 vs shuffle-rle on the host<->device path, and the
    threaded engine's queue-wait.  The ledger charges post-codec wire bytes;
    on a transfer-bound link (PCIe model scaled to the bench size, so the
    slow link — not latency or compute — is the critical path, as it is at
    the paper's real scale) the fp16/rle rows' modelled makespans show
    compressed traffic paying off."""
    from repro.apps import CloverLeaf2D
    from repro.core import P100_PCIE, Session

    hw = P100_PCIE.with_(link_latency=1e-6, up_bw=2e9, down_bw=2e9)
    rows = []
    for backend, codec in (("ooc", "identity"), ("ooc", "fp16"),
                           ("ooc", "shuffle-rle"), ("ooc-async", "identity")):
        app = CloverLeaf2D(48, 32, summary_every=0)
        rt = Session(backend, hw=hw, num_tiles=4, capacity_bytes=float("inf"),
                     codec=codec)
        t0 = time.perf_counter()
        app.run(rt, steps=steps)
        rt.flush()
        wall = time.perf_counter() - t0
        st = rt.transfer_stats()
        rt.close()   # stop ooc-async worker threads before the next row
        rows.append({
            "backend": backend, "codec": codec, "mode": st["mode"],
            "bytes_moved_raw": st["bytes_up_raw"] + st["bytes_down_raw"],
            "bytes_moved_wire": st["bytes_moved_wire"],
            "compression_ratio": st["compression_ratio"],
            "queue_wait_s": st["queue_wait_s"],
            "modelled_s": sum(c.modelled_s for c in rt.history),
            "wall_s": wall,
        })
    return rows


def _transfer_bound_session(nx=48, ny=32, num_tiles=4, capacity_frac=0.5):
    """One recorded CloverLeaf2D timestep on a slow-link model with fast
    memory sized so the chain *must* tile — the setup where plan choices
    actually move the modelled makespan."""
    from repro.apps import CloverLeaf2D
    from repro.core import P100_PCIE, Session

    hw = P100_PCIE.with_(link_latency=1e-6, up_bw=2e9, down_bw=2e9)
    app = CloverLeaf2D(nx, ny, summary_every=0)
    sess = Session("sim", hw=hw, num_tiles=num_tiles,
                   capacity_bytes=app.total_bytes() * capacity_frac)
    app.record_init(sess)
    sess.queue.clear()
    app.dt = 1e-4
    app.record_timestep(sess)
    return app, sess


def tune_bench():
    """Autotune the transfer-bound setup via the sim interpreter: enumerate
    num_tiles x tiled_dim x num_slots (codec fixed lossless), cost each
    candidate's Plan IR, report the winner vs the default config."""
    app, sess = _transfer_bound_session()
    t0 = time.perf_counter()
    res = sess.tune()
    tune_s = time.perf_counter() - t0
    best = res.best
    return {
        "candidates": len(res.rows),
        "feasible": sum(1 for r in res.rows if r["feasible"]),
        "baseline_modelled_s": res.baseline_makespan,
        "best_modelled_s": res.best_makespan,
        "speedup": res.speedup,
        "best": {"num_tiles": best.num_tiles, "num_slots": best.num_slots,
                 "tiled_dim": best.tiled_dim, "codec": best.codec},
        "tune_s": tune_s,
        "rows": res.rows,
    }


def disk_tier_bench():
    """Modelled disk-tier numbers (repro.core.store): the same CloverLeaf2D
    timestep costed with host RAM sized below the working set (FetchHome/
    SpillHome ops on stream 3) across disk bandwidths, vs. the host-resident
    baseline.  Shows the paper's thesis one level down: with enough disk
    bandwidth the spill traffic hides behind the host<->device link."""
    from repro.apps import CloverLeaf2D
    from repro.core import P100_PCIE, Session

    base_hw = P100_PCIE.with_(link_latency=1e-6, up_bw=2e9, down_bw=2e9)
    rows = []
    for label, disk_bw, oversub in (("host-resident", None, False),
                                    ("disk 0.5 GB/s", 0.5e9, True),
                                    ("disk 2 GB/s", 2e9, True),
                                    ("disk 8 GB/s", 8e9, True)):
        app = CloverLeaf2D(48, 32, summary_every=0)
        hw = base_hw
        if oversub:
            hw = base_hw.with_(host_capacity=app.total_bytes() * 0.5,
                               disk_bw=disk_bw, disk_latency=50e-6)
        sess = Session("sim", hw=hw, num_tiles=4,
                       capacity_bytes=float("inf"))
        app.record_init(sess)
        sess.queue.clear()
        app.dt = 1e-4
        app.record_timestep(sess)
        sess.flush()
        ops = {k: sum(c.op_counts.get(k, 0) for c in sess.history)
               for k in ("home_fetches", "home_spills")}
        rows.append({
            "config": label,
            # None, not inf: bare Infinity is not valid strict JSON
            "host_capacity": hw.host_capacity if oversub else None,
            "disk_bw": disk_bw,
            "modelled_s": sum(c.modelled_s for c in sess.history),
            "disk_read": sum(c.disk_read for c in sess.history),
            "disk_written": sum(c.disk_written for c in sess.history),
            "ops": ops,
        })
    base = rows[0]["modelled_s"]
    for r in rows:
        r["slowdown_vs_resident"] = r["modelled_s"] / base if base else 0.0
    return rows


def disk_smoke(tmpdir):
    """CI guard for the tiered-storage subsystem: (a) sim-mode planning with
    a HostModel small enough to force FetchHome/SpillHome ops; (b) a tiny
    ``chunked``-store data-plane run under ``tmpdir``, bit-identical to the
    same problem on a ``ram`` store, with nonzero achieved disk bytes."""
    import numpy as np

    from repro.apps import CloverLeaf2D
    from repro.core import P100_PCIE, Session, StoreConfig

    # (a) modelled: host oversubscribed -> disk ops in the plan + the ledger
    app = CloverLeaf2D(40, 24, summary_every=0)
    hw = P100_PCIE.with_(host_capacity=app.total_bytes() * 0.4)
    sim = Session("sim", hw=hw, num_tiles=4, capacity_bytes=float("inf"))
    app.record_init(sim)
    sim.flush()
    app.dt = 1e-4
    app.record_timestep(sim)
    plans = sim.plan()
    assert any(p.spill_home for p in plans), "HostModel overflow not planned"
    counts = {k: sum(p.counts()[k] for p in plans)
              for k in ("home_fetches", "home_spills")}
    assert counts["home_fetches"] > 0 and counts["home_spills"] > 0, counts
    sim.flush()
    sim_disk = sum(c.disk_read + c.disk_written for c in sim.history)
    assert sim_disk > 0, "ledger interpreter costed no disk traffic"

    # (b) data plane: tiny chunked store vs ram, bit-identical + real bytes
    def run(store, hw_):
        a = CloverLeaf2D(24, 16, summary_every=0, store=store)
        s = Session("ooc", hw=hw_, num_tiles=2, capacity_bytes=float("inf"))
        a.run(s, steps=1)
        return a, s

    ram_app, ram_sess = run(None, P100_PCIE)
    # Cache budget below the per-dataset chunk count so chunks really cycle
    # through disk (evict -> reload), not just spill once.
    cfg = StoreConfig(kind="chunked", directory=os.path.join(tmpdir, "ch"),
                      chunk_bytes=1 << 10, cache_bytes=2 << 10)
    ch_app, ch_sess = run(
        cfg, P100_PCIE.with_(host_capacity=ram_app.total_bytes() * 0.3))
    for name, dat in ram_app.dats.items():
        assert np.array_equal(ram_sess.fetch_raw(dat),
                              ch_sess.fetch_raw(ch_app.dats[name])), name
    st = ch_sess.transfer_stats()
    assert st["bytes_disk_written"] > 0, "chunked run spilled nothing"
    assert st["bytes_disk_read"] > 0, "chunked run never read disk back"
    return {
        "sim_modelled_disk_bytes": sim_disk,
        "sim_ops": counts,
        "chunked_disk_read": st["bytes_disk_read"],
        "chunked_disk_written": st["bytes_disk_written"],
        "bit_identical": True,
    }


def sharded_bench():
    """Modelled sharded scaling (the paper's §5.2 axis): one CloverLeaf2D
    timestep on the transfer-bound link, decomposed along dim 1 over
    1/2/4/8 virtual devices — each device drives its own host link, so the
    staged traffic divides across the mesh while the once-per-segment
    accumulated-depth halo exchanges add network time.  Reports the critical
    device's modelled makespan and the halo message/byte totals."""
    from repro.apps import CloverLeaf2D
    from repro.core import P100_PCIE, Session

    hw = P100_PCIE.with_(link_latency=1e-6, up_bw=2e9, down_bw=2e9)
    rows = []
    for n in (1, 2, 4, 8):
        app = CloverLeaf2D(48, 1024, summary_every=0)
        sess = Session("sim", hw=hw, num_tiles=4,
                       capacity_bytes=app.total_bytes() * 0.5,
                       mesh=f"sim:{n}")
        app.record_init(sess)
        sess.queue.clear()
        app.dt = 1e-4
        app.record_timestep(sess)
        sess.flush()
        hist = sess.history
        rows.append({
            "devices": n,
            "modelled_s": sum(c.modelled_s for c in hist),
            "halo_messages": sum(c.halo_messages for c in hist),
            "halo_bytes": sum(c.halo_bytes for c in hist),
            "uploaded": sum(c.uploaded for c in hist),
            "downloaded": sum(c.downloaded for c in hist),
        })
    base = rows[0]["modelled_s"]
    for r in rows:
        r["speedup_vs_1dev"] = base / r["modelled_s"] if r["modelled_s"] else 0.0
        r["parallel_efficiency"] = r["speedup_vs_1dev"] / r["devices"]
    return rows


def sharded_smoke():
    """CI guard for the device-mesh subsystem: (a) ooc-sharded on a 1-device
    mesh bit-identical to ooc; (b) a 4-virtual-device data-plane run
    bit-identical to ooc (redundant skirt compute is the same arithmetic);
    (c) per-device explain() with halo ops, and the ledger model's halo
    message/byte counts agreeing with the runtime's achieved stats."""
    import numpy as np

    from repro.apps import CloverLeaf2D
    from repro.core import Session

    def run(mesh):
        app = CloverLeaf2D(32, 24, summary_every=0)
        sess = Session("ooc-sharded" if mesh else "ooc", num_tiles=3,
                       capacity_bytes=float("inf"), mesh=mesh)
        app.record_init(sess)
        sess.flush()
        app.dt = 1e-4
        app.record_timestep(sess)
        sess.flush()
        return app, sess

    ref_app, _ = run(None)
    one_app, _ = run("sim:1")
    four_app, four = run("sim:4")
    for name, dat in ref_app.dats.items():
        assert np.array_equal(dat.materialize(),
                              one_app.dats[name].materialize()), \
            f"1-device mesh diverged on {name}"
        assert np.array_equal(dat.materialize(),
                              four_app.dats[name].materialize()), \
            f"4-device mesh diverged on {name}"
    st = four.transfer_stats()
    achieved = four.backend.halo_stats
    assert st["halo_messages"] == achieved.messages > 0, \
        (st["halo_messages"], achieved.messages)
    assert st["halo_bytes"] == achieved.bytes > 0
    # Sharded plans: per-device streams with halo ops + mesh summary.
    app = CloverLeaf2D(32, 24, summary_every=0)
    sim = Session("sim", mesh="sim:4", num_tiles=3,
                  capacity_bytes=float("inf"))
    app.record_init(sim)
    sim.queue.clear()
    app.dt = 1e-4
    app.record_timestep(sim)
    text = sim.explain()
    assert "device 0/4" in text and "halo-exchange" in text, "explain() lost"
    assert "mesh summary: per-device makespans" in text
    return {
        "bit_identical_1dev": True,
        "bit_identical_4dev": True,
        "halo_messages": st["halo_messages"],
        "halo_bytes": st["halo_bytes"],
        "explain_devices": 4,
    }


def sim_smoke():
    """Planner smoke (no data plane): plan + explain + JSON round-trip + a
    sim-interpreted flush on a small CloverLeaf2D chain.  Fails loudly on
    any planner/interpreter/serialisation regression."""
    from repro.core import Plan

    app, sess = _transfer_bound_session(nx=40, ny=24)
    plans = sess.plan()
    text = sess.explain()
    assert "modelled makespan" in text, "explain() lost its makespan line"
    for p in plans:
        back = Plan.from_json(p.to_json())
        assert back == p, "plan JSON round-trip is not lossless"
    sess.flush()
    chain = sess.history[-1]
    assert chain.op_counts == plans[-1].counts(), \
        "executed op counts diverge from the planned stream"
    return {
        "chains": len(plans),
        "ops": {k: sum(p.counts()[k] for p in plans)
                for k in plans[0].counts()},
        "modelled_s": sum(c.modelled_s for c in sess.history),
        "explain_lines": len(text.splitlines()),
    }


def verify_bench():
    """Static verification sweep: every plan ``build_plan`` emits for the
    three apps x {ram, spilled-host} tiers x {unsharded, sim:4 mesh} must
    verify clean, and the plan fuzzer must catch every mutation it emits
    (zero false negatives).  Returns per-config diagnostic counts; any
    error-severity diagnostic or fuzzer miss fails the CI gate."""
    from repro.apps.cloverleaf2d import CloverLeaf2D
    from repro.apps.cloverleaf3d import CloverLeaf3D
    from repro.apps.opensbli import OpenSBLI
    from repro.core import Session, check_mutations, verify_plans
    from repro.core.memory import P100_PCIE

    makers = {
        "cloverleaf2d": lambda: CloverLeaf2D(48, 32),
        "cloverleaf3d": lambda: CloverLeaf3D(16, 48, 10),
        "opensbli": lambda: OpenSBLI(24),
    }
    rows = []
    fuzz_total = fuzz_missed = 0
    for app_name, mk in makers.items():
        for mesh in (None, "sim:4"):
            for tier in ("ram", "spill"):
                app = mk()
                kw = dict(num_tiles=4)
                if tier == "spill":
                    kw["hw"] = P100_PCIE.with_(
                        host_capacity=app.total_bytes() * 0.4)
                else:
                    kw["capacity_bytes"] = float("inf")
                if mesh:
                    kw["mesh"] = mesh
                sess = Session("sim", **kw)
                app.record_init(sess)
                sess.queue.clear()
                app.dt = 1e-4
                app.record_timestep(sess)
                plans = sess.plan()
                res = verify_plans(plans)
                # Fuzz the first (head) plan of each unsharded config —
                # the mesh configs re-verify the same mutation classes
                # dozens of times for little extra coverage.
                if mesh is None:
                    fz = check_mutations(plans[0])
                    fuzz_total += len(fz)
                    fuzz_missed += sum(not v for v in fz.values())
                rows.append({
                    "config": f"{app_name}/{tier}"
                              + (f"/{mesh}" if mesh else ""),
                    "plans": len(plans), "ops": res.ops,
                    "errors": len(res.errors),
                    "warnings": len(res.warnings),
                    "diagnostics": [str(d) for d in res.diagnostics],
                })
    return {"configs": rows, "fuzz_mutations": fuzz_total,
            "fuzz_missed": fuzz_missed}


def serve_bench():
    """Serving-layer smoke: 8 tenant jobs admitted onto a shared ``sim:4``
    lane pool under each scheduling policy.  Asserts the admission oracle's
    predicted makespans against the ledger-achieved ones (same model, same
    plans — they must agree within tolerance), that cross-tenant plan
    sharing happened, that one preempt/checkpoint/restore cycle ran, and
    that an oversized job is rejected with a typed AdmissionError.  Returns
    per-policy throughput rows for ``reports/bench_results.json``."""
    import threading

    from repro.apps.cloverleaf2d import CloverLeaf2D
    from repro.serve import AdmissionError, StencilServer

    n_jobs = 8
    policies = []
    for policy in ("fifo", "sjf"):
        t0 = time.time()
        with StencilServer("sim:4", policy=policy,
                           capacity_bytes=4e6) as srv:
            sessions = [srv.session(f"t{i}", priority=i % 2)
                        for i in range(n_jobs)]
            # Deterministic preempt/restore demonstration: t0's first chain
            # boundary checkpoints its datasets, re-queues, restores.
            srv.preempt("t0")
            errs = []

            def work(i):
                try:
                    app = CloverLeaf2D(nx=32 + 4 * (i % 3), ny=32,
                                       summary_every=2)
                    try:
                        app.run(sessions[i], steps=2)
                    finally:
                        sessions[i].close()
                except BaseException as e:  # pragma: no cover - surfaced below
                    errs.append((i, repr(e)))

            threads = [threading.Thread(target=work, args=(i,))
                       for i in range(n_jobs)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs, f"serve bench tenant failures: {errs}"
            st = srv.stats()
        wall = time.time() - t0
        predicted = sum(t.predicted_s for t in st.tenants.values())
        achieved = sum(t.achieved_modelled_s for t in st.tenants.values())
        # Oracle and interpreter cost the same plans with the same ledger
        # model; warm-cache effects (prefetch hits, pinned reuse) are the
        # only divergence allowed.
        assert achieved <= predicted * 1.05 + 1e-9, \
            f"achieved {achieved:.6f}s exceeds oracle prediction {predicted:.6f}s"
        assert achieved >= predicted * 0.5, \
            f"achieved {achieved:.6f}s implausibly below prediction {predicted:.6f}s"
        assert st.cross_tenant_plan_hits > 0, "no cross-tenant plan sharing"
        assert st.preemptions >= 1, "preempt/restore cycle did not run"
        policies.append({
            "policy": policy,
            "jobs": n_jobs,
            "chains": st.jobs_completed,
            "wall_s": wall,
            "throughput_chains_per_s": st.jobs_completed / wall if wall else 0.0,
            "predicted_s": predicted,
            "achieved_modelled_s": achieved,
            "predicted_vs_achieved": achieved / predicted if predicted else 1.0,
            "mean_queue_wait_s": (sum(t.queue_wait_s
                                      for t in st.tenants.values()) / n_jobs),
            "cross_tenant_plan_hits": st.cross_tenant_plan_hits,
            "preemptions": st.preemptions,
            "plan_cache": st.plan_cache,
        })
    # Typed admission rejection on a pool too small for even one loop.
    with StencilServer("sim:1", capacity_bytes=1024) as srv:
        app = CloverLeaf2D(nx=64, ny=64, summary_every=1)
        rt = srv.session("oversized")
        try:
            app.record_init(rt)
            rt.flush()
            raise AssertionError("oversized job was not rejected")
        except AdmissionError:
            rejected = True
        rt.queue.clear()
        rt.close()
    return {"policies": policies, "oversized_rejected": rejected}


def trace_smoke():
    """Observability smoke (``--trace``): (a) sim-mode drift audit is
    oracle-exact — the modelled spans the sim interpreter emits *are* the
    simulated ledger events, so ``repro.obs.audit.compare`` must report a
    per-stream ratio of exactly 1.0; (b) a threaded data-plane CloverLeaf2D
    run exports a valid Chrome trace with distinct compute/upload/download
    tracks, a nonzero span count per stream, and wall-vs-model drift ratios
    inside a loose sanity band (CPU wall clock against the TPU-class
    hardware model — orders of magnitude apart, but finite and positive)."""
    from repro.apps import CloverLeaf2D
    from repro.core import Session
    from repro.obs import compare, validate_chrome_trace

    # (a) modelled == achieved, bit for bit, on every stream of every chain
    app = CloverLeaf2D(40, 24, summary_every=0)
    sess = Session("sim", num_tiles=4,
                   capacity_bytes=app.total_bytes() * 0.5, trace=True)
    app.record_init(sess)
    sess.flush()
    app.dt = 1e-4
    app.record_timestep(sess)
    sess.flush()
    tr = sess.trace()
    sim_streams = {}
    for ci, ledger in enumerate(sess.backend.ledgers):
        rep = compare(ledger, tr, chain=ci)
        if rep.unmatched_events:
            raise SystemExit(
                f"trace smoke: chain {ci} left {rep.unmatched_events} "
                f"ledger events unmatched in sim mode")
        for sd in rep.streams.values():
            name = sd.name
            if sd.ratio != 1.0:
                raise SystemExit(
                    f"trace smoke: sim drift on chain {ci} stream {name}: "
                    f"ratio {sd.ratio!r} != 1.0 "
                    f"(modelled {sd.modelled_s}, achieved {sd.achieved_s})")
            agg = sim_streams.setdefault(
                name, {"events": 0, "modelled_s": 0.0, "ratio": 1.0})
            agg["events"] += sd.events
            agg["modelled_s"] += sd.modelled_s
    if not {"compute", "upload", "download"} <= set(sim_streams):
        raise SystemExit(
            f"trace smoke: sim run exercised only {sorted(sim_streams)}")
    sim_spans = len(tr)
    sess.close()

    # (b) threaded data plane: chrome export + per-stream spans + loose band
    app = CloverLeaf2D(48, 32, summary_every=0)
    sess = Session("ooc-async", num_tiles=4, capacity_bytes=float("inf"),
                   trace=True)
    app.run(sess, steps=2)
    tr = sess.trace()
    track_counts = {}
    for s in tr.spans():
        track_counts[s.track] = track_counts.get(s.track, 0) + 1
    for t in ("compute", "upload", "download"):
        if not track_counts.get(t):
            raise SystemExit(
                f"trace smoke: no spans on the {t!r} track "
                f"(tracks: {sorted(track_counts)})")
    doc = tr.chrome()
    validate_chrome_trace(doc)
    wall_streams = {}
    for ci, ledger in enumerate(sess.backend.ledgers):
        rep = compare(ledger, tr, chain=ci)
        for sd in rep.streams.values():
            name = sd.name
            if sd.modelled_s <= 0.0 or sd.achieved_s <= 0.0:
                continue
            if not (1e-4 < sd.ratio < 1e8):
                raise SystemExit(
                    f"trace smoke: wall drift on chain {ci} stream {name} "
                    f"out of band: ratio {sd.ratio!r}")
            agg = wall_streams.setdefault(
                name, {"events": 0, "modelled_s": 0.0, "achieved_s": 0.0})
            agg["events"] += sd.events
            agg["modelled_s"] += sd.modelled_s
            agg["achieved_s"] += sd.achieved_s
    lanes = sess.transfer_stats()["lanes"]
    sess.close()
    for name, agg in wall_streams.items():
        agg["ratio"] = (agg["achieved_s"] / agg["modelled_s"]
                        if agg["modelled_s"] else 0.0)
    return {
        "sim": {"spans": sim_spans, "streams": sim_streams,
                "oracle_exact": True},
        "wall": {"spans": len(tr), "chrome_events": len(doc["traceEvents"]),
                 "tracks": track_counts, "streams": wall_streams,
                 "lane_histograms": {k: {m: h["count"] for m, h in v.items()}
                                     for k, v in lanes.items()}},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true",
                    help="include the Plan-IR autotuner section")
    ap.add_argument("--simulate", action="store_true",
                    help="sim-mode smoke only (fast; no data plane/Pallas)")
    ap.add_argument("--verify", action="store_true",
                    help="static plan verification sweep (apps x tiers x "
                         "meshes) + fuzzer; exit 1 on any error diagnostic")
    ap.add_argument("--serve", action="store_true",
                    help="serving-layer smoke: 8 tenants on sim:4 under "
                         "each policy; oracle-vs-achieved makespan gate")
    ap.add_argument("--trace", action="store_true",
                    help="observability smoke: sim drift audit must be "
                         "oracle-exact; threaded run must export a valid "
                         "Chrome trace with per-stream spans")
    args = ap.parse_args(argv)

    # Fresh clones may lack reports/ (and nested sections write artifacts
    # mid-run); create it up front instead of failing at the final dump.
    os.makedirs("reports", exist_ok=True)

    if args.verify:
        t0 = time.time()
        print("== Plan verification sweep (apps x tiers x meshes) ==")
        vb = verify_bench()
        errors = 0
        for r in vb["configs"]:
            errors += r["errors"]
            print(f"{r['config']},plans={r['plans']},ops={r['ops']},"
                  f"errors={r['errors']},warnings={r['warnings']}")
            for d in r["diagnostics"]:
                print(f"  {d}")
        print(f"fuzz,{vb['fuzz_mutations']} mutations,"
              f"{vb['fuzz_missed']} missed")
        with open("reports/bench_verify.json", "w") as f:
            json.dump(vb, f, indent=1, default=float)
        print(f"\nverify bench time: {time.time() - t0:.0f}s; "
              f"results -> reports/bench_verify.json")
        if errors or vb["fuzz_missed"]:
            raise SystemExit(
                f"plan verification FAILED: {errors} error diagnostic(s), "
                f"{vb['fuzz_missed']} fuzzer false negative(s)")
        return

    if args.serve:
        t0 = time.time()
        print("== Serving layer: 8 tenants on a shared sim:4 lane pool ==")
        sv = serve_bench()
        for r in sv["policies"]:
            print(f"serve/{r['policy']},jobs={r['jobs']},"
                  f"chains={r['chains']},"
                  f"throughput={r['throughput_chains_per_s']:.1f} chains/s,"
                  f"pred/achieved=x{r['predicted_vs_achieved']:.2f},"
                  f"xtenant_hits={r['cross_tenant_plan_hits']},"
                  f"preemptions={r['preemptions']}")
        print(f"serve/admission,oversized_rejected={sv['oversized_rejected']}")
        path = "reports/bench_results.json"
        results = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    results = json.load(f)
            except (OSError, ValueError):
                results = {}
        results["serve"] = sv
        with open(path, "w") as f:
            json.dump(results, f, indent=1, default=float)
        print(f"\nserve bench time: {time.time() - t0:.0f}s; "
              f"results -> {path}")
        return

    if args.trace:
        t0 = time.time()
        print("== Observability smoke: drift audit + Chrome export ==")
        ts = trace_smoke()
        print(f"trace/sim,spans={ts['sim']['spans']},"
              f"streams={len(ts['sim']['streams'])},"
              f"oracle_exact={ts['sim']['oracle_exact']}")
        for name, agg in sorted(ts["sim"]["streams"].items()):
            print(f"trace/sim/{name},events={agg['events']},"
                  f"modelled={agg['modelled_s'] * 1e3:.3f}ms,ratio=1.0")
        w = ts["wall"]
        print(f"trace/wall,spans={w['spans']},"
              f"chrome_events={w['chrome_events']},"
              f"tracks={len(w['tracks'])}")
        for name, agg in sorted(w["streams"].items()):
            print(f"trace/wall/{name},events={agg['events']},"
                  f"achieved={agg['achieved_s'] * 1e3:.2f}ms,"
                  f"ratio={agg['ratio']:.3g}")
        with open("reports/bench_trace.json", "w") as f:
            json.dump(ts, f, indent=1, default=float)
        print(f"\ntrace smoke time: {time.time() - t0:.0f}s; "
              f"results -> reports/bench_trace.json")
        return

    if args.simulate:
        import tempfile

        results = {}
        t0 = time.time()
        print("== Sim smoke: plan/explain/JSON round-trip ==")
        sm = sim_smoke()
        results["sim_smoke"] = sm
        print(f"chains,{sm['chains']},modelled={sm['modelled_s'] * 1e3:.2f}ms")
        print("ops," + ",".join(f"{k}={v}" for k, v in sm["ops"].items() if v))
        print("\n== Disk-tier smoke (chunked store + HostModel spill) ==")
        with tempfile.TemporaryDirectory(prefix="repro-disk-smoke-") as td:
            ds = disk_smoke(td)
        results["disk_smoke"] = ds
        print(f"disk_smoke,sim_bytes={ds['sim_modelled_disk_bytes']},"
              f"chunked r/w={ds['chunked_disk_read']}/"
              f"{ds['chunked_disk_written']},bit_identical={ds['bit_identical']}")
        print("\n== Disk-tier scaling (modelled) ==")
        dt_rows = disk_tier_bench()
        results["disk_tier"] = dt_rows
        for r in dt_rows:
            print(f"{r['config']},modelled={r['modelled_s'] * 1e3:.2f}ms,"
                  f"{r['slowdown_vs_resident']:.2f}x vs resident,"
                  f"disk r/w={r['disk_read'] / 1e6:.2f}/"
                  f"{r['disk_written'] / 1e6:.2f}MB")
        print("\n== Sharded smoke (device mesh, bit-identity + halo "
              "accounting) ==")
        sh = sharded_smoke()
        results["sharded_smoke"] = sh
        print(f"sharded_smoke,1dev/4dev bit-identical,"
              f"halo={sh['halo_messages']} msgs/"
              f"{sh['halo_bytes'] / 1e6:.2f}MB")
        print("\n== Sharded modelled scaling (device mesh) ==")
        sh_rows = sharded_bench()
        results["sharded_scaling"] = sh_rows
        for r in sh_rows:
            print(f"devices={r['devices']},"
                  f"modelled={r['modelled_s'] * 1e3:.2f}ms,"
                  f"speedup={r['speedup_vs_1dev']:.2f}x,"
                  f"eff={r['parallel_efficiency']:.2f},"
                  f"halo={r['halo_messages']} msgs/"
                  f"{r['halo_bytes'] / 1e6:.2f}MB")
        if args.tune:
            print("\n== Plan-IR autotuner (sim-costed) ==")
            tn = tune_bench()
            results["tune"] = tn
            print(f"tune_candidates,{tn['candidates']},"
                  f"{tn['feasible']} feasible, {tn['tune_s']:.2f}s")
            print(f"tune_speedup,{tn['speedup']:.2f},best={tn['best']} vs "
                  f"default {tn['baseline_modelled_s'] * 1e3:.2f}ms")
            assert tn["best_modelled_s"] <= tn["baseline_modelled_s"], \
                "tuner returned a config worse than the default"
        os.makedirs("reports", exist_ok=True)
        with open("reports/bench_sim.json", "w") as f:
            json.dump(results, f, indent=1, default=float)
        print(f"\nsim bench time: {time.time() - t0:.0f}s; "
              f"results -> reports/bench_sim.json")
        return

    from . import gpu_scaling, kernel_bench, paper_scaling, um_scaling

    results = {}
    t0 = time.time()
    print("== Figs 3-6: KNL problem scaling (model; GB/s) ==")
    results["knl_scaling"] = paper_scaling.main()
    print(f"\n== Figs 7-9: P100 explicit-management scaling + ablations "
          f"(3-slot executor, modelled links) ==")
    results["gpu_scaling"] = gpu_scaling.main()
    print("\n== Fig 11: Unified-memory scaling (model; GB/s) ==")
    results["um_scaling"] = um_scaling.main()
    print("\n== Pallas kernels ==")
    results["kernels"] = kernel_bench.main()
    print("\n== Chain-plan cache (repeated CloverLeaf2D timesteps) ==")
    pc = plan_cache_bench()
    results["plan_cache"] = pc
    print(f"chains,{pc['chains']},over {pc['steps']} steps")
    print(f"plan_cache_hit_rate,{pc['plan_hit_rate']:.2f},"
          f"{pc['plan_hits']} hits / {pc['plan_misses']} misses "
          f"(one analysis per distinct chain shape)")
    print(f"plan_time_s,{pc['plan_time_s']:.4f},schedule construction paid once")
    print(f"plan_time_saved_s,{pc['plan_time_saved_s']:.4f},"
          f"analysis+scheduling amortised by the cache")

    print("\n== Transfer engine & codecs (CloverLeaf2D, real data plane) ==")
    tr = transfer_bench()
    results["transfer"] = tr
    base = next(r for r in tr if r["codec"] == "identity"
                and r["backend"] == "ooc")
    for r in tr:
        speed = base["modelled_s"] / r["modelled_s"] if r["modelled_s"] else 0.0
        print(f"{r['backend']}/{r['codec']},"
              f"ratio={r['compression_ratio']:.2f},"
              f"wire={r['bytes_moved_wire'] / 1e6:.2f}MB,"
              f"modelled={r['modelled_s'] * 1e3:.2f}ms,"
              f"queue_wait={r['queue_wait_s'] * 1e3:.1f}ms,"
              f"{speed:.2f}x vs identity")

    if args.tune:
        print("\n== Plan-IR autotuner (sim-costed) ==")
        tn = tune_bench()
        results["tune"] = tn
        print(f"tune_candidates,{tn['candidates']},{tn['feasible']} feasible")
        print(f"tune_speedup,{tn['speedup']:.2f},best={tn['best']} "
              f"({tn['best_modelled_s'] * 1e3:.2f}ms vs default "
              f"{tn['baseline_modelled_s'] * 1e3:.2f}ms)")

    print("\n== Disk tier: spill-aware plans vs host-resident (modelled) ==")
    dt_rows = disk_tier_bench()
    results["disk_tier"] = dt_rows
    for r in dt_rows:
        print(f"{r['config']},modelled={r['modelled_s'] * 1e3:.2f}ms,"
              f"{r['slowdown_vs_resident']:.2f}x vs resident,"
              f"disk r/w={r['disk_read'] / 1e6:.2f}/"
              f"{r['disk_written'] / 1e6:.2f}MB")

    print("\n== Sharded scaling: device mesh x out-of-core (modelled) ==")
    sh_rows = sharded_bench()
    results["sharded_scaling"] = sh_rows
    for r in sh_rows:
        print(f"devices={r['devices']},modelled={r['modelled_s'] * 1e3:.2f}ms,"
              f"speedup={r['speedup_vs_1dev']:.2f}x,"
              f"eff={r['parallel_efficiency']:.2f},"
              f"halo={r['halo_messages']} msgs/"
              f"{r['halo_bytes'] / 1e6:.2f}MB")

    # headline reproduction checks (paper §5/§6 claims, at 3x capacity)
    print("\n== Reproduction checks vs paper claims ==")
    checks = []
    for row in results["knl_scaling"]:
        if row["app"] == "cloverleaf2d" and row["ratio"] >= 2.8:
            eff = row["cache_tiled_gbs"] / max(
                r["cache_tiled_gbs"] for r in results["knl_scaling"]
                if r["app"] == "cloverleaf2d")
            checks.append(("knl_cl2d_tiled_retention_at_3x", round(eff, 2),
                           "paper 0.85; ours lower by the ~5x loop-count "
                           "fidelity gap, see EXPERIMENTS §Paper"))
            speed = row["cache_tiled_gbs"] / row["cache_gbs"]
            checks.append(("knl_cl2d_tiling_speedup_at_3x", round(speed, 2),
                           "paper ~2.2x"))
            checks.append(("knl_cl2d_tiled_hit_rate_at_3x",
                           round(row["tiled_hit_rate"], 2),
                           "flat ~0.8+ vs untiled "
                           f"{row['cache_hit_rate']:.2f} (Fig 4 shape)"))
    for row in results["gpu_scaling"]:
        if (row["app"] == "cloverleaf2d" and row["ratio"] == 3.0
                and row["cyclic"] and row["prefetch"]):
            checks.append((f"p100_{row['link']}_cl2d_efficiency_at_3x",
                           round(row["efficiency"], 2),
                           "paper: nvlink 0.84 / pcie 0.48"))
        if (row["app"] == "opensbli" and row["ratio"] == 3.0
                and row["cyclic"] and row["prefetch"]):
            checks.append((f"p100_{row['link']}_sbli_efficiency_at_3x",
                           round(row["efficiency"], 2),
                           "paper: ~1.0 (fully hidden)"))
    for name, val, note in checks:
        print(f"{name},{val},{note}")
    results["checks"] = checks

    os.makedirs("reports", exist_ok=True)
    with open("reports/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"\ntotal bench time: {time.time() - t0:.0f}s; "
          f"results -> reports/bench_results.json")


if __name__ == "__main__":
    main()
