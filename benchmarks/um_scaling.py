"""Paper Figure 11: problem scaling with Unified Memory on the P100.

UM page migration is modelled per §5.4's observations: page-fault service is
LATENCY-bound (identical throughput on PCIe and NVLink), bulk prefetches move
pages at link bandwidth but degrade ~0.6x when oversubscribed (the driver
issue the paper reports).  Reproduced claims: performance collapses past
16 GB without tiling; tiling recovers ~3x but stays below explicit
management; UM+prefetch on OpenSBLI (tiling over 5 steps) approaches but
does not reach baseline.
"""
from __future__ import annotations

from typing import Dict, List

from repro.apps import CloverLeaf2D, OpenSBLI
from repro.core import P100_PCIE, Session
from repro.core.cachesim import simulate_chain

CAPACITY = 8 << 20

APPS = {
    "cloverleaf2d": (lambda nx: CloverLeaf2D(nx, nx, summary_every=0), 470e9, 1),
    "opensbli": (lambda nx: OpenSBLI(nx), 170e9, 5),
}


def _size_for(build, ratio):
    lo, hi = 8, 4096
    while lo < hi:
        mid = (lo + hi) // 2
        if build(mid).total_bytes() < ratio * CAPACITY:
            lo = mid + 1
        else:
            hi = mid
    return lo


def _loops(app, tile_steps: int):
    rt = Session("reference")
    app.record_init(rt)
    rt.queue.clear()
    app.dt = 1e-4
    for _ in range(tile_steps):
        app.record_timestep(rt)
    loops = list(rt.queue)
    rt.queue.clear()
    return loops


def run(ratios=(0.5, 1.0, 1.5, 2.0, 3.0)) -> List[Dict]:
    rows = []
    for name, (build, fast_bw, tile_steps) in APPS.items():
        # Host tier: RAM sized at 2x fast capacity, so the oversubscribed
        # rows (ratio > 2) exercise the disk tier (FetchHome/SpillHome) and
        # their ChainStats carry nonzero disk I/O counters.
        hw = P100_PCIE.with_(fast_capacity=CAPACITY, fast_bw=fast_bw,
                             dd_bw=509.7e9, page_bytes=4096,
                             page_fault_latency=30e-6,
                             host_capacity=2.0 * CAPACITY)
        for ratio in ratios:
            nx = _size_for(build, ratio)
            app = build(nx)
            loops = _loops(app, tile_steps)
            row = {"app": name, "ratio": round(app.total_bytes() / CAPACITY, 2)}
            st = simulate_chain(loops, hw, mode="um")
            row["um_gbs"] = st.achieved_bw / 1e9
            st = simulate_chain(loops, hw, mode="um", tiled=True, num_tiles=8)
            row["um_tiled_gbs"] = st.achieved_bw / 1e9
            st = simulate_chain(loops, hw, mode="um_prefetch", tiled=True,
                                num_tiles=8)
            row["um_tiled_prefetch_gbs"] = st.achieved_bw / 1e9
            # Replay the same chain through the explicit-management planner
            # (sim backend, no data plane) so UM rows carry the plan-cache
            # counters and a `transfer` stats section like the other benches.
            # Two flushes model the paper's repeated warm timesteps: the
            # second chain replays the cached plan (the amortisation the
            # counters exist to show); the transfer section reports that
            # steady-state chain.
            sim = Session("sim", hw=hw)
            sim.queue.extend(loops)
            sim.flush()
            warm_start = len(sim.history)   # split chains count individually
            sim.queue.extend(loops)
            sim.flush()
            plan = sim.plan_stats()
            row["plan_hits"] = plan["plan_hits"]
            row["plan_misses"] = plan["plan_misses"]
            row["plan_hit_rate"] = plan["plan_hit_rate"]
            row["plan_time_s"] = plan["plan_time_s"]
            steady = sim.history[warm_start:]
            wire = sum(c.uploaded_wire + c.downloaded_wire for c in steady)
            raw = sum(c.uploaded + c.downloaded for c in steady)
            row["transfer"] = {
                "bytes_moved_wire": wire,
                "bytes_up_raw": sum(c.uploaded for c in steady),
                "bytes_down_raw": sum(c.downloaded for c in steady),
                "compression_ratio": raw / wire if wire else 1.0,
                "queue_wait_s": sum(c.queue_wait_s for c in steady),
            }
            # Plan-IR op counts, straight from each chain's instruction
            # stream (ChainStats.op_counts) — no re-derivation from ledger
            # events needed.
            row["ops"] = {
                k: sum(c.op_counts.get(k, 0) for c in steady)
                for k in ("uploads", "downloads", "carries", "elisions",
                          "evictions", "home_fetches", "home_spills")
            }
            # Disk-tier I/O counters (repro.core.store): modelled bytes the
            # FetchHome/SpillHome ops moved for this steady-state chain —
            # nonzero exactly when the row's working set exceeds host RAM.
            row["disk"] = {
                "read_bytes": sum(c.disk_read for c in steady),
                "written_bytes": sum(c.disk_written for c in steady),
            }
            rows.append(row)
    return rows


def main():
    rows = run()
    print("app,ratio,um,um_tiled,um_tiled_prefetch (GB/s),plan_hit_rate,"
          "explicit_wire_MB,ops(up/down/carry/evict),disk_rw_MB")
    for r in rows:
        ops = r["ops"]
        print(f"{r['app']},{r['ratio']},{r['um_gbs']:.1f},"
              f"{r['um_tiled_gbs']:.1f},{r['um_tiled_prefetch_gbs']:.1f},"
              f"{r['plan_hit_rate']:.2f},"
              f"{r['transfer']['bytes_moved_wire'] / 1e6:.1f},"
              f"{ops['uploads']}/{ops['downloads']}/{ops['carries']}/"
              f"{ops['evictions']},"
              f"{r['disk']['read_bytes'] / 1e6:.1f}/"
              f"{r['disk']['written_bytes'] / 1e6:.1f}")
    return rows


if __name__ == "__main__":
    main()
