"""Paper Figures 3–6: problem scaling on the KNL (flat-DDR4 / flat-MCDRAM /
cache / cache+tiling) + MCDRAM hit rates.

The KNL is modelled (this container is CPU-only): per-app effective
bandwidths are calibrated to the paper's own measured numbers (§5.2 — CL2D
240/50, CL3D 200/50, SBLI 83/30 GB/s MCDRAM/DDR4), and the cache behaviour
comes from the exact page-granular LRU over the access stream the runtime
schedules (untiled vs skewed-tiled).  Problem sizes are scaled down ~2000x
(16 GB -> 8 MB "MCDRAM") keeping the size/capacity RATIO the paper sweeps
(0.4x .. 3x); results are reported in the same ratio units.

The paper's headline claims this reproduces:
  * without tiling, cache-mode efficiency collapses as size -> 3x capacity
    (CL2D 0.36x, CL3D 0.45x, SBLI 0.59x of flat-MCDRAM);
  * with tiling, <= ~15% loss at 3x capacity;
  * hit rates decline steeply without tiling, stay high with it (Fig 4).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.apps import CloverLeaf2D, CloverLeaf3D, OpenSBLI
from repro.core import KNL_7210, Session
from repro.core.cachesim import simulate_chain
from repro.core.dependency import analyze_chain

# capacity scaled 2000x down; grid sizes chosen to sweep size/capacity ratio
CAPACITY = 8 << 20  # 8 MB stand-in for 16 GB MCDRAM

APPS = {
    # name: (builder, fast_bw, slow_bw, paper's flat-MCDRAM 'baseline' GB/s)
    "cloverleaf2d": (lambda nx: CloverLeaf2D(nx, nx, summary_every=0),
                     240e9, 50e9),
    "cloverleaf3d": (lambda nx: CloverLeaf3D(nx, nx, nx, summary_every=0),
                     200e9, 50e9),
    "opensbli": (lambda nx: OpenSBLI(nx), 83e9, 30e9),
}


def _record_one_step(app) -> List:
    rt = Session("reference")
    app.record_init(rt)
    rt.queue.clear()           # init is not part of the measured cyclic phase
    app.dt = 1e-4
    app.record_timestep(rt)
    loops = list(rt.queue)
    rt.queue.clear()
    return loops


def _sizes_for(app_name: str, ratios) -> List[int]:
    """Grid edge lengths giving total dataset bytes ~ ratio x CAPACITY."""
    build = APPS[app_name][0]
    out = []
    for r in ratios:
        target = r * CAPACITY
        lo, hi = 8, 4096
        while lo < hi:
            mid = (lo + hi) // 2
            b = build(mid).total_bytes()
            if b < target:
                lo = mid + 1
            else:
                hi = mid
        out.append(lo)
    return out


def run(ratios=(0.4, 0.8, 1.2, 2.0, 3.0), tile_counts=(1, 4, 8, 16, 24)) -> List[Dict]:
    rows = []
    for name, (build, fast_bw, slow_bw) in APPS.items():
        hw = KNL_7210.with_(fast_capacity=CAPACITY, fast_bw=fast_bw,
                            dd_bw=fast_bw, slow_bw=slow_bw,
                            up_bw=slow_bw, down_bw=slow_bw,
                            page_bytes=4096)
        for ratio, nx in zip(ratios, _sizes_for(name, ratios)):
            app = build(nx)
            loops = _record_one_step(app)
            size_b = app.total_bytes()
            row = {"app": name, "ratio": round(size_b / CAPACITY, 2), "grid": nx}
            # flat MCDRAM (errors beyond capacity, like the paper's segfault)
            try:
                st = simulate_chain(loops, hw, mode="flat_fast")
                row["flat_mcdram_gbs"] = st.achieved_bw / 1e9
            except MemoryError:
                row["flat_mcdram_gbs"] = None
            st = simulate_chain(loops, hw, mode="flat_slow")
            row["flat_ddr4_gbs"] = st.achieved_bw / 1e9
            st = simulate_chain(loops, hw, mode="cache")
            row["cache_gbs"] = st.achieved_bw / 1e9
            row["cache_hit_rate"] = st.hit_rate
            # cache + skewed tiling: pick the best tile count (auto-tuning,
            # as OPS does at runtime)
            best = None
            for nt in tile_counts:
                st = simulate_chain(loops, hw, mode="cache", tiled=True,
                                    num_tiles=nt)
                if best is None or st.achieved_bw > best[0].achieved_bw:
                    best = (st, nt)
            row["cache_tiled_gbs"] = best[0].achieved_bw / 1e9
            row["tiled_hit_rate"] = best[0].hit_rate
            row["best_tiles"] = best[1]
            rows.append(row)
    return rows


def main():
    rows = run()
    print("app,ratio,flat_ddr4,flat_mcdram,cache,cache_tiled,hit_untiled,hit_tiled,tiles")
    for r in rows:
        fm = f"{r['flat_mcdram_gbs']:.0f}" if r["flat_mcdram_gbs"] else "OOM"
        print(f"{r['app']},{r['ratio']},{r['flat_ddr4_gbs']:.0f},{fm},"
              f"{r['cache_gbs']:.0f},{r['cache_tiled_gbs']:.0f},"
              f"{r['cache_hit_rate']:.2f},{r['tiled_hit_rate']:.2f},{r['best_tiles']}")
    return rows


if __name__ == "__main__":
    main()
