"""Serve a small model with batched requests — including the paper's
out-of-core mode: weights streamed layer-by-layer from host memory through
the 3-slot schedule, with device-resident weight footprint bounded by the
window, validated against fully-resident decoding.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced_config  # noqa: E402
from repro.models import decode_step, init_params  # noqa: E402
from repro.models.offload import StreamedDecoder  # noqa: E402
from repro.models.transformer import init_cache  # noqa: E402


def main():
    cfg = get_reduced_config("llama3_2_1b").with_(num_layers=8)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, gen = 4, 16
    prompts = jax.random.randint(key, (B,), 0, cfg.vocab_size)

    # resident serving
    cache = init_cache(cfg, B, gen + 1)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tok = prompts
    t0 = time.perf_counter()
    resident_out = []
    for _ in range(gen):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)
        resident_out.append(tok)
    jax.block_until_ready(tok)
    t_res = time.perf_counter() - t0

    # out-of-core serving: weights live in HOST memory, 3-slice window
    streamer = StreamedDecoder(params, cfg, window=3)
    cache = init_cache(cfg, B, gen + 1)
    tok = prompts
    t0 = time.perf_counter()
    streamed_out = []
    for _ in range(gen):
        logits, cache = streamer.decode(cache, tok)
        tok = jnp.argmax(logits, -1)
        streamed_out.append(tok)
    jax.block_until_ready(tok)
    t_str = time.perf_counter() - t0

    same = all(bool((a == b).all())
               for a, b in zip(resident_out, streamed_out))
    total_w = sum(np.asarray(l).nbytes
                  for l in jax.tree.leaves(streamer.host_blocks))
    print(f"batch={B} gen={gen} tokens")
    print(f"resident : {t_res:.2f}s   (all {cfg.num_layers} layers on device)")
    print(f"streamed : {t_str:.2f}s   (window=3 of {cfg.num_layers} layers; "
          f"device weights {streamer.device_resident_bytes() / 1e6:.1f} MB "
          f"of {total_w / 1e6:.1f} MB total)")
    print(f"greedy outputs identical: {same}")
    print(f"modelled step on TPU v5e (PCIe streaming, overlapped): "
          f"{streamer.stats.modelled_step_s * 1e3:.2f} ms/token")
    assert same


if __name__ == "__main__":
    main()
