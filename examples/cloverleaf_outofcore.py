"""CloverLeaf 2D at 3x the fast-memory capacity — the paper's headline
experiment, end to end through the Session API: lazy recording with inferred
stencils, dt-reduction chain breakers, skewed tiling, 3-slot streaming with
the Cyclic + Prefetch optimisations, memoised chain plans, and the
achieved-bandwidth metric vs. the resident baseline.

  PYTHONPATH=src python examples/cloverleaf_outofcore.py
"""
import numpy as np

from repro.apps import CloverLeaf2D
from repro.core import P100_NVLINK, Session


def main():
    capacity = 4 << 20               # scaled-down "16 GB"
    nx = 450                         # ~3x capacity with 25 fp32 datasets
    app_probe = CloverLeaf2D(nx, nx)
    ratio = app_probe.total_bytes() / capacity
    print(f"problem: {app_probe.total_bytes() / 1e6:.1f} MB "
          f"= {ratio:.1f}x fast memory ({capacity / 1e6:.0f} MB)")

    hw = P100_NVLINK.with_(fast_capacity=capacity, fast_bw=470e9, dd_bw=509.7e9)
    steps = 3

    ref_app = CloverLeaf2D(nx, nx, summary_every=steps)
    ref_summary = ref_app.run(Session("reference"), steps=steps)

    app = CloverLeaf2D(nx, nx, summary_every=steps)
    sess = Session("ooc", hw=hw, prefetch=True)
    summary = app.run(sess, steps=steps)   # enables cyclic after init

    err = np.abs(ref_app.d("density0").interior()
                 - app.d("density0").interior()).max()
    print(f"correctness vs in-core reference: max|drho| = {err:.2e}")
    assert err < 1e-4

    hist = sess.history[1:]
    bw = sum(c.loop_bytes for c in hist) / sum(c.modelled_s for c in hist)
    print(f"chains: {len(sess.history)}  tiles/chain: {hist[0].num_tiles}  "
          f"slot: {hist[0].slot_bytes / 1e6:.2f} MB")
    up = sum(c.uploaded for c in hist) / 1e6
    dn = sum(c.downloaded for c in hist) / 1e6
    print(f"link traffic: {up:.0f} MB up / {dn:.0f} MB down "
          f"(write-first+cyclic elision on)")
    plan = sess.plan_stats()
    print(f"chain plans: {plan['plan_misses']} analysed once, "
          f"{plan['plan_hits']} replayed from cache "
          f"(hit rate {plan['plan_hit_rate']:.0%})")
    print(f"achieved bandwidth (modelled {hw.name}): {bw / 1e9:.0f} GB/s "
          f"= {bw / 470e9 * 100:.0f}% of the in-core baseline")
    for k, v in summary.items():
        print(f"  summary {k}: {v:.6g} (ref {ref_summary[k]:.6g})")


if __name__ == "__main__":
    main()
