"""Quickstart: the OPS-style DSL + out-of-core tiled execution in ~60 lines.

A 2-D heat solver whose working set is larger than the configured "fast
memory": the runtime records the loop chain lazily, analyses dependencies,
builds a skewed tile schedule, and streams tiles through three slots —
validated against the eager reference, with the transfer ledger printed.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    Arg, Block, OOCConfig, OutOfCoreExecutor, READ, RW, ReferenceRuntime,
    Runtime, TPU_V5E, WRITE, make_dataset, point_stencil, star_stencil,
)


def heat(rt, n=512, m=256, steps=8):
    blk = Block("grid", (n, m))
    rng = np.random.RandomState(0)
    u = make_dataset(blk, "u", halo=1, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=1)
    S, Z = star_stencil(2, 1), point_stencil(2)
    interior = ((1, n - 1), (1, m - 1))
    for s in range(steps):
        rt.par_loop(f"diffuse{s}", blk, interior,
                    [Arg(u, S, READ), Arg(tmp, Z, WRITE)],
                    lambda acc: {"tmp": 0.25 * (acc("u", (1, 0)) + acc("u", (-1, 0))
                                                 + acc("u", (0, 1)) + acc("u", (0, -1)))})
        rt.par_loop(f"commit{s}", blk, interior,
                    [Arg(tmp, Z, READ), Arg(u, Z, RW)],
                    lambda acc: {"u": acc("tmp")})
    return rt.fetch(u)  # <- chain breaker: analysis + tiling + execution here


def main():
    ref = heat(ReferenceRuntime())

    # fast memory holds only ~1/4 of the problem: out-of-core streaming
    problem_bytes = 2 * 514 * 258 * 4
    hw = TPU_V5E.with_(fast_capacity=problem_bytes // 4)
    ex = OutOfCoreExecutor(OOCConfig(hw=hw, cyclic=True, prefetch=True))
    got = heat(Runtime(ex))

    assert np.allclose(ref, got, atol=1e-5), "out-of-core result mismatch!"
    st = ex.history[-1]
    print(f"problem        : {problem_bytes / 1e6:.1f} MB")
    print(f"fast memory    : {hw.fast_capacity / 1e6:.1f} MB  "
          f"(3 slots x {st.slot_bytes / 1e6:.2f} MB used)")
    print(f"tiles          : {st.num_tiles}")
    print(f"uploaded       : {st.uploaded / 1e6:.1f} MB   "
          f"downloaded: {st.downloaded / 1e6:.1f} MB")
    print(f"modelled step  : {st.modelled_s * 1e3:.2f} ms  "
          f"-> {st.achieved_bw_model / 1e9:.0f} GB/s achieved (model: {hw.name})")
    print("out-of-core result == reference  [OK]")


if __name__ == "__main__":
    main()
