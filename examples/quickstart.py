"""Quickstart: the StencilProgram/Session API + out-of-core execution.

A 2-D heat solver whose working set is larger than the configured "fast
memory".  Loops are registered *declaratively*: pass the datasets a kernel
touches and the runtime traces the kernel's accessor calls to infer every
READ stencil and access mode — no hand-built ``Arg(dat, stencil, mode)``
lists.  Backends are selected by name from the registry ("reference",
"resident", "ooc", "ooc-cyclic", "sim", "pallas"); chain plans (dependency
analysis + skewed tile schedule + compiled tiles) are memoised, so repeated
identical chains replay a cached plan.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Block, Session, TPU_V5E, make_dataset
from repro.kernels import star2d_kernel


def heat(sess: Session, n=512, m=256, steps=8):
    blk = Block("grid", (n, m))
    rng = np.random.RandomState(0)
    u = make_dataset(blk, "u", halo=1, init=rng.rand(n, m).astype(np.float32))
    tmp = make_dataset(blk, "tmp", halo=1)
    interior = ((1, n - 1), (1, m - 1))
    # A declared star sweep (the "pallas" backend fast-paths this one) ...
    diffuse = star2d_kernel("u", "tmp", (0.0, 0.25, 0.25))
    # ... and a plain accessor kernel — stencils/modes inferred by tracing.
    commit = lambda acc: {"u": acc("tmp")}
    for s in range(steps):
        sess.par_loop(f"diffuse{s}", blk, interior, [u, tmp], diffuse)
        sess.par_loop(f"commit{s}", blk, interior, [tmp, u], commit)
    return sess.fetch(u)  # <- chain breaker: analysis + tiling + execution


def main():
    ref = heat(Session("reference"))

    # fast memory holds only ~1/4 of the problem: out-of-core streaming
    problem_bytes = 2 * 514 * 258 * 4
    hw = TPU_V5E.with_(fast_capacity=problem_bytes // 4)
    sess = Session("ooc", hw=hw, cyclic=True, prefetch=True)

    # Inspect the Plan IR before anything executes: record one step, ask the
    # planner for the typed instruction stream and its modelled makespan.
    blk = Block("preview", (512, 256))
    rng = np.random.RandomState(0)
    pu = make_dataset(blk, "u", halo=1,
                      init=rng.rand(512, 256).astype(np.float32))
    pt = make_dataset(blk, "tmp", halo=1)
    box = ((1, 511), (1, 255))
    sess.par_loop("p_diffuse", blk, box, [pu, pt],
                  star2d_kernel("u", "tmp", (0.0, 0.25, 0.25)))
    sess.par_loop("p_commit", blk, box, [pt, pu], lambda acc: {"u": acc("tmp")})
    print("--- Session.explain(): the chain's instruction stream ---")
    print("\n".join(sess.explain().splitlines()[:10]))
    print("    ...\n")
    sess.queue.clear()          # preview only — nothing ran

    got = heat(sess)

    assert np.allclose(ref, got, atol=1e-5), "out-of-core result mismatch!"
    st = sess.history[-1]
    plan = sess.plan_stats()
    print(f"problem        : {problem_bytes / 1e6:.1f} MB")
    print(f"fast memory    : {hw.fast_capacity / 1e6:.1f} MB  "
          f"(3 slots x {st.slot_bytes / 1e6:.2f} MB used)")
    print(f"tiles          : {st.num_tiles}")
    print(f"uploaded       : {st.uploaded / 1e6:.1f} MB   "
          f"downloaded: {st.downloaded / 1e6:.1f} MB")
    print(f"modelled step  : {st.modelled_s * 1e3:.2f} ms  "
          f"-> {st.achieved_bw_model / 1e9:.0f} GB/s achieved (model: {hw.name})")
    print(f"chain planning : {plan['plan_misses']} analysed, "
          f"{plan['plan_hits']} cache hits "
          f"({plan['plan_time_s'] * 1e3:.1f} ms total)")
    print("out-of-core result == reference  [OK]")


if __name__ == "__main__":
    main()
