"""End-to-end training driver: train a llama-style model through the full
production path — sharded train_step, AdamW + cosine schedule, deterministic
data pipeline, periodic checkpointing and resume.

Default ("tiny") trains a CPU-sized model for 40 steps in ~2 minutes and
verifies the loss dropped.  ``--preset 100m --steps 300`` runs a ~100M-param
model for a few hundred steps (hours on this CPU container, the intended
config on real hardware) — the code path is IDENTICAL to what the dry-run
compiles for the 512-chip mesh.

  PYTHONPATH=src python examples/train_lm.py [--preset 100m] [--steps N]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_reduced_config  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.train import AdamWConfig, adamw_init, make_train_step  # noqa: E402
from repro.train.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint  # noqa: E402
from repro.train.data import DataConfig, PrefetchIterator, TokenStream  # noqa: E402


def build_config(preset: str):
    base = get_reduced_config("llama3_2_1b")
    if preset == "tiny":
        return base.with_(num_layers=4, d_model=256, num_heads=8,
                          num_kv_heads=4, head_dim=32, d_ff=512,
                          vocab_size=2048), 8, 128
    # ~100M params
    return base.with_(num_layers=12, d_model=768, num_heads=12,
                      num_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab_size=32000), 8, 512


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg, batch, seq = build_config(args.preset)
    steps = args.steps or (40 if args.preset == "tiny" else 300)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-{args.preset} ({n_params / 1e6:.1f}M params) "
          f"| {steps} steps x batch {batch} x seq {seq}")

    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=max(5, steps // 10),
                          total_steps=steps)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0
    newest = latest_checkpoint(args.ckpt_dir)
    if newest is not None:
        _, st = restore_checkpoint(args.ckpt_dir, newest, {"p": params, "o": opt_state})
        params = jax.tree.map(jnp.asarray, st["p"])
        opt_state = jax.tree.map(jnp.asarray, st["o"])
        start = newest
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh))
    stream = TokenStream(DataConfig(cfg.vocab_size, seq, batch))
    it = PrefetchIterator(stream, start_step=start)
    first_loss = None
    try:
        while True:
            s, batch_np = next(it)
            if s >= steps:
                break
            t0 = time.perf_counter()
            jb = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, m = step_fn(params, opt_state, jb)
            loss = float(m["loss"])
            if first_loss is None:
                first_loss = loss
            if (s + 1) % 5 == 0 or s == 0:
                print(f"step {s + 1:4d}/{steps} loss={loss:.4f} "
                      f"lr={float(m['lr']):.2e} ({time.perf_counter() - t0:.2f}s)")
            if (s + 1) % 10 == 0 or s + 1 == steps:
                save_checkpoint(args.ckpt_dir, s + 1, {"p": params, "o": opt_state})
    finally:
        it.close()
    print(f"loss: {first_loss:.4f} -> {loss:.4f} "
          f"({'improved' if loss < first_loss else 'NO IMPROVEMENT'})")
    assert loss < first_loss, "training failed to reduce loss"


if __name__ == "__main__":
    main()
