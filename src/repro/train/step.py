"""The jitted train_step / serve_step factories used by the launcher AND the
dry-run (same code path — what compiles in the dry-run is what trains).

Features:
  * gradient accumulation (microbatching) via lax.scan over the batch split,
  * optional int8-compressed gradient all-reduce over the pod (DCN) axis,
  * remat (activation checkpointing) through the model's layer scan,
  * AdamW with ZeRO state sharding inherited from param specs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..distributed.compression import make_pod_grad_allreduce
from ..models import loss_fn
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh: Optional[Mesh] = None,
    *,
    microbatches: int = 1,
    compress_pod_grads: bool = False,
    remat: bool = True,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    pod_reduce = (make_pod_grad_allreduce(mesh, compress=True)
                  if (compress_pod_grads and mesh is not None) else None)

    def compute_grads(params, batch):
        def lf(p, b):
            return loss_fn(
                p, cfg, b["tokens"], b["labels"],
                patches=b.get("patches"), enc_inputs=b.get("enc_inputs"),
                mesh=mesh, remat=remat,
            )
        if microbatches == 1:
            loss, grads = jax.value_and_grad(lf)(params, batch)
            return loss, grads
        # split batch dim into microbatches and accumulate
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = {k: split(v) for k, v in batch.items()}

        def body(carry, mbatch):
            acc_loss, acc_g = carry
            loss, g = jax.value_and_grad(lf)(params, mbatch)
            acc_g = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), acc_g, g)
            return (acc_loss + loss, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if pod_reduce is not None:
            grads = pod_reduce(grads)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Callable:
    """Returns serve_step(params, cache, tokens) -> (logits, cache)."""
    from ..models import decode_step

    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, mesh=mesh)

    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Callable:
    """Returns prefill(params, batch) -> last-position logits.

    (Cache materialisation for decode is exercised separately by serve_step —
    the prefill cell measures the full-sequence forward cost.)
    """
    from ..models import forward

    def prefill(params, batch):
        logits = forward(
            params, cfg, batch["tokens"],
            patches=batch.get("patches"), enc_inputs=batch.get("enc_inputs"),
            mesh=mesh, remat=False,
        )
        return logits[:, -1, :]

    return prefill
