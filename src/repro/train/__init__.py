"""Training substrate: optimizer, data pipeline, checkpointing, train step."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .step import make_train_step

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "make_train_step",
]
