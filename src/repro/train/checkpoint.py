"""Checkpointing: sharded-agnostic save/restore with atomic commits, async
writing, retention, and restore-to-any-mesh (elastic) resharding.

Format: one ``.npz`` per checkpoint step (leaves keyed by tree path) plus a
JSON manifest (step, shapes, dtypes, tree structure hash).  Writes go to a
temp dir and ``os.replace`` in — a killed process never leaves a half-valid
checkpoint (crash-consistency is tested by the preemption test).

Restore returns host numpy; the caller ``device_put``s with the CURRENT
mesh's shardings, so a checkpoint taken on one topology restores onto any
other (elastic scaling across restarts).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3,
                    async_write: bool = False) -> threading.Thread | None:
    """Atomically write ``step``'s checkpoint; prune to ``keep`` newest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(jax.device_get(tree))

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _prune(ckpt_dir, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=False)
        t.start()
        return t
    _write()
    return None


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = list_checkpoints(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def list_checkpoints(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{8})", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree) -> Tuple[int, Any]:
    """Restore into the structure of ``like_tree`` (host numpy leaves)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for p, leaf in flat_like:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != model {want}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), leaves)
    return manifest["step"], tree
