"""AdamW, written directly in JAX (no optax dependency), ZeRO-friendly:
moment tensors mirror the parameter tree so they inherit FSDP shardings."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.peak_lr * (cfg.min_lr_ratio
                             + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg)(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, state, {"grad_norm": gnorm, "lr": lr}
