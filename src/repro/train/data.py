"""Data pipeline: deterministic synthetic stream + binary-file loader, with a
background prefetch thread (the practical straggler-mitigation lever on the
input side) and per-host sharding hooks for multi-host launches.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None        # None -> synthetic
    host_index: int = 0
    host_count: int = 1


class TokenStream:
    """Deterministic, seekable token stream.

    Synthetic mode generates a mixed Zipf/Markov-ish stream from a counter-
    based RNG keyed on (seed, step, host): restartable at any step without
    replaying history — the property checkpoint/resume tests rely on.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._data = None
        if cfg.path:
            self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local_batch = cfg.global_batch // cfg.host_count
        if self._data is not None:
            tokens_per_batch = local_batch * (cfg.seq_len + 1)
            start = (step * cfg.host_count + cfg.host_index) * tokens_per_batch
            start = start % max(1, self._data.size - tokens_per_batch)
            chunk = np.asarray(self._data[start:start + tokens_per_batch])
            chunk = chunk.reshape(local_batch, cfg.seq_len + 1) % cfg.vocab_size
        else:
            rng = np.random.Generator(np.random.Philox(
                key=cfg.seed, counter=[0, 0, step, cfg.host_index]))
            zipf = rng.zipf(1.3, size=(local_batch, cfg.seq_len + 1))
            chunk = (zipf % cfg.vocab_size).astype(np.int32)
        return {
            "tokens": chunk[:, :-1].astype(np.int32),
            "labels": chunk[:, 1:].astype(np.int32),
        }


class PrefetchIterator:
    """Background-thread prefetch of the next ``depth`` batches."""

    def __init__(self, stream: TokenStream, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.step = start_step
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
