"""repro — out-of-core stencil runtime in JAX.

Reproduction of "Beyond 16GB: Out-of-Core Stencil Computations", grown into
a general runtime: OPS-style lazy loop chains, runtime dependency analysis,
skewed tiling, and streaming out-of-core execution, fronted by the
``repro.core.Session`` API.
"""

__version__ = "0.1.0"
