"""Compatibility helpers across JAX versions.

The repo targets current JAX but must run on older installs (e.g. 0.4.x)
where two APIs differ:

* ``jax.make_mesh`` grew an ``axis_types=`` parameter (and
  ``jax.sharding.AxisType``) only in newer releases;
* ``jax.shard_map`` (with ``check_vma=``) replaced
  ``jax.experimental.shard_map.shard_map`` (with ``check_rep=``).

Everything in the repo goes through these two wrappers instead of touching
the version-specific spellings directly.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported.

    On JAX versions exposing ``jax.sharding.AxisType`` the mesh is built with
    every axis in Auto mode (the repo's convention); older versions have no
    axis-type concept and get the plain mesh, which behaves identically.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(axis_type.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap bodies.

    ``jax.lax.axis_size`` on new JAX; on old releases ``jax.core.axis_frame``
    already resolves to the bound axis size.
    """
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core as core

    frame = core.axis_frame(axis_name)
    return getattr(frame, "size", frame)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new JAX, experimental shard_map on old.

    ``check_vma`` maps onto the old API's ``check_rep`` (same meaning:
    verify per-shard replication invariants; both default off here because
    the repo's collectives handle replication explicitly).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma)
