"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16) — model axis sized to one ICI
torus dimension so TP collectives stay on fastest links.  Multi-pod: 2 pods
x 256 chips as (pod=2, data=16, model=16); the pod axis crosses DCN and is
used for coarse-grained parallelism only (extra DP with one grad all-reduce
per step — optionally int8-compressed — or pipeline stages).

Functions, not module constants: importing this module must never touch JAX
device state (the dry-run sets XLA_FLAGS before first jax init).  Mesh
construction goes through :mod:`repro.compat` so it works on JAX versions
with and without ``jax.sharding.AxisType``.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host offers (tests / local runs); elastic by device count."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return make_mesh((data, model), ("data", "model"))
