import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell: build the production
mesh, lower the REAL step function (train_step / prefill / serve_step — the
same code the launcher runs) with ShapeDtypeStruct inputs and explicit
shardings, ``.compile()`` it, and record ``memory_analysis()`` +
``cost_analysis()`` + the post-SPMD HLO for the roofline pass.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import numpy as np


def _cell_step_and_args(cfg, shape, mesh, *, microbatches=1, compress=False,
                        fsdp=True, remat=True, tp=True):
    from repro.distributed.sharding import (
        batch_specs, cache_specs, param_specs, shardings_of)
    from repro.launch.specs import input_specs
    from repro.train.optimizer import AdamWConfig
    from repro.train.step import make_prefill_step, make_serve_step, make_train_step
    from jax.sharding import NamedSharding, PartitionSpec as P

    ns = lambda spec: NamedSharding(mesh, spec)
    specs = input_specs(cfg, shape)
    p_specs = param_specs(specs["params"], cfg, mesh, fsdp=fsdp, tp=tp)
    p_sh = jax.tree.map(lambda s: ns(s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        o_specs = {
            "mu": p_specs, "nu": p_specs, "step": P(),
        }
        o_sh = jax.tree.map(lambda s: ns(s), o_specs,
                            is_leaf=lambda x: isinstance(x, P))
        b_spec_tree = batch_specs(cfg, mesh, shape.global_batch,
                                  include_model=not tp)
        b_sh = {k: ns(b_spec_tree[k]) for k in specs["batch"]}
        fn = make_train_step(
            cfg, AdamWConfig(), mesh,
            microbatches=microbatches, compress_pod_grads=compress, remat=remat)
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
        )
        return jitted, (specs["params"], specs["opt_state"], specs["batch"])

    if shape.kind == "prefill":
        b_spec_tree = batch_specs(cfg, mesh, shape.global_batch)
        b_sh = {k: ns(b_spec_tree[k]) for k in specs["batch"]}
        fn = make_prefill_step(cfg, mesh)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        return jitted, (specs["params"], specs["batch"])

    # decode
    c_spec_tree = cache_specs(cfg, mesh, shape.global_batch)
    c_sh = {k: ns(c_spec_tree[k]) for k in specs["cache"]}
    ba = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    nb = int(np.prod([mesh.shape[a] for a in ba]))
    tok_sh = ns(P(ba if shape.global_batch % nb == 0 else None))
    fn = make_serve_step(cfg, mesh)
    jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh), donate_argnums=(1,))
    return jitted, (specs["params"], specs["cache"], specs["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Optional[str],
             *, microbatches=1, compress=False, fsdp=True, remat=True, tp=True,
             save_hlo=True, tag=""):
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        jitted, args = _cell_step_and_args(
            cfg, shape, mesh, microbatches=microbatches, compress=compress,
            fsdp=fsdp, remat=remat, tp=tp)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    elapsed = time.time() - t0
    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh.shape.items()),
        "devices": n_dev,
        "kind": shape.kind,
        "compile_s": round(elapsed, 1),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "alias_bytes_per_device": int(mem.alias_size_in_bytes),
            "peak_estimate_per_device": int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and not k.startswith("utilization")},
        "flags": {"microbatches": microbatches, "compress": compress,
                  "fsdp": fsdp, "remat": remat, "tp": tp},
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        stem = f"{arch.replace('.', '_')}_{shape_name}_{'pod2' if multi_pod else 'pod1'}{tag}"
        with open(os.path.join(out_dir, stem + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    return result, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="both")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-tp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, all_cells, shape_cells, get_config
    from repro.models.config import SHAPES

    if args.all:
        cells = [(a, s.name) for a, s in all_cells()]
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        cells = []
        for a in archs:
            names = ([args.shape] if args.shape
                     else [s.name for s in shape_cells(a)])
            for n in names:
                cells.append((a, n))

    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    failures = []
    for arch, shape_name in cells:
        for mp in pods:
            label = f"{arch} x {shape_name} x {'2-pod(512)' if mp else '1-pod(256)'}"
            try:
                res, compiled = run_cell(
                    arch, shape_name, mp, args.out,
                    microbatches=args.microbatches, compress=args.compress,
                    fsdp=not args.no_fsdp, remat=not args.no_remat,
                    tp=not args.no_tp,
                    save_hlo=not args.no_hlo, tag=args.tag)
                peak = res["memory"]["peak_estimate_per_device"] / 1e9
                flops = res["cost_analysis"].get("flops", 0)
                print(f"OK   {label}: peak/dev={peak:.2f}GB "
                      f"hlo_flops={flops:.3e} compile={res['compile_s']}s",
                      flush=True)
                del compiled
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((label, repr(e)))
                print(f"FAIL {label}: {e}", flush=True)
                traceback.print_exc()
    print(f"\n{len(cells) * len(pods) - len(failures)} passed, {len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
