"""ShapeDtypeStruct stand-ins for every (arch x shape) cell — the dry-run's
inputs.  Weak-type-correct, shardable, zero allocation.

Modality frontends are STUBS per the assignment: whisper gets precomputed
frame embeddings (B, S, d); internvl gets 256 patch embeddings that occupy
the first sequence positions.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig, ShapeConfig
from ..models.transformer import init_cache, init_params
from ..train.optimizer import adamw_init


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_sds(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for train/prefill cells."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32)}
    if shape.is_train:
        batch["labels"] = sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["patches"] = sds((B, cfg.vision_patches, cfg.d_model), cfg.jdtype)
    if cfg.encdec:
        batch["enc_inputs"] = sds((B, S, cfg.d_model), cfg.jdtype)
    return batch


def params_sds(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def opt_state_sds(params):
    return jax.eval_shape(adamw_init, params)


def cache_sds(cfg: ModelConfig, shape: ShapeConfig):
    """Serving cache at full context length (decode cells)."""
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: init_cache(cfg, B, S, enc_len=S if cfg.encdec else 0))


def decode_tokens_sds(shape: ShapeConfig):
    return sds((shape.global_batch,), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Everything the cell's step function consumes, as ShapeDtypeStructs."""
    params = params_sds(cfg)
    if shape.kind == "train":
        return {
            "params": params,
            "opt_state": opt_state_sds(params),
            "batch": batch_specs_sds(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs_sds(cfg, shape)}
    # decode
    return {
        "params": params,
        "cache": cache_sds(cfg, shape),
        "tokens": decode_tokens_sds(shape),
    }
