"""Fault-tolerant training launcher.

Survival story (designed for 1000+ nodes, exercised here on one host):
  * resume: on start, restore the newest valid checkpoint in --ckpt-dir
    (atomic commits mean a SIGKILL mid-write never corrupts; the preemption
    test kills -9 and resumes bitwise-identically);
  * elastic: checkpoints are topology-free (host numpy + manifest); restore
    re-device_puts onto whatever mesh the current launch built, so restarts
    may change device counts;
  * deterministic data: the stream is counter-keyed by (seed, step, host) —
    resuming at step k replays exactly batch k without reading history;
  * straggler mitigation: input pipeline prefetch thread + per-step deadline
    watchdog (steps slower than --straggler-factor x median are logged and
    counted; on multi-host this is where you'd trigger re-balancing);
  * SIGTERM (preemption notice): checkpoint immediately, exit 0.

Usage (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --reduced \
      --steps 20 --ckpt-dir /tmp/ckpt --ckpt-every 5
"""
from __future__ import annotations

import argparse
import signal
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--sleep-per-step", type=float, default=0.0)  # test hook
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_reduced_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.train import AdamWConfig, adamw_init, make_train_step
    from repro.train.checkpoint import (
        latest_checkpoint, restore_checkpoint, save_checkpoint)
    from repro.train.data import DataConfig, PrefetchIterator, TokenStream

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    start_step = 0

    if args.ckpt_dir:
        newest = latest_checkpoint(args.ckpt_dir)
        if newest is not None:
            _, state = restore_checkpoint(
                args.ckpt_dir, newest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            start_step = newest
            if not args.quiet:
                print(f"resumed from step {newest}", flush=True)

    train_step = jax.jit(make_train_step(cfg, opt_cfg, mesh,
                                         microbatches=args.microbatches))
    data = TokenStream(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=args.seed))
    it = PrefetchIterator(data, start_step=start_step)

    stop = {"now": False}

    def on_sigterm(signum, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, on_sigterm)

    step_times = []
    stragglers = 0
    step = start_step
    try:
        while step < args.steps:
            t0 = time.perf_counter()
            step, batch = next(it)
            if step >= args.steps:
                break
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = train_step(params, opt_state, jb)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if args.sleep_per_step:
                time.sleep(args.sleep_per_step)
            step_times.append(dt)
            med = float(np.median(step_times[-20:]))
            if len(step_times) > 3 and dt > args.straggler_factor * med:
                stragglers += 1
                if not args.quiet:
                    print(f"straggler: step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s)", flush=True)
            if not args.quiet:
                print(f"step {step + 1}/{args.steps} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s",
                      flush=True)
            step += 1
            if args.ckpt_dir and (step % args.ckpt_every == 0 or step == args.steps
                                  or stop["now"]):
                save_checkpoint(args.ckpt_dir, step,
                                {"params": params, "opt": opt_state},
                                keep=args.keep)
            if stop["now"]:
                if not args.quiet:
                    print("SIGTERM: checkpointed, exiting", flush=True)
                break
    finally:
        it.close()
    if not args.quiet:
        print(f"done at step {step}; stragglers flagged: {stragglers}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
