"""Serving launcher: batched decode with a KV/state cache.

Runs prefill over the prompt batch then streams decode steps; reports
tokens/s and per-step latency.  With --offload, layer weights stream from
host memory through the out-of-core 3-slot schedule (the paper's technique
applied to serving models larger than device memory — see
repro/models/offload.py).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_reduced_config
    from repro.models import decode_step, forward, init_params
    from repro.models.transformer import init_cache

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B = args.batch
    max_len = args.prompt_len + args.gen_tokens
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)

    cache = init_cache(cfg, B, max_len, enc_len=args.prompt_len)
    if cfg.encdec:
        # stub frontend: random frame embeddings -> encoder KV via one forward
        cache["enc_k"] = jnp.zeros_like(cache["enc_k"]) + 0.01
        cache["enc_v"] = jnp.zeros_like(cache["enc_v"]) + 0.01

    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    # prefill = teacher-forced decode over the prompt (exercises the cache
    # write path; a production server would batch-prefill via forward())
    t0 = time.perf_counter()
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, i])
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)
    lat = []
    generated = [tok]
    for i in range(args.gen_tokens - 1):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)
        tok.block_until_ready()
        lat.append(time.perf_counter() - t0)
        generated.append(tok)
    out = jnp.stack(generated, 1)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    if not args.quiet:
        lat_ms = 1e3 * float(np.mean(lat)) if lat else 0.0
        print(f"arch={cfg.name} batch={B} prefill={t_prefill:.2f}s "
              f"decode={lat_ms:.1f}ms/tok ({B * 1e3 / max(lat_ms, 1e-9):.0f} tok/s) "
              f"sample={np.asarray(out[0, :8]).tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
