"""Serving launchers.

Two entry points:

* **model decode** (default): batched decode with a KV/state cache — prefill
  over the prompt batch, then streamed decode steps; reports tokens/s and
  per-step latency.  With ``--offload``, layer weights stream from host
  memory through the out-of-core windowed schedule
  (:class:`repro.models.offload.StreamedDecoder` — the paper's technique
  applied to serving models larger than device memory); at most ``--window``
  layer slices are device-resident at any point.

* **stencil serving** (``stencil`` subcommand): the multi-tenant
  :class:`repro.serve.StencilServer` — N CloverLeaf2D tenants submitted from
  threads onto a shared ``sim:K`` lane pool with ledger-oracle admission
  control::

      python -m repro.launch.serve stencil --tenants 4 --mesh sim:2 \\
          --policy sjf --steps 3
"""
from __future__ import annotations

import argparse
import sys
import time


def stencil_main(argv=None):
    """Serve N stencil tenants through a shared StencilServer."""
    ap = argparse.ArgumentParser(prog="serve stencil")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--mesh", default="sim:2",
                    help="lane pool, e.g. sim:4 (default sim:2)")
    ap.add_argument("--policy", default="fifo",
                    help="scheduling policy: fifo | sjf")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--nx", type=int, default=48)
    ap.add_argument("--ny", type=int, default=48)
    ap.add_argument("--capacity-mb", type=float, default=4.0,
                    help="per-lane fast-memory capacity (forces tiling)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    import threading

    from repro.apps.cloverleaf2d import CloverLeaf2D
    from repro.serve import StencilServer

    t0 = time.perf_counter()
    with StencilServer(args.mesh, policy=args.policy,
                       capacity_bytes=args.capacity_mb * 1e6) as server:
        errs = []

        def tenant_work(i: int) -> None:
            try:
                app = CloverLeaf2D(nx=args.nx, ny=args.ny,
                                   summary_every=args.steps)
                rt = server.session(f"tenant-{i}", priority=i % 2)
                try:
                    app.run(rt, steps=args.steps)
                finally:
                    rt.close()
            except BaseException as e:
                errs.append((i, e))

        threads = [threading.Thread(target=tenant_work, args=(i,))
                   for i in range(args.tenants)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = server.stats()
    if errs:
        print(f"tenant failures: {errs}", file=sys.stderr)
        return 1
    if not args.quiet:
        print(stats.summary())
        print(f"wall {time.perf_counter() - t0:.2f}s for "
              f"{stats.jobs_completed} chains across {args.tenants} tenants")
    return 0


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "stencil":
        return stencil_main(argv[1:])

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--offload", action="store_true",
                    help="stream layer weights from host memory through the "
                         "out-of-core windowed schedule (dense/vlm families)")
    ap.add_argument("--window", type=int, default=3,
                    help="device-resident layer slices with --offload")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_reduced_config
    from repro.models import decode_step, init_params
    from repro.models.transformer import init_cache

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B = args.batch
    max_len = args.prompt_len + args.gen_tokens
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)

    cache = init_cache(cfg, B, max_len, enc_len=args.prompt_len)
    if cfg.encdec:
        # stub frontend: random frame embeddings -> encoder KV via one forward
        cache["enc_k"] = jnp.zeros_like(cache["enc_k"]) + 0.01
        cache["enc_v"] = jnp.zeros_like(cache["enc_v"]) + 0.01

    streamer = None
    if args.offload:
        if cfg.family not in ("dense", "vlm"):
            print(f"--offload supports dense/vlm families, not {cfg.family}",
                  file=sys.stderr)
            return 2
        from repro.models.offload import StreamedDecoder

        streamer = StreamedDecoder(params, cfg, window=args.window)

        def step(p, c, t):
            return streamer.decode(c, t)
    else:
        step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    # prefill = teacher-forced decode over the prompt (exercises the cache
    # write path; a production server would batch-prefill via forward())
    t0 = time.perf_counter()
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, i])
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)
    lat = []
    generated = [tok]
    for i in range(args.gen_tokens - 1):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)
        tok.block_until_ready()
        lat.append(time.perf_counter() - t0)
        generated.append(tok)
    out = jnp.stack(generated, 1)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    if not args.quiet:
        lat_ms = 1e3 * float(np.mean(lat)) if lat else 0.0
        line = (f"arch={cfg.name} batch={B} prefill={t_prefill:.2f}s "
                f"decode={lat_ms:.1f}ms/tok "
                f"({B * 1e3 / max(lat_ms, 1e-9):.0f} tok/s) "
                f"sample={np.asarray(out[0, :8]).tolist()}")
        if streamer is not None:
            line += (f" offload[window={streamer.window} "
                     f"resident={streamer.device_resident_bytes() / 1e6:.1f}MB "
                     f"modelled={streamer.stats.modelled_step_s * 1e3:.2f}ms/step]")
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
