"""repro.serve — multi-tenant serving over a shared device pool.

The ROADMAP's production serving layer: a :class:`StencilServer` admits many
concurrent tenant :class:`~repro.core.Session`\\ s, schedules their chain
plans onto a pool of out-of-core executor lanes (sized by a
``DeviceMesh`` — ``sim:N`` for deterministic CI), uses the Plan-IR ledger
interpreter as an admission-control oracle, shares chain plans across
tenants under the tenant-neutral ``shared_plan_signature``, and preempts /
migrates long-running jobs at chain boundaries via the PR-4
checkpoint/restore machinery.

Quick start::

    from repro.serve import StencilServer

    with StencilServer("sim:4", policy="sjf") as server:
        rt = server.session("alice", priority=1)
        app.run(rt, steps=5)        # any app: Sessions are unchanged
        print(server.stats().summary())
"""
from .cache import SharedPlanCache
from .errors import AdmissionError, ServeError, UnknownTenantError
from .oracle import AdmissionOracle, AdmissionVerdict
from .policy import (
    JobView,
    SchedulingPolicy,
    available_policies,
    make_policy,
    register_policy,
)
from .server import ServerClient, StencilServer
from .stats import ServerStats, TenantStats

__all__ = [
    "AdmissionError", "AdmissionOracle", "AdmissionVerdict", "JobView",
    "SchedulingPolicy", "ServeError", "ServerClient", "ServerStats",
    "SharedPlanCache", "StencilServer", "TenantStats", "UnknownTenantError",
    "available_policies", "make_policy", "register_policy",
]
