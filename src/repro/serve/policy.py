"""Pluggable scheduling policies (string-keyed registry, like
``repro.core.backends``).

A policy orders the *waiting* jobs each time a lane frees up.  Priority
classes always dominate (the preemption contract depends on higher-priority
tenants being served first); within a class the policy decides:

==========  ==============================================================
``fifo``    arrival order (submission sequence number)
``sjf``     cost-aware shortest-predicted-makespan first, from the
            admission oracle's ledger prediction; ties broken by arrival
==========  ==============================================================

Register your own::

    @register_policy("my-policy")
    class MyPolicy(SchedulingPolicy):
        def select(self, waiting):
            return min(waiting, key=...)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple, Type


@dataclass(frozen=True)
class JobView:
    """What a policy sees of one waiting job — deliberately value-only, so
    policies cannot reach into server internals."""

    tenant: str
    seq: int                        # global submission sequence number
    priority: int                   # higher preempts/schedules first
    predicted_makespan_s: float     # oracle prediction for the pending chain


class SchedulingPolicy:
    """Base class: pick the next job to grant a lane."""

    name: str = "?"

    def select(self, waiting: Sequence[JobView]) -> JobView:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[SchedulingPolicy]] = {}


def register_policy(name: str) -> Callable[[Type[SchedulingPolicy]],
                                           Type[SchedulingPolicy]]:
    """Decorator registering a :class:`SchedulingPolicy` subclass."""
    def deco(cls: Type[SchedulingPolicy]) -> Type[SchedulingPolicy]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_policy(name: str) -> SchedulingPolicy:
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(f"unknown scheduling policy {name!r}; "
                         f"available: {', '.join(available_policies())}")
    return cls()


@register_policy("fifo")
class FifoPolicy(SchedulingPolicy):
    """Arrival order within each priority class."""

    def select(self, waiting: Sequence[JobView]) -> JobView:
        return min(waiting, key=lambda j: (-j.priority, j.seq))


@register_policy("sjf")
class ShortestJobFirst(SchedulingPolicy):
    """Shortest predicted makespan (the admission oracle's ledger estimate)
    within each priority class — classic mean-queue-wait minimiser."""

    def select(self, waiting: Sequence[JobView]) -> JobView:
        return min(waiting,
                   key=lambda j: (-j.priority, j.predicted_makespan_s, j.seq))
