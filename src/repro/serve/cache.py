"""Cross-tenant shared chain-plan cache.

``OutOfCoreExecutor`` memoises :class:`~repro.core.executor.ChainPlan`
objects per-executor, keyed by ``plan_signature`` — which embeds dataset
*object identity*, so two tenants running the same app on their own datasets
can never share a plan that way.  The server hands every lane executor (and
the admission oracle's sim executor) one :class:`SharedPlanCache`; executors
consult it on a local miss under the tenant-neutral
``shared_plan_signature`` key and feed it on every build.  A hit replays the
donor's analysis, tile schedule, instruction stream and — the real win — its
compiled :class:`~repro.core.engine.TileEngine` with its jit cache, rebound
to the adopter's datasets (``OutOfCoreExecutor._adopt_shared``).

Soundness: equal shared signatures mean isomorphic dataset layouts and
value-identical kernels (``kernel_fingerprint`` hashes code + captured
constants; captures that are not plain data fingerprint by identity and so
never match across tenants).  All config knobs that shape a plan are part of
the key, codecs included — but note the README caveat: a *lossy* codec
registered under one name for two tenants shares plans by name, as it does
within a single session.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.executor import ChainPlan


class SharedPlanCache:
    """Thread-safe LRU of ``(shared_key) -> (ChainPlan, first_tenant)``.

    ``lookup``/``insert`` are the executor-facing protocol (see
    ``OutOfCoreExecutor.plan_chain``); the tenant argument only feeds the
    cross-tenant hit counters surfaced in :class:`~repro.serve.ServerStats`.
    """

    def __init__(self, max_plans: int = 128) -> None:
        self.max_plans = max_plans
        self._lock = threading.Lock()
        self._plans: "OrderedDict[Tuple, Tuple[ChainPlan, Optional[str]]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.cross_tenant_hits = 0

    def lookup(self, key: Tuple, tenant: Optional[str]) -> "Optional[ChainPlan]":
        with self._lock:
            ent = self._plans.get(key)
            if ent is None:
                self.misses += 1
                return None
            self._plans.move_to_end(key)
            self.hits += 1
            if ent[1] is not None and tenant is not None and ent[1] != tenant:
                self.cross_tenant_hits += 1
            return ent[0]

    def insert(self, key: Tuple, plan: "ChainPlan",
               tenant: Optional[str]) -> None:
        with self._lock:
            if key in self._plans:
                # First writer wins: keep the donor attribution (and its
                # engine — concurrent builders racing here built equivalent
                # plans, either is fine).
                self._plans.move_to_end(key)
                return
            self._plans[key] = (plan, tenant)
            self.inserts += 1
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "plans": len(self._plans),
                "hits": self.hits,
                "misses": self.misses,
                "inserts": self.inserts,
                "cross_tenant_hits": self.cross_tenant_hits,
            }
