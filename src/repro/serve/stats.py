"""Per-tenant and server-level observability."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class TenantStats:
    """One tenant's service record.

    ``predicted_s`` accumulates the admission oracle's modelled makespans,
    ``achieved_modelled_s`` the ledger makespans the executing interpreter
    actually recorded — both come from the same :class:`TransferLedger`
    model, so their ratio is the serving layer's *scheduling* overhead
    signal (cache warmth, splits), not model error.

    ``queue_wait_s`` (and every other wall-time in these rows) is read from
    the server's single injected clock (``StencilServer(clock=...)``) — the
    same source the :mod:`repro.obs` tracer stamps serve spans with, so the
    predicted-vs-achieved rows and the trace timeline can be compared
    instant-for-instant."""

    tenant: str
    priority: int = 0
    state: str = "idle"             # idle | queued | running | preempted | closed
    lane: Optional[int] = None
    chains: int = 0
    loops: int = 0
    queue_wait_s: float = 0.0       # wall time spent waiting for a lane grant
    predicted_s: float = 0.0
    achieved_modelled_s: float = 0.0
    preemptions: int = 0
    rejected: int = 0               # AdmissionError count
    plan_hits: int = 0              # lane-level plan-cache hits while running

    @property
    def predicted_vs_achieved(self) -> float:
        """achieved / predicted modelled time (1.0 = oracle-exact)."""
        if self.predicted_s <= 0.0:
            return 1.0
        return self.achieved_modelled_s / self.predicted_s


@dataclass
class ServerStats:
    """A point-in-time snapshot assembled by :meth:`StencilServer.stats`."""

    policy: str
    lanes: int
    mesh: str
    tenants: Dict[str, TenantStats] = field(default_factory=dict)
    jobs_completed: int = 0
    jobs_rejected: int = 0
    preemptions: int = 0
    lane_busy_modelled_s: List[float] = field(default_factory=list)
    plan_cache: Dict[str, int] = field(default_factory=dict)

    @property
    def cross_tenant_plan_hits(self) -> int:
        return int(self.plan_cache.get("cross_tenant_hits", 0))

    def summary(self) -> str:
        """Human-readable multi-line digest (the ``--serve`` bench prints
        this per policy)."""
        lines = [
            f"server[{self.mesh} policy={self.policy}]: "
            f"{self.jobs_completed} chains served, "
            f"{self.jobs_rejected} rejected, {self.preemptions} preemptions, "
            f"{self.cross_tenant_plan_hits} cross-tenant plan hits",
            "  lane busy (modelled): "
            + " ".join(f"l{i}={t * 1e3:.2f}ms"
                       for i, t in enumerate(self.lane_busy_modelled_s)),
        ]
        for name in sorted(self.tenants):
            t = self.tenants[name]
            lines.append(
                f"  {name}: prio={t.priority} chains={t.chains} "
                f"wait={t.queue_wait_s * 1e3:.1f}ms "
                f"predicted={t.predicted_s * 1e3:.2f}ms "
                f"achieved={t.achieved_modelled_s * 1e3:.2f}ms "
                f"(x{t.predicted_vs_achieved:.2f}) "
                f"preempted={t.preemptions}")
        return "\n".join(lines)
