"""The multi-tenant stencil server.

A :class:`StencilServer` owns a pool of *lanes* — one full
:class:`~repro.core.executor.OutOfCoreExecutor` per device of a
:class:`~repro.core.mesh.DeviceMesh` (``sim:N`` lanes are ordinary CPU-hosted
executors; the mesh supplies the pool size and the deterministic CI story) —
plus one :class:`~repro.serve.SharedPlanCache` and one ledger-backed
:class:`~repro.serve.AdmissionOracle` shared by everything.

Tenants attach with :meth:`session`, which returns an ordinary
:class:`~repro.core.Session` whose backend is a :class:`ServerClient`; the
three bundled apps run through it unchanged.  Every flushed chain becomes one
*job*:

1. the admission oracle lowers it to Plan IR (through the shared cache) and
   predicts footprint + makespan; jobs that cannot fit even after splitting
   raise :class:`~repro.serve.AdmissionError` at the submit site;
2. the job queues; when a lane frees, the scheduling policy (``fifo`` /
   ``sjf`` — priority classes always dominate) picks the next grant;
3. the chain executes on the granted lane.  A lane keeps the previous
   tenant's device-side caches warm and resets them only on tenant change,
   so a tenant bouncing between chains on one lane keeps its pinned arrays.

Chains are atomic (the paper's unit of scheduling); preemption happens at
chain boundaries, where dataset homes are authoritative.  A preempt-flagged
tenant's next submit checkpoints its datasets to the server spill directory
(:func:`~repro.core.store.save_checkpoint` — the PR-4 machinery), re-enters
the queue behind the higher-priority work, restores on re-grant (possibly on
a *different* lane: migration) and resumes bit-identically.

Determinism: tenants own disjoint datasets and kernels are pure, so results
never depend on which lane ran a chain or in what order jobs were granted —
concurrency moves wall-clock time only.  ``tests/test_serve.py`` pins this
against serial runs under both policies.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Union,
                    TYPE_CHECKING)

from repro.core.backends import _ooc_executor
from repro.core.memory import TPU_V5E, HardwareModel
from repro.core.mesh import parse_mesh
from repro.core.program import ExecutionConfig, Session, SessionClosedError
from repro.core.store import load_checkpoint, save_checkpoint
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import AnyTracer, Tracer, as_tracer

from .cache import SharedPlanCache
from .errors import AdmissionError, ServeError, UnknownTenantError
from .oracle import AdmissionOracle, AdmissionVerdict
from .policy import JobView, SchedulingPolicy, make_policy
from .stats import ServerStats, TenantStats

if TYPE_CHECKING:  # pragma: no cover - typing only
    import numpy as np

    from repro.core.dataset import Dataset
    from repro.core.executor import ChainStats, OutOfCoreExecutor
    from repro.core.loop import ParallelLoop


class _ClientCfg:
    """The ``backend.cfg`` shim a :class:`ServerClient` exposes so
    ``Session.cyclic = True`` (what the apps set) lands per-tenant instead of
    mutating a shared lane config."""

    def __init__(self, hw: HardwareModel) -> None:
        self.cyclic = False
        self.hw = hw


@dataclass
class _Tenant:
    """Server-side record of one attached session."""

    name: str
    priority: int
    cfg: _ClientCfg
    state: str = "idle"
    lane: Optional[int] = None             # lease held only while running
    closed: bool = False
    preempt_requested: bool = False
    needs_cache_reset: bool = False        # set by Session.restore()
    ckpt_path: Optional[str] = None
    datasets: Dict[str, "Dataset"] = field(default_factory=dict)
    history: List["ChainStats"] = field(default_factory=list)
    # counters mirrored into TenantStats snapshots
    chains: int = 0
    loops: int = 0
    queue_wait_s: float = 0.0
    predicted_s: float = 0.0
    achieved_modelled_s: float = 0.0
    preemptions: int = 0
    rejected: int = 0
    plan_hits: int = 0
    last_pred_s: float = 0.0


class ServerClient:
    """The Session backend that routes ``run_chain`` to a server.

    Built by :meth:`StencilServer.session`; implements exactly the backend
    protocol :mod:`repro.core.backends` documents (``run_chain``, ``cfg``,
    ``history``, ``close``) plus the data-cache hook ``Session.restore``
    calls."""

    def __init__(self, server: "StencilServer", tenant: str,
                 cfg: _ClientCfg) -> None:
        self._server = server
        self._tenant = tenant
        self.cfg = cfg

    @property
    def tenant(self) -> str:
        return self._tenant

    @property
    def tracer(self) -> AnyTracer:
        """The server-wide tracer (shared by every lane), so
        ``Session.trace()`` works on server-backed sessions too."""
        return self._server.tracer

    def run_chain(self, loops: Sequence["ParallelLoop"]
                  ) -> Dict[str, "np.ndarray"]:
        return self._server.submit(self._tenant, loops)

    @property
    def history(self) -> List["ChainStats"]:
        return self._server.tenant_history(self._tenant)

    def reset_data_caches(self) -> None:
        self._server.flag_cache_reset(self._tenant)

    def close(self) -> None:
        self._server.deregister(self._tenant)


class StencilServer:
    """Admit many tenant Sessions onto one shared lane pool.

    ``mesh`` sizes the pool (``"sim:4"`` = four virtual lanes — the whole
    server is CI-testable with deterministic modelled time); the remaining
    knobs mirror :class:`~repro.core.program.ExecutionConfig` and apply to
    every lane uniformly, which is what makes cross-tenant plan sharing
    sound (config knobs are part of the shared-cache key)."""

    def __init__(self, mesh: Union[str, int, None] = "sim:4", *,
                 policy: str = "fifo",
                 backend: str = "ooc",
                 hw: Union[HardwareModel, str] = TPU_V5E,
                 capacity_bytes: Optional[float] = None,
                 num_slots: int = 3,
                 num_tiles: Optional[int] = None,
                 tiled_dim: int = 0,
                 prefetch: bool = False,
                 flops_per_point: Optional[int] = None,
                 transfer: str = "sync",
                 codec: Union[str, Dict[str, str]] = "identity",
                 host_capacity: Optional[float] = None,
                 spill_dir: Optional[str] = None,
                 auto_preempt: bool = True,
                 max_shared_plans: int = 128,
                 trace: Union[bool, Tracer] = False,
                 clock: Optional[Callable[[], float]] = None) -> None:
        if backend not in ("ooc", "ooc-async", "sim"):
            raise ServeError(
                f"serving lanes must be ooc-family executors, got {backend!r}")
        self.mesh = parse_mesh(mesh if mesh is not None else 1)
        # One wall-clock source for everything the server times: tenant
        # queue-wait accounting (ServerStats predicted-vs-achieved rows),
        # serve-layer spans and lane spans all read ``self._clock`` — inject
        # a fake in tests to pin them to the same instants.
        self._clock: Callable[[], float] = (
            clock if clock is not None else time.perf_counter)
        self.tracer: AnyTracer = as_tracer(trace)
        if self.tracer.enabled:
            self.tracer.clock = self._clock  # type: ignore[method-assign]
        self.metrics_registry = MetricsRegistry()
        self._config = ExecutionConfig(
            backend="ooc", hw=hw, capacity_bytes=capacity_bytes,
            num_slots=num_slots, num_tiles=num_tiles, tiled_dim=tiled_dim,
            prefetch=prefetch, flops_per_point=flops_per_point,
            simulate_only=(backend == "sim"),
            transfer=("threaded" if backend == "ooc-async" else transfer),
            codec=codec, host_capacity=host_capacity)
        self.plan_cache = SharedPlanCache(max_plans=max_shared_plans)
        self.lanes: List["OutOfCoreExecutor"] = [
            _ooc_executor(self._config, shared_plans=self.plan_cache)
            for _ in range(self.mesh.num_devices)]
        # The tracer rides on the lanes directly rather than through
        # ``self._config`` so the admission oracle's sim executor (which
        # shares that config) never pollutes the trace with phantom runs.
        for i, lane_ex in enumerate(self.lanes):
            lane_ex.tracer = self.tracer
            lane_ex.trace_tag = f"lane{i}/"
        self.oracle = AdmissionOracle(self._config, self.plan_cache)
        self.policy: SchedulingPolicy = make_policy(policy)
        self.auto_preempt = auto_preempt
        self._own_spill = spill_dir is None
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="repro-serve-")
        self._cond = threading.Condition()
        self._tenants: Dict[str, _Tenant] = {}
        self._free: List[int] = list(range(self.mesh.num_devices))
        self._waiting: List[JobView] = []
        self._seq = 0
        self._lane_busy: List[float] = [0.0] * self.mesh.num_devices
        self.jobs_completed = 0
        self.jobs_rejected = 0
        self.preemptions = 0
        self._closed = False

    # -- tenant lifecycle -------------------------------------------------------
    def session(self, tenant: Optional[str] = None, *,
                priority: int = 0) -> Session:
        """Register a tenant and return its :class:`Session` (backend =
        :class:`ServerClient`).  ``Session.close()`` deregisters it."""
        with self._cond:
            if self._closed:
                raise ServeError("server is closed")
            name = tenant or f"tenant-{len(self._tenants)}"
            existing = self._tenants.get(name)
            if existing is not None and not existing.closed:
                raise ServeError(f"tenant {name!r} is already attached")
            ten = _Tenant(name=name, priority=priority,
                          cfg=_ClientCfg(hw=self._config.hw))
            self._tenants[name] = ten
        return Session(backend=ServerClient(self, name, ten.cfg))

    def deregister(self, name: str) -> None:
        """Detach a tenant (idempotent; called by ``Session.close``)."""
        with self._cond:
            ten = self._tenants.get(name)
            if ten is None or ten.closed:
                return
            ten.closed = True
            ten.state = "closed"
            self._cond.notify_all()

    def _tenant(self, name: str) -> _Tenant:
        ten = self._tenants.get(name)
        if ten is None:
            raise UnknownTenantError(f"unknown tenant {name!r}")
        if ten.closed:
            raise SessionClosedError(
                f"tenant {name!r} submitted work after Session.close()")
        return ten

    # -- the job path -----------------------------------------------------------
    def submit(self, name: str, loops: Sequence["ParallelLoop"]
               ) -> Dict[str, "np.ndarray"]:
        """Admit, queue, and execute one chain for ``name``; returns its
        reduction results.  Blocks until a lane is granted and the chain has
        run.  Raises :class:`AdmissionError` if the oracle rejects it."""
        loops = list(loops)
        with self._cond:
            ten = self._tenant(name)
            for lp in loops:
                for a in lp.args:
                    ten.datasets[a.dat.name] = a.dat
            cyclic = ten.cfg.cyclic
        tr = self.tracer
        mr = self.metrics_registry
        t_adm = tr.clock() if tr.enabled else 0.0
        verdict = self.oracle.predict(loops, cyclic=cyclic, tenant=name)
        if tr.enabled:
            tr.emit("admit", cat="serve", track=f"tenant/{name}",
                    t_start=t_adm, t_end=tr.clock(),
                    args={"tenant": name, "admitted": verdict.admitted,
                          "predicted_s": verdict.predicted_makespan_s})
        if not verdict.admitted:
            with self._cond:
                ten.rejected += 1
                self.jobs_rejected += 1
            mr.counter("jobs_rejected").inc()
            raise AdmissionError(
                f"job rejected for tenant {name!r}: {verdict.reason}",
                predicted_bytes=verdict.predicted_bytes,
                capacity_bytes=verdict.capacity_bytes)

        preempt_path: Optional[str] = None
        with self._cond:
            if ten.preempt_requested and ten.datasets:
                preempt_path = os.path.join(
                    self.spill_dir, f"{name}.preempt.npz")
        if preempt_path is not None:
            # Chain boundary: homes are authoritative, so the snapshot is the
            # tenant's whole live state.  Taken outside the server lock —
            # only this tenant's thread touches these datasets.
            t_ck = tr.clock() if tr.enabled else 0.0
            save_checkpoint(preempt_path, list(ten.datasets.values()),
                            chains_flushed=ten.chains)
            if tr.enabled:
                tr.emit("preempt-checkpoint", cat="serve",
                        track=f"tenant/{name}",
                        t_start=t_ck, t_end=tr.clock(),
                        args={"tenant": name,
                              "datasets": len(ten.datasets)})
            mr.counter("preemptions").inc()
            with self._cond:
                ten.preempt_requested = False
                ten.preemptions += 1
                self.preemptions += 1
                ten.state = "preempted"
                ten.ckpt_path = preempt_path
                ten.needs_cache_reset = True

        t0 = self._clock()
        with self._cond:
            lane_idx = self._await_grant_locked(ten, verdict)
            t_grant = self._clock()
            ten.queue_wait_s += t_grant - t0
            ten.state = "running"
            ten.last_pred_s = verdict.predicted_makespan_s
            ten.predicted_s += verdict.predicted_makespan_s
        if tr.enabled:
            tr.emit("queue-wait", cat="serve", track=f"tenant/{name}",
                    t_start=t0, t_end=t_grant,
                    args={"tenant": name, "lane": lane_idx})
        mr.histogram("queue_wait_s").observe(t_grant - t0)
        mr.gauge("queue_depth").set(float(len(self._waiting)))
        lane = self.lanes[lane_idx]
        try:
            if lane.tenant != name or ten.needs_cache_reset:
                lane.reset_data_caches()
                lane.tenant = name
                ten.needs_cache_reset = False
            if ten.ckpt_path is not None:
                # Resume after preemption — possibly on a different lane
                # (migration).  Restoring re-materialises the exact homes the
                # checkpoint captured, so the resumed run is bit-identical.
                t_rs = tr.clock() if tr.enabled else 0.0
                load_checkpoint(ten.ckpt_path, list(ten.datasets.values()))
                lane.reset_data_caches()
                ten.ckpt_path = None
                if tr.enabled:
                    tr.emit("preempt-restore", cat="serve",
                            track=f"tenant/{name}",
                            t_start=t_rs, t_end=tr.clock(),
                            args={"tenant": name, "lane": lane_idx})
            lane.cfg.cyclic = bool(ten.cfg.cyclic)
            h0 = len(lane.history)
            hits0 = lane.plan_hits
            reds = lane.run_chain(loops)
            with self._cond:
                new = lane.history[h0:]
                achieved = sum(cs.modelled_s for cs in new)
                ten.history.extend(new)
                ten.achieved_modelled_s += achieved
                self._lane_busy[lane_idx] += achieved
                ten.plan_hits += lane.plan_hits - hits0
                ten.chains += 1
                ten.loops += len(loops)
                self.jobs_completed += 1
            mr.counter("jobs_completed").inc()
            mr.histogram("achieved_modelled_s").observe(achieved)
            return reds
        finally:
            with self._cond:
                ten.state = "idle" if not ten.closed else "closed"
                self._release_locked(ten)
            if tr.enabled:
                # The lane lease: one slice per job on the lane's own track,
                # named after the tenant that held it.
                tr.emit(name, cat="lease", track=f"lane{lane_idx}",
                        t_start=t_grant, t_end=tr.clock(),
                        args={"tenant": name, "lane": lane_idx,
                              "predicted_s": verdict.predicted_makespan_s})

    def _next_seq_locked(self) -> int:
        self._seq += 1
        return self._seq

    def _await_grant_locked(self, ten: _Tenant,
                            verdict: AdmissionVerdict) -> int:
        """Queue this job and block until the policy grants it a lane."""
        entry = JobView(tenant=ten.name, seq=self._next_seq_locked(),
                        priority=ten.priority,
                        predicted_makespan_s=verdict.predicted_makespan_s)
        self._waiting.append(entry)
        ten.state = "queued" if ten.state != "preempted" else ten.state
        try:
            while True:
                if ten.closed:
                    raise SessionClosedError(
                        f"tenant {ten.name!r} was closed while queued")
                if self._closed:
                    raise ServeError("server closed while a job was queued")
                if self._free:
                    pick = self.policy.select(self._waiting)
                    if pick is entry:
                        lane_idx = self._free.pop(0)   # lowest index: sticky
                        ten.lane = lane_idx
                        self._waiting.remove(entry)
                        self._cond.notify_all()
                        return lane_idx
                if self.auto_preempt:
                    self._flag_victim_locked(entry)
                # Timed wait: a missed notify (or a policy pick that went to
                # another waiter) must not strand this job.
                self._cond.wait(timeout=0.05)
        except BaseException:
            if entry in self._waiting:
                self._waiting.remove(entry)
            self._cond.notify_all()
            raise

    def _flag_victim_locked(self, waiter: JobView) -> None:
        """With every lane busy and a higher-priority job waiting, flag the
        lowest-priority *running* tenant: at its next chain boundary it
        checkpoints, yields its place and re-queues behind this job."""
        if self._free:
            return
        running = [t for t in self._tenants.values()
                   if t.state == "running" and not t.preempt_requested]
        victims = [t for t in running if t.priority < waiter.priority]
        if not victims:
            return
        victim = min(victims, key=lambda t: (t.priority, t.name))
        victim.preempt_requested = True

    def _release_locked(self, ten: _Tenant) -> None:
        if ten.lane is not None:
            self._free.append(ten.lane)
            self._free.sort()
            ten.lane = None
        self._cond.notify_all()

    # -- preemption -------------------------------------------------------------
    def preempt(self, name: str) -> None:
        """Flag ``name`` for preemption.  Takes effect at the tenant's next
        chain boundary (its next submit): checkpoint, re-queue, restore on
        re-grant.  Chains themselves are atomic."""
        with self._cond:
            ten = self._tenant(name)
            ten.preempt_requested = True
            self._cond.notify_all()

    # -- client plumbing --------------------------------------------------------
    def tenant_history(self, name: str) -> List["ChainStats"]:
        with self._cond:
            ten = self._tenants.get(name)
            return list(ten.history) if ten is not None else []

    def flag_cache_reset(self, name: str) -> None:
        """Session.restore() hook: device-side caches that could shadow the
        restored homes must die before the tenant's next chain."""
        with self._cond:
            ten = self._tenants.get(name)
            if ten is not None:
                ten.needs_cache_reset = True

    # -- observability ----------------------------------------------------------
    def sla_estimate(self, name: str) -> Dict[str, float]:
        """A tenant's service outlook: queue depth, a queue-wait estimate
        (total predicted work waiting, spread over the lanes) and the
        oracle's prediction for its most recent chain shape."""
        with self._cond:
            ten = self._tenant(name)
            backlog = sum(j.predicted_makespan_s for j in self._waiting)
            return {
                "queued_jobs": float(len(self._waiting)),
                "predicted_queue_wait_s": backlog / max(len(self.lanes), 1),
                "predicted_makespan_s": ten.last_pred_s,
            }

    def metrics(self) -> Dict[str, Any]:
        """Snapshot of the server's :class:`~repro.obs.MetricsRegistry` —
        counters (``jobs_completed`` / ``jobs_rejected`` / ``preemptions``),
        the ``queue_wait_s`` and ``achieved_modelled_s`` histograms, and
        instantaneous scheduler gauges.  All timings in it were read from the
        same injected clock the tracer and :meth:`stats` rows use."""
        mr = self.metrics_registry
        with self._cond:
            mr.gauge("queue_depth").set(float(len(self._waiting)))
            mr.gauge("free_lanes").set(float(len(self._free)))
            mr.gauge("tenants").set(float(sum(
                1 for t in self._tenants.values() if not t.closed)))
        return mr.snapshot()

    def stats(self) -> ServerStats:
        """Snapshot of every counter the serving layer keeps."""
        with self._cond:
            tenants = {
                name: TenantStats(
                    tenant=name, priority=t.priority, state=t.state,
                    lane=t.lane, chains=t.chains, loops=t.loops,
                    queue_wait_s=t.queue_wait_s, predicted_s=t.predicted_s,
                    achieved_modelled_s=t.achieved_modelled_s,
                    preemptions=t.preemptions, rejected=t.rejected,
                    plan_hits=t.plan_hits)
                for name, t in self._tenants.items()}
            return ServerStats(
                policy=self.policy.name, lanes=len(self.lanes),
                mesh=self.mesh.spec, tenants=tenants,
                jobs_completed=self.jobs_completed,
                jobs_rejected=self.jobs_rejected,
                preemptions=self.preemptions,
                lane_busy_modelled_s=list(self._lane_busy),
                plan_cache=self.plan_cache.stats())

    # -- lifecycle --------------------------------------------------------------
    def close(self) -> None:
        """Detach every tenant, release lane resources (transfer-engine
        workers), drop the spill directory if the server created it.
        Idempotent."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            for ten in self._tenants.values():
                ten.closed = True
                ten.state = "closed"
            self._cond.notify_all()
        for lane in self.lanes:
            lane.close()
        self.oracle.close()
        if self._own_spill:
            shutil.rmtree(self.spill_dir, ignore_errors=True)

    def __enter__(self) -> "StencilServer":
        return self

    def __exit__(self, exc_type: object, exc: object, tb: object) -> None:
        self.close()
