"""Ledger-interpreter admission control.

The Plan IR gives the server an exact cost model *before* any data moves: a
submitted chain is lowered to its instruction stream(s) through the shared
plan cache (so repeat chains cost a cache lookup), then costed with
``simulate_plan`` on cold caches.  The oracle answers two questions:

* **does it fit** — mirror ``run_chain``'s MemoryError chain-splitting; if
  even single-loop chains cannot fit the slot pool, the job is *rejected*
  (typed :class:`~repro.serve.AdmissionError` at the submit site) instead of
  wedging a lane at run time;
* **how long will it take** — the summed modelled makespan, which the
  scheduler's cost-aware policy and the per-tenant SLA estimates consume.

Because the oracle's sim executor shares the server's ``SharedPlanCache``,
the plans it builds during admission are the very plans the data-plane lanes
replay — predicted and achieved makespans come from one ledger model.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import FrozenSet, List, Optional, Sequence, TYPE_CHECKING

from repro.core.interp import predict_plans
from repro.core.tune import make_sim_executor

from .cache import SharedPlanCache

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.executor import OutOfCoreExecutor
    from repro.core.loop import ParallelLoop
    from repro.core.plan import Plan
    from repro.core.program import ExecutionConfig


@dataclass(frozen=True)
class AdmissionVerdict:
    """The oracle's prediction for one submitted chain."""

    admitted: bool
    predicted_makespan_s: float      # summed modelled makespan, all splits
    predicted_bytes: int             # peak fast-memory footprint of any plan
    capacity_bytes: float            # the pool capacity it was checked against
    chains: int                      # plans after MemoryError splitting
    reason: str = ""                 # human-readable rejection cause


class AdmissionOracle:
    """Predict footprint and makespan for a chain on this server's config.

    One ledger-only executor, serialised by a lock (planning mutates its
    caches); its plan cache is the server's shared one, so admission work is
    never thrown away — the lane that later runs the job replays the same
    plans.
    """

    def __init__(self, config: "ExecutionConfig",
                 shared: SharedPlanCache) -> None:
        self._ex: "OutOfCoreExecutor" = make_sim_executor(
            config, shared_plans=shared)
        self._lock = threading.Lock()
        self.capacity_bytes: float = float(self._ex.cfg.capacity)
        self.hw = self._ex.cfg.hw
        self.predictions = 0
        self.rejections = 0

    def predict(self, loops: Sequence["ParallelLoop"], *,
                cyclic: bool = False,
                tenant: Optional[str] = None) -> AdmissionVerdict:
        """Lower ``loops`` (one chain) and cost it.  Never raises for a
        too-big job — rejection is a verdict, the server turns it into a
        typed ``AdmissionError`` at the submit site."""
        with self._lock:
            self._ex.cfg.cyclic = bool(cyclic)
            self._ex.tenant = tenant
            self.predictions += 1
            try:
                plans = self._plan_split(list(loops), frozenset(), frozenset())
            except MemoryError as e:
                self.rejections += 1
                return AdmissionVerdict(
                    admitted=False, predicted_makespan_s=0.0,
                    predicted_bytes=0, capacity_bytes=self.capacity_bytes,
                    chains=0,
                    reason=f"no tiling fits even single-loop chains: {e}")
            makespan, peak = predict_plans(plans, self.hw)
            return AdmissionVerdict(
                admitted=True, predicted_makespan_s=makespan,
                predicted_bytes=peak, capacity_bytes=self.capacity_bytes,
                chains=len(plans))

    def close(self) -> None:
        self._ex.close()

    def _plan_split(self, loops: List["ParallelLoop"],
                    keep_live: FrozenSet[str],
                    warm: FrozenSet[str]) -> List["Plan"]:
        """``Session._plan_split``'s policy, verbatim: the oracle must
        predict exactly the chains ``run_chain`` will execute."""
        try:
            ir = self._ex.plan_chain(loops, keep_live, warm=warm).ir
            return list(ir) if isinstance(ir, tuple) else [ir]
        except MemoryError:
            if len(loops) <= 1:
                raise
            mid = len(loops) // 2
            head, tail = loops[:mid], loops[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            head_writes = frozenset(
                a.dat.name for lp in head for a in lp.args if a.mode.writes)
            return (self._plan_split(head, keep_live | tail_reads, warm)
                    + self._plan_split(tail, keep_live, warm | head_writes))
