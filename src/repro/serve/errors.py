"""Typed errors for the serving layer."""
from __future__ import annotations


class ServeError(RuntimeError):
    """Base class for serving-layer failures."""


class AdmissionError(ServeError):
    """The admission oracle rejected a job: its predicted fast-memory
    footprint cannot fit the pool even after chain splitting down to single
    loops (``run_chain`` would die with MemoryError — the server refuses it
    up front instead of wedging a lane)."""

    def __init__(self, message: str, *, predicted_bytes: int = 0,
                 capacity_bytes: float = 0.0) -> None:
        super().__init__(message)
        self.predicted_bytes = predicted_bytes
        self.capacity_bytes = capacity_bytes


class UnknownTenantError(ServeError):
    """An operation referenced a tenant the server has never registered (or
    one already deregistered by :meth:`Session.close`)."""
