"""granite-34b [dense]: 88L d6144 48H (GQA kv=1, MQA) ff24576 V=49152 — code.
[arXiv:2405.04324; hf]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=3, d_model=128, num_heads=4, num_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512, dtype="float32")
