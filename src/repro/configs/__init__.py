"""Assigned architecture registry: ``get_config(arch_id)`` and, per arch,
``reduced_config()`` (CPU smoke) and the set of runnable shape cells.

Every full config matches the assignment block verbatim; deviations/notes
live in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import LONG_CONTEXT_OK, SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "llama3_2_1b",
    "granite_34b",
    "tinyllama_1_1b",
    "qwen2_5_14b",
    "qwen3_moe_30b_a3b",
    "deepseek_v2_lite_16b",
    "zamba2_1_2b",
    "whisper_medium",
    "internvl2_76b",
    "mamba2_1_3b",
]

def _module(arch: str):
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f".{arch}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).reduced_config()


def shape_cells(arch: str) -> List[ShapeConfig]:
    """The shape cells this arch runs (skips per DESIGN.md noted here)."""
    cfg = get_config(arch)
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.name in LONG_CONTEXT_OK:
        cells.append(SHAPES["long_500k"])
    return cells


def all_cells():
    for arch in ARCH_IDS:
        for shape in shape_cells(arch):
            yield arch, shape
