"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d2048, ssm_state=64, plus a SHARED
attention+MLP block (32H MHA kv=32, ff 8192) applied every 6th layer.
[arXiv:2411.15242; hf]  Simplification noted in DESIGN.md: per-invocation
LoRA deltas on the shared block are omitted.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid", ssm=True,
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        shared_attn_every=6,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=6, d_model=128, num_heads=4, num_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=512, ssm_state=16,
                          ssm_headdim=32, shared_attn_every=3, dtype="float32")
