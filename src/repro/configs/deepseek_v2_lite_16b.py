"""deepseek-v2-lite-16b [moe]: 27L d2048 16H V=102400, MLA kv_lora=512
(qk_nope 128, qk_rope 64, v_head 128), 64 routed experts top-6 + 2 shared,
per-expert ff 1408, first layer dense (ff 10944).
[arXiv:2405.04434; hf]  Note: assignment line says "GQA kv=16" — MLA makes
kv_heads == num_heads structurally; we implement true MLA per the paper.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", moe=True, mla=True,
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        num_experts=64, experts_per_token=6, moe_d_ff=1408,
        num_shared_experts=2, first_dense_layers=1, dense_d_ff=10944,
        norm_topk=False,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
                          vocab_size=512, kv_lora_rank=32, qk_nope_dim=16,
                          qk_rope_dim=8, v_head_dim=16, num_experts=8,
                          experts_per_token=2, moe_d_ff=64, d_ff=64,
                          dense_d_ff=96, dtype="float32")
