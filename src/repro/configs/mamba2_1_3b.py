"""mamba2-1.3b [ssm]: 48L d2048 attn-free, ssm_state=128, headdim 64,
expand 2, conv 4 — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", ssm=True,
        num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=3, d_model=128, vocab_size=512,
                          ssm_state=16, ssm_headdim=32, dtype="float32")
