"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) V=151936,
MoE 128 experts top-8, per-expert ff 768, norm_topk.
[hf:Qwen/Qwen3-30B-A3B]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", moe=True,
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        num_experts=128, experts_per_token=8, moe_d_ff=768,
        norm_topk=True, rope_theta=1000000.0,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, vocab_size=512, num_experts=8,
                          experts_per_token=2, moe_d_ff=96, d_ff=96, dtype="float32")
