"""qwen2.5-14b [dense]: 48L d5120 40H (GQA kv=8) ff13824 V=152064, QKV bias.
[hf:Qwen/Qwen2.5-14B; hf]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=13824, vocab_size=152064, qkv_bias=True,
        rope_theta=1000000.0,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, d_model=160, num_heads=4, num_kv_heads=2,
                          head_dim=40, d_ff=288, vocab_size=512, dtype="float32")
