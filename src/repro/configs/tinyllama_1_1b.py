"""tinyllama-1.1b [dense]: 22L d2048 32H (GQA kv=4) ff5632 V=32000.
[arXiv:2401.02385; hf]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=64, d_ff=5632, vocab_size=32000,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, d_ff=192, vocab_size=512, dtype="float32")
