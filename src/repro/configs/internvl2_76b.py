"""internvl2-76b [vlm]: LM backbone = 80L d8192 64H (GQA kv=8) ff28672
V=128256 (InternLM2/llama-arch); InternViT frontend STUBBED — input_specs
supplies 256 patch embeddings that occupy the first sequence slots.
[arXiv:2404.16821; unverified]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=128256, vision_patches=256,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, d_ff=256, vocab_size=512,
                          vision_patches=8, dtype="float32")
