"""llama3.2-1b [dense]: 16L d2048 32H (GQA kv=8) ff8192 V=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
        head_dim=64, d_ff=8192, vocab_size=128256,
        rope_theta=500000.0, tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, d_ff=256, vocab_size=512, dtype="float32")
