"""whisper-medium [audio, enc-dec]: 24L enc + 24L dec, d1024 16H MHA ff4096
V=51865; conv frontend STUBBED — input_specs supplies precomputed frame
embeddings (B, S_enc, d). [arXiv:2212.04356; unverified]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec", encdec=True,
        num_layers=24, enc_layers=24, d_model=1024, num_heads=16,
        num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=51865,
    )


def reduced_config() -> ModelConfig:
    return config().with_(num_layers=2, enc_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
                          dtype="float32")
