"""Low-overhead span tracing.

The tracing spine is a single :class:`Tracer` shared by every layer of one
run (executor, interpreters, transfer lanes, sharded mesh, serve).  Design
constraints, in order:

* **Disabled is free.**  Instrumentation sites hold a tracer reference and
  guard on ``tracer.enabled`` — a plain class attribute, so the untraced hot
  path pays one attribute load and a branch.  ``NullTracer.span()`` returns a
  module-level singleton context manager: no allocation either.
* **Thread-safe.**  Threaded transfer lanes and serve worker threads emit
  concurrently; the span buffer is a ``deque`` guarded by a lock.
* **Bounded.**  The buffer is a ring (``capacity`` spans); old spans are
  dropped, never the run.  ``Tracer.dropped`` counts evictions.
* **One clock.**  ``Tracer.clock`` is an injectable ``() -> float`` (default
  ``time.perf_counter``) so serve-layer stats and spans cannot disagree, and
  tests can pin time.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, Iterable, List, Optional,
                    Union)


class Span:
    """One half-open ``[t_start, t_end)`` interval on a named track.

    Times are seconds on the emitting tracer's clock — wall-clock for data
    planes, *modelled* seconds for the sim interpreter (the drift audit
    exploits exactly that).  ``args`` is a small JSON-able dict; by
    convention spans tied to ledger events carry ``eid`` (one event) or
    ``eids`` (inline ops covering several), plus ``op`` (the plan op index
    shown by ``format_plan`` as ``#N``) and ``chain``.
    """

    __slots__ = ("name", "cat", "track", "t_start", "t_end", "args")

    def __init__(self, name: str, cat: str, track: str,
                 t_start: float, t_end: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        self.name = name
        self.cat = cat
        self.track = track
        self.t_start = t_start
        self.t_end = t_end
        self.args = args

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "cat": self.cat, "track": self.track,
                "t_start": self.t_start, "t_end": self.t_end,
                "args": self.args or {}}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, track={self.track!r}, "
                f"t={self.t_start:.6f}..{self.t_end:.6f})")


class _SpanCtx:
    """Context manager minted by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, track: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._args = args
        self._t0 = 0.0

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc: object) -> None:
        self._tracer.emit(self._name, cat=self._cat, track=self._track,
                          t_start=self._t0, t_end=self._tracer.clock(),
                          args=self._args)


class _NullCtx:
    """Singleton no-op context manager — ``NullTracer.span()`` allocates
    nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullCtx":
        return self

    def __exit__(self, *exc: object) -> None:
        return None


_NULL_CTX = _NullCtx()


class Tracer:
    """Thread-safe, ring-buffered span recorder."""

    enabled = True

    def __init__(self, capacity: int = 1 << 16,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.clock = clock
        self.capacity = int(capacity)
        self._spans: Deque[Span] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.dropped = 0

    def emit(self, name: str, *, cat: str = "", track: str = "",
             t_start: float, t_end: float,
             args: Optional[Dict[str, Any]] = None) -> Span:
        """Record a finished span.  Safe from any thread."""
        span = Span(name, cat, track, t_start, t_end, args)
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(span)
        return span

    def span(self, name: str, *, cat: str = "", track: str = "",
             args: Optional[Dict[str, Any]] = None) -> _SpanCtx:
        """``with tracer.span("scatter", track="mesh"): ...`` — times the
        body on this tracer's clock and emits on exit."""
        return _SpanCtx(self, name, cat, track, args)

    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    # -- convenience exporters -------------------------------------------
    def chrome(self) -> Dict[str, Any]:
        """Chrome trace-event document for the current buffer."""
        from .chrome import chrome_trace
        return chrome_trace(self.spans())

    def save(self, path: str) -> Dict[str, Any]:
        """Write the Chrome trace to ``path`` (open in Perfetto)."""
        doc = self.chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


class NullTracer:
    """Disabled tracer: every instrumentation site checks ``enabled`` first,
    so in practice none of these methods run on hot paths."""

    enabled = False
    clock = staticmethod(time.perf_counter)

    def emit(self, name: str, *, cat: str = "", track: str = "",
             t_start: float, t_end: float,
             args: Optional[Dict[str, Any]] = None) -> None:
        return None

    def span(self, name: str, *, cat: str = "", track: str = "",
             args: Optional[Dict[str, Any]] = None) -> _NullCtx:
        return _NULL_CTX

    def spans(self) -> List[Span]:
        return []

    def clear(self) -> None:
        return None

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()

AnyTracer = Union[Tracer, NullTracer]


def as_tracer(spec: object) -> AnyTracer:
    """Resolve a user-facing ``trace=`` value to a tracer.

    ``None``/``False`` → the shared :data:`NULL_TRACER`; ``True`` → a fresh
    :class:`Tracer`; a tracer instance → itself (lets callers share one
    spine across executors, devices and serve lanes).
    """
    if spec is None or spec is False:
        return NULL_TRACER
    if spec is True:
        return Tracer()
    if isinstance(spec, (Tracer, NullTracer)):
        return spec
    raise TypeError(f"trace= expects bool, None or a Tracer; got {spec!r}")


def merge_spans(*traces: Union[AnyTracer, Iterable[Span]]) -> List[Span]:
    """Combine spans from several tracers/iterables, ordered by start time."""
    out: List[Span] = []
    for tr in traces:
        out.extend(tr.spans() if hasattr(tr, "spans") else tr)  # type: ignore[union-attr]
    out.sort(key=lambda s: (s.t_start, s.t_end))
    return out
