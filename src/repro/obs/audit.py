"""Modelled-vs-achieved drift audit.

The ledger gives every chain a *modelled* timeline: ``simulate()`` assigns
each :class:`repro.core.memory.Event` a ``t_start``/``t_end`` on its stream.
A traced run gives the *achieved* timeline: spans carrying ``eid`` (lane
spans, modelled spans) or ``eids`` (dispatch spans covering ops executed
inline on the issue thread).  :func:`compare` aligns the two event-by-event
and reports, per stream, the achieved/modelled time ratio plus the top-k
divergent ops — turning "the sim says N× speed-up" into a falsifiable
per-op claim (``format_plan`` prints the same ``#op`` indices, and
``repro.core.verify`` diagnostics cite them as ``op N``).

The oracle case: a sim-mode run emits its spans *from* the modelled
timeline, so ``compare`` must report a per-stream ratio of exactly ``1.0``
— both sides accumulate the identical floats in the identical order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, List, Optional, Tuple, Union,
                    TYPE_CHECKING)

from .tracer import Span, Tracer

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.memory import TransferLedger

STREAM_NAMES: Dict[int, str] = {
    0: "compute", 1: "upload", 2: "download", 3: "disk", 4: "network"}


def stream_name(stream: int) -> str:
    return STREAM_NAMES.get(stream, f"stream{stream}")


@dataclass
class OpDrift:
    """One matched ledger event: modelled vs achieved duration."""

    op: int                 # plan op index (#N in format_plan; -1 unknown)
    eid: int                # ledger event id
    kind: str               # event kind ("upload", "compute", ...)
    stream: int
    modelled_s: float
    achieved_s: float

    @property
    def ratio(self) -> float:
        if self.modelled_s == 0.0:
            return 1.0 if self.achieved_s == 0.0 else float("inf")
        return self.achieved_s / self.modelled_s

    @property
    def divergence(self) -> float:
        """Symmetric distance from ratio 1.0 used for top-k ranking."""
        r = self.ratio
        if r <= 0.0:
            return float("inf")
        return r if r >= 1.0 else 1.0 / r


@dataclass
class StreamDrift:
    """Per-stream aggregate over the matched events."""

    stream: int
    name: str
    events: int = 0         # ledger events on this stream
    matched: int = 0        # ... with an achieved span
    modelled_s: float = 0.0
    achieved_s: float = 0.0

    @property
    def ratio(self) -> float:
        if self.modelled_s == 0.0:
            return 1.0 if self.achieved_s == 0.0 else float("inf")
        return self.achieved_s / self.modelled_s


@dataclass
class DriftReport:
    """Output of :func:`compare`."""

    streams: Dict[int, StreamDrift]
    ops: List[OpDrift] = field(default_factory=list)  # matched events
    unmatched_events: int = 0   # ledger events with no achieved span
    spans_seen: int = 0         # spans considered after filtering

    def top(self, k: int = 5) -> List[OpDrift]:
        """The k most divergent matched ops (ties broken by modelled time)."""
        ranked = sorted(self.ops,
                        key=lambda o: (o.divergence, o.modelled_s),
                        reverse=True)
        return ranked[:k]

    @property
    def overall_ratio(self) -> float:
        modelled = sum(s.modelled_s for s in self.streams.values())
        achieved = sum(s.achieved_s for s in self.streams.values())
        if modelled == 0.0:
            return 1.0 if achieved == 0.0 else float("inf")
        return achieved / modelled

    def summary(self, top_k: int = 5) -> str:
        lines = ["drift audit (achieved / modelled):"]
        for sid in sorted(self.streams):
            s = self.streams[sid]
            lines.append(
                f"  {s.name:<9} ratio {s.ratio:10.4g}  "
                f"modelled {s.modelled_s:.6g}s  achieved {s.achieved_s:.6g}s  "
                f"({s.matched}/{s.events} events matched)")
        if self.unmatched_events:
            lines.append(f"  unmatched ledger events: {self.unmatched_events}")
        top = self.top(top_k)
        if top:
            lines.append(f"  top-{len(top)} divergent ops:")
            for o in top:
                lines.append(
                    f"    op #{o.op} {o.kind:<10} [{stream_name(o.stream)}] "
                    f"modelled {o.modelled_s:.6g}s achieved "
                    f"{o.achieved_s:.6g}s ratio {o.ratio:.4g}")
        return "\n".join(lines)


def _achieved_by_eid(spans: Iterable[Span]) -> Tuple[
        Dict[int, float], Dict[int, int]]:
    """Map eid -> achieved duration (and -> plan op index when known).

    Spans with a single ``eid`` (lane spans, sim modelled spans) take
    precedence over ``eids`` dispatch spans: the former time the event
    itself, the latter time the issuing op and are only used for events
    executed inline on the issue thread.
    """
    achieved: Dict[int, float] = {}
    op_of: Dict[int, int] = {}
    deferred: List[Span] = []
    for s in spans:
        a = s.args
        if not a:
            continue
        eid = a.get("eid")
        if eid is not None:
            achieved[eid] = s.t_end - s.t_start
            if "op" in a:
                op_of[eid] = a["op"]
        elif a.get("eids"):
            deferred.append(s)
    for s in deferred:
        a = s.args or {}
        eids = [e for e in a["eids"] if e not in achieved]
        if not eids:
            continue
        # An inline op's dispatch time covers all its events; attribute it
        # proportionally to the modelled share later — here, split evenly.
        share = (s.t_end - s.t_start) / len(eids)
        for e in eids:
            achieved[e] = share
            if "op" in a:
                op_of[e] = a["op"]
    return achieved, op_of


def compare(ledger: "TransferLedger",
            trace: Union[Tracer, Iterable[Span]], *,
            chain: Optional[int] = None,
            tag: str = "") -> DriftReport:
    """Align achieved spans against the ledger's modelled event stream.

    ``chain`` filters spans by their ``chain`` arg (each executor numbers
    chains in submission order — pass the index of the ledger's chain);
    ``tag`` filters by track prefix (e.g. ``"dev0/"`` on a sharded run,
    ``"lane2/"`` on a serve lane).
    """
    spans: List[Span] = (trace.spans() if isinstance(trace, Tracer)
                         else list(trace))
    if tag:
        spans = [s for s in spans if s.track.startswith(tag)]
    if chain is not None:
        spans = [s for s in spans
                 if s.args is not None and s.args.get("chain") == chain]
    ledger.simulate()  # idempotent: fills Event.t_start/t_end
    achieved, op_of = _achieved_by_eid(spans)

    streams: Dict[int, StreamDrift] = {}
    ops: List[OpDrift] = []
    unmatched = 0
    for ev in ledger.events:
        sd = streams.get(ev.stream)
        if sd is None:
            sd = streams[ev.stream] = StreamDrift(
                stream=ev.stream, name=stream_name(ev.stream))
        sd.events += 1
        got: Any = achieved.get(ev.eid)
        if got is None:
            unmatched += 1
            continue
        modelled = ev.t_end - ev.t_start
        sd.matched += 1
        sd.modelled_s += modelled
        sd.achieved_s += got
        ops.append(OpDrift(op=op_of.get(ev.eid, -1), eid=ev.eid,
                           kind=ev.kind, stream=ev.stream,
                           modelled_s=modelled, achieved_s=got))
    return DriftReport(streams=streams, ops=ops,
                       unmatched_events=unmatched, spans_seen=len(spans))
