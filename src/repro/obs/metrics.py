"""Structured metrics: counters, gauges, histograms, and a registry.

Instruments are individually locked (serve worker threads update them
concurrently); snapshots are plain JSON-able dicts so they can ride inside
``Session.transfer_stats()`` / ``StencilServer.metrics()`` without dragging
this module into every consumer.

Histograms use fixed decade buckets tuned for seconds-scale latencies
(1 µs … 100 s) — queue waits and service times across sim and real hardware
span that whole range, and fixed bounds make per-device snapshots mergeable
(:func:`merge_histogram_snapshots`, used by the sharded executor to fold
per-device lane histograms into one ``transfer_stats()`` view).
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

DEFAULT_BUCKETS: Tuple[float, ...] = tuple(10.0 ** e for e in range(-6, 3))


class Counter:
    """Monotonic counter."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max.

    ``buckets`` are upper bounds; an observation lands in the first bucket
    whose bound is >= the value, or in ``overflow``.
    """

    __slots__ = ("_lock", "bounds", "counts", "overflow", "count", "sum",
                 "min", "max")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS) -> None:
        self._lock = threading.Lock()
        self.bounds: Tuple[float, ...] = tuple(bounds)
        self.counts: List[int] = [0] * len(self.bounds)
        self.overflow = 0
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            i = bisect.bisect_left(self.bounds, v)
            if i < len(self.bounds):
                self.counts[i] += 1
            else:
                self.overflow += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            mean = self.sum / self.count if self.count else 0.0
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "buckets": [[b, c] for b, c in zip(self.bounds, self.counts)],
                "overflow": self.overflow,
            }


def merge_histogram_snapshots(a: Dict[str, Any],
                              b: Dict[str, Any]) -> Dict[str, Any]:
    """Fold two :meth:`Histogram.snapshot` dicts into one (same bounds)."""
    if not a:
        return dict(b)
    if not b:
        return dict(a)
    ab = [bound for bound, _ in a["buckets"]]
    bb = [bound for bound, _ in b["buckets"]]
    if ab != bb:
        raise ValueError("cannot merge histograms with different buckets")
    count = a["count"] + b["count"]
    total = a["sum"] + b["sum"]
    lo = min(x["min"] for x in (a, b) if x["count"]) if count else 0.0
    hi = max(x["max"] for x in (a, b) if x["count"]) if count else 0.0
    return {
        "count": count,
        "sum": total,
        "mean": total / count if count else 0.0,
        "min": lo,
        "max": hi,
        "buckets": [[bound, ca + cb] for (bound, ca), (_, cb)
                    in zip(a["buckets"], b["buckets"])],
        "overflow": a["overflow"] + b["overflow"],
    }


class MetricsRegistry:
    """Named instruments behind one lock; ``snapshot()`` is a plain dict."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter()
            return inst

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge()
            return inst

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(
                    bounds if bounds is not None else DEFAULT_BUCKETS)
            return inst

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": {k: c.snapshot()
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.snapshot()
                           for k, g in sorted(self._gauges.items())},
                "histograms": {k: h.snapshot()
                               for k, h in sorted(self._histograms.items())},
            }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent)
