"""Chrome trace-event JSON export.

Emits the "JSON Array Format" subset every trace viewer understands
(Perfetto, ``chrome://tracing``, speedscope): one ``ph="X"`` complete event
per span with microsecond ``ts``/``dur``, plus ``ph="M"`` metadata events
naming the process and one thread per distinct span track — so compute,
upload, download, disk, network, per-device and per-tenant activity each get
their own swim-lane.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Union

from .tracer import Span, Tracer

_SpanSource = Union[Tracer, Iterable[Span]]


def _spans(source: _SpanSource) -> List[Span]:
    if hasattr(source, "spans"):
        return source.spans()  # type: ignore[union-attr]
    return list(source)  # type: ignore[arg-type]


def chrome_trace(source: _SpanSource, *,
                 process_name: str = "repro") -> Dict[str, Any]:
    """Build a Chrome trace-event document from spans (or a tracer)."""
    spans = _spans(source)
    tids: Dict[str, int] = {}
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    for s in spans:
        track = s.track or "main"
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
            events.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": track},
            })
        events.append({
            "name": s.name,
            "cat": s.cat or "span",
            "ph": "X",
            "ts": s.t_start * 1e6,
            "dur": (s.t_end - s.t_start) * 1e6,
            "pid": 0,
            "tid": tid,
            "args": s.args or {},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(source: _SpanSource, path: str, *,
                        process_name: str = "repro") -> Dict[str, Any]:
    """Write the Chrome trace for ``source`` to ``path`` and return it."""
    doc = chrome_trace(source, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> None:
    """Schema-check a trace document; raises ``ValueError`` on violations.

    Checks the invariants viewers rely on: a ``traceEvents`` list, complete
    events with numeric non-negative ``ts``/``dur`` and a ``tid`` that has a
    ``thread_name`` metadata event, JSON-serialisable ``args``.
    """
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    named_tids = {0}
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                raise ValueError(f"unknown metadata event {ev.get('name')!r}")
            named_tids.add(ev["tid"])
        elif ph == "X":
            for key in ("name", "ts", "dur", "pid", "tid"):
                if key not in ev:
                    raise ValueError(f"complete event missing {key!r}: {ev}")
            if not isinstance(ev["ts"], (int, float)):
                raise ValueError("ts must be numeric")
            if not isinstance(ev["dur"], (int, float)) or ev["dur"] < 0:
                raise ValueError("dur must be numeric and non-negative")
            if ev["tid"] not in named_tids:
                raise ValueError(f"tid {ev['tid']} has no thread_name event")
            json.dumps(ev.get("args", {}))
        else:
            raise ValueError(f"unexpected event phase {ph!r}")


def spans_from_chrome(doc: Dict[str, Any]) -> List[Span]:
    """Reconstruct spans from a Chrome trace document (the round-trip of
    :func:`chrome_trace`; times come back with µs precision)."""
    tracks: Dict[int, str] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tracks[ev["tid"]] = ev["args"]["name"]
    out: List[Span] = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        t0 = ev["ts"] / 1e6
        out.append(Span(ev["name"], ev.get("cat", ""),
                        tracks.get(ev["tid"], "main"),
                        t0, t0 + ev["dur"] / 1e6, ev.get("args") or None))
    return out
