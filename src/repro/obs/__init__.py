"""repro.obs — unified runtime tracing & metrics spine.

One observability layer for the whole stack:

* :mod:`repro.obs.tracer` — a low-overhead span :class:`Tracer` (thread-safe,
  ring-buffered) with a :class:`NullTracer` default so untraced hot paths pay
  a single attribute check.  Spans are emitted by ``OutOfCoreExecutor``
  (per-chain / per-tile / per-plan-op), the ``TransferEngine`` worker lanes,
  ``ShardedOutOfCoreExecutor`` (per-device streams + halo exchange) and
  ``repro.serve.StencilServer`` (admission, queue-wait, lane lease,
  preempt/restore).
* :mod:`repro.obs.chrome` — Chrome trace-event JSON export (one track per
  stream/lane/device/tenant, viewable in Perfetto / ``chrome://tracing``).
* :mod:`repro.obs.metrics` — counters / gauges / histograms behind a
  :class:`MetricsRegistry`, surfaced as ``StencilServer.metrics()`` and the
  per-lane histograms in ``Session.transfer_stats()``.
* :mod:`repro.obs.audit` — the modelled-vs-achieved **drift audit**:
  :func:`repro.obs.audit.compare` aligns the achieved span timeline against
  the ``LedgerInterpreter``'s modelled event stream op-by-op and reports
  per-stream ratios plus the top-k divergent ops.

This package deliberately imports nothing from :mod:`repro.core` at runtime —
the core layers import *us*, never the reverse.
"""
from __future__ import annotations

from .audit import DriftReport, OpDrift, StreamDrift, compare
from .chrome import (chrome_trace, export_chrome_trace, spans_from_chrome,
                     validate_chrome_trace)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      merge_histogram_snapshots)
from .tracer import NULL_TRACER, NullTracer, Span, Tracer, as_tracer

__all__ = [
    "Span", "Tracer", "NullTracer", "NULL_TRACER", "as_tracer",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "merge_histogram_snapshots",
    "chrome_trace", "export_chrome_trace", "spans_from_chrome",
    "validate_chrome_trace",
    "compare", "DriftReport", "StreamDrift", "OpDrift",
]
