"""The paper's benchmark applications, written against the repro.core DSL.

Structural fidelity to the originals (dataset counts, stencil shapes, access
modes, loop-chain lengths, reduction placement) is what drives the paper's
performance behaviour, and is what these implementations reproduce:

* ``cloverleaf2d`` — compressible Euler, staggered grid, Lagrangian
  (predictor/corrector) + directionally-split advection; ~25 datasets, dt
  min-reduction every step (chain breaker), field summary every 10 steps.
* ``cloverleaf3d`` — the 3-D variant (more datasets, deeper chains).
* ``opensbli`` — 3-D Taylor–Green vortex, RK3, no reductions in the main
  phase: chains may span an arbitrary number of timesteps (the paper tiles
  over 1–3 steps on GPUs, 5 with UM).

The kernel formulas are simplified-but-physical equivalents of the original
Fortran (documented in DESIGN.md §Arch-applicability); every run is validated
by out-of-core == reference-executor equivalence and NaN/boundedness checks,
which is what the paper's analysis needs (its metric is bytes/time, not
solution error).
"""
from .cloverleaf2d import CloverLeaf2D
from .cloverleaf3d import CloverLeaf3D
from .opensbli import OpenSBLI

__all__ = ["CloverLeaf2D", "CloverLeaf3D", "OpenSBLI"]
