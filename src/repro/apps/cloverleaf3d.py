"""CloverLeaf 3D on the repro.core DSL.

Same structure as :mod:`cloverleaf2d` extended to three dimensions and a
third velocity pair + z-fluxes: 30 datasets (§5.1), three directionally-split
advection sweeps per step (x/y/z rotated each step), deeper chains
(~40 loops/step), dt MIN-reduction chain breaker each step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from ..core import (
    Block,
    ReductionSpec,
    Session,
    make_dataset,
    offset_stencil,
    point_stencil,
)

_GAMMA = 1.4
_AXES = {"x": (1, 0, 0), "y": (0, 1, 0), "z": (0, 0, 1)}


@dataclass
class CloverLeaf3D:
    nx: int
    ny: int
    nz: int
    dtype: type = np.float32
    summary_every: int = 10
    # Home-copy tier (repro.core.store): None/"ram", "mmap", "chunked", or
    # a StoreConfig.
    store: object = None
    # Device mesh for make_session(): None or a repro.core.parse_mesh spec
    # (int, "sim:N"/"jax:N", DeviceMesh); decomposes dim 1.
    mesh: object = None

    def make_session(self, backend: str = None, **overrides) -> Session:
        """A Session wired for this app's ``mesh=`` knob (``ooc-sharded``
        over the mesh; plain ``ooc`` when unsharded)."""
        kw: Dict[str, object] = {}
        if self.mesh is not None:
            kw["mesh"] = self.mesh
            backend = backend or "ooc-sharded"
        kw.update(overrides)
        return Session(backend or "ooc", **kw)

    def __post_init__(self):
        nx, ny, nz = self.nx, self.ny, self.nz
        self.block = Block("clover3d", (nx, ny, nz))
        mk = lambda name: make_dataset(self.block, name, halo=2,
                                       dtype=self.dtype, store=self.store)
        names = [
            "density0", "density1", "energy0", "energy1", "pressure",
            "viscosity", "soundspeed", "volume",
            "vol_flux_x", "vol_flux_y", "vol_flux_z",
            "mass_flux_x", "mass_flux_y", "mass_flux_z",
            "pre_vol", "post_vol", "pre_mass", "post_mass", "advec_vol",
            "post_ener", "ener_flux", "xarea", "yarea", "zarea",
            "xvel0", "xvel1", "yvel0", "yvel1", "zvel0", "zvel1",
        ]
        self.dats = {n: mk(n) for n in names}
        assert len(self.dats) == 30
        self.S0 = point_stencil(3)
        self.S_p = {a: offset_stencil((0, 0, 0), _AXES[a]) for a in "xyz"}
        self.S_node = offset_stencil(
            (0, 0, 0), (-1, 0, 0), (0, -1, 0), (0, 0, -1),
            (-1, -1, 0), (-1, 0, -1), (0, -1, -1), (-1, -1, -1),
        )
        self.S_adv = {
            a: offset_stencil(
                tuple(-2 * o for o in _AXES[a]), tuple(-1 * o for o in _AXES[a]),
                (0, 0, 0), _AXES[a], tuple(2 * o for o in _AXES[a]),
            )
            for a in "xyz"
        }
        self.step_count = 0
        self.dt = 1e-4

    def d(self, name):
        return self.dats[name]

    def _interior(self):
        return ((0, self.nx), (0, self.ny), (0, self.nz))

    def _adv_range(self):
        return ((2, self.nx - 2), (2, self.ny - 2), (2, self.nz - 2))

    # -- init -----------------------------------------------------------------
    def record_init(self, rt: Session) -> None:
        nx, ny, nz = self.nx, self.ny, self.nz
        hx, hy, hz = 2 * np.pi / nx, 2 * np.pi / ny, 2 * np.pi / nz

        def k_init(acc):
            ix, iy, iz = acc.coords()
            x = ix.astype(jnp.float32) * hx
            y = iy.astype(jnp.float32) * hy
            z = iz.astype(jnp.float32) * hz
            one = jnp.ones(acc.shape, jnp.float32)
            return {
                "density0": 1.0 + 0.2 * jnp.sin(x) * jnp.cos(y) * jnp.cos(z),
                "energy0": 2.5 + 0.5 * jnp.cos(x),
                "volume": one, "xarea": one, "yarea": one, "zarea": one,
                "xvel0": 0.1 * jnp.sin(x),
                "yvel0": -0.1 * jnp.cos(y),
                "zvel0": 0.05 * jnp.sin(z),
            }

        rt.par_loop(
            "initialise3d", self.block, self._interior(),
            [self.d(n) for n in ("density0", "energy0", "volume", "xarea",
                                  "yarea", "zarea", "xvel0", "yvel0", "zvel0")],
            k_init,
        )

        def k_zero(acc):
            zf = jnp.zeros(acc.shape, jnp.float32)
            return {n: zf for n in ("density1", "energy1", "pressure", "viscosity",
                                     "soundspeed", "xvel1", "yvel1", "zvel1")}

        rt.par_loop(
            "zero_fields3d", self.block, self._interior(),
            [self.d(n) for n in ("density1", "energy1", "pressure",
                                  "viscosity", "soundspeed", "xvel1", "yvel1",
                                  "zvel1")],
            k_zero,
        )

    # -- physics ----------------------------------------------------------------
    def _ideal_gas(self, rt, rho_name, e_name, tag):
        def k(acc):
            rho = acc(rho_name)
            p = (_GAMMA - 1.0) * rho * acc(e_name)
            ss = jnp.sqrt(jnp.maximum(_GAMMA * p / jnp.maximum(rho, 1e-10), 1e-10))
            return {"pressure": p, "soundspeed": ss}

        rt.par_loop(
            f"ideal_gas3d{tag}", self.block, self._interior(),
            [self.d(rho_name), self.d(e_name), self.d("pressure"),
             self.d("soundspeed")],
            k,
        )

    def _viscosity(self, rt):
        def k(acc):
            div = ((acc("xvel0", (1, 0, 0)) - acc("xvel0"))
                   + (acc("yvel0", (0, 1, 0)) - acc("yvel0"))
                   + (acc("zvel0", (0, 0, 1)) - acc("zvel0")))
            return {"viscosity": jnp.where(div < 0, 2.0 * acc("density0") * div * div, 0.0)}

        rt.par_loop(
            "viscosity3d", self.block, self._interior(),
            [self.d("xvel0"), self.d("yvel0"), self.d("zvel0"),
             self.d("density0"), self.d("viscosity")],
            k,
        )

    def _calc_dt(self, rt):
        def k(acc):
            speed = (acc("soundspeed") + jnp.abs(acc("xvel0"))
                     + jnp.abs(acc("yvel0")) + jnp.abs(acc("zvel0")))
            return {"dt": jnp.min(0.5 / jnp.maximum(speed, 1e-6) / max(self.nx, self.ny, self.nz))}

        rt.par_loop(
            "calc_dt3d", self.block, self._interior(),
            [self.d(n) for n in ("soundspeed", "xvel0", "yvel0", "zvel0")],
            k, reductions=[ReductionSpec("dt", "min")],
        )

    def _pdv(self, rt, predict, tag):
        dt = self.dt * (0.5 if predict else 1.0)

        def k(acc):
            div = ((acc("xvel0", (1, 0, 0)) - acc("xvel0"))
                   + (acc("yvel0", (0, 1, 0)) - acc("yvel0"))
                   + (acc("zvel0", (0, 0, 1)) - acc("zvel0")))
            rho = acc("density0") / jnp.maximum(1.0 + dt * div, 0.1)
            e = acc("energy0") - dt * acc("pressure") * div / jnp.maximum(acc("density0"), 1e-10)
            return {"density1": rho, "energy1": e}

        rt.par_loop(
            f"pdv3d_{tag}", self.block, self._interior(),
            [self.d("xvel0"), self.d("yvel0"), self.d("zvel0"),
             self.d("density0"), self.d("energy0"), self.d("pressure"),
             self.d("density1"), self.d("energy1")],
            k,
        )

    def _revert(self, rt):
        def k(acc):
            return {"density1": acc("density0"), "energy1": acc("energy0")}

        rt.par_loop(
            "revert3d", self.block, self._interior(),
            [self.d("density0"), self.d("energy0"), self.d("density1"),
             self.d("energy1")],
            k,
        )

    def _accelerate(self, rt):
        dt = self.dt
        rng = ((1, self.nx), (1, self.ny), (1, self.nz))

        def k(acc):
            nodal = 0.125 * sum(
                acc("density0", o) for o in self.S_node.points
            )
            upd = {}
            for vel, ax in (("xvel", (-1, 0, 0)), ("yvel", (0, -1, 0)), ("zvel", (0, 0, -1))):
                grad = (acc("pressure") - acc("pressure", ax)
                        + acc("viscosity") - acc("viscosity", ax))
                upd[f"{vel}1"] = acc(f"{vel}0") - dt * grad / jnp.maximum(nodal, 1e-10)
            return upd

        rt.par_loop(
            "accelerate3d", self.block, rng,
            [self.d("density0"), self.d("pressure"), self.d("viscosity")]
            + [self.d(f"{v}0") for v in ("xvel", "yvel", "zvel")]
            + [self.d(f"{v}1") for v in ("xvel", "yvel", "zvel")],
            k,
        )

    def _flux_calc(self, rt):
        dt = self.dt

        def k(acc):
            return {
                "vol_flux_x": 0.5 * dt * (acc("xvel1") + acc("xvel1", (0, 1, 0))) * acc("xarea"),
                "vol_flux_y": 0.5 * dt * (acc("yvel1") + acc("yvel1", (0, 0, 1))) * acc("yarea"),
                "vol_flux_z": 0.5 * dt * (acc("zvel1") + acc("zvel1", (1, 0, 0))) * acc("zarea"),
            }

        rt.par_loop(
            "flux_calc3d", self.block, self._interior(),
            [self.d("xvel1"), self.d("yvel1"), self.d("zvel1")]
            + [self.d(a) for a in ("xarea", "yarea", "zarea")]
            + [self.d(f) for f in ("vol_flux_x", "vol_flux_y", "vol_flux_z")],
            k,
        )

    def _advec_cell(self, rt, sweep):
        flux = f"vol_flux_{sweep}"
        off = _AXES[sweep]
        moff = tuple(-o for o in off)
        S_don = self.S_adv[sweep]
        rng = self._adv_range()

        def k_prevol(acc):
            return {"pre_vol": acc("volume") + (acc(flux, off) - acc(flux)),
                    "post_vol": acc("volume")}

        rt.par_loop(
            f"advec_cell3d_{sweep}_vol", self.block, rng,
            [self.d("volume"), self.d(flux), self.d("pre_vol"),
             self.d("post_vol")],
            k_prevol,
        )

        def k_flux(acc):
            f = acc(flux)
            donor_rho = jnp.where(f > 0, acc("density1", moff), acc("density1"))
            donor_e = jnp.where(f > 0, acc("energy1", moff), acc("energy1"))
            return {"pre_mass": donor_rho * jnp.abs(f),
                    "ener_flux": donor_rho * donor_e * jnp.abs(f) * jnp.sign(f)}

        rt.par_loop(
            f"advec_cell3d_{sweep}_flux", self.block, rng,
            [self.d(flux), self.d("density1"), self.d("energy1"),
             self.d("pre_mass"), self.d("ener_flux")],
            k_flux,
            # keep the original second-order advection footprint (see 2-D app)
            explicit_stencil={"density1": S_don, "energy1": S_don},
        )

        def k_update(acc):
            f = acc(flux)
            fp = acc(flux, off)
            m_in = jnp.where(f > 0, acc("pre_mass"), -acc("pre_mass"))
            m_out = jnp.where(fp > 0, acc("pre_mass", off), -acc("pre_mass", off))
            pre_mass = acc("density1") * acc("pre_vol")
            post_mass = pre_mass + m_in - m_out
            rho = post_mass / jnp.maximum(acc("post_vol"), 1e-10)
            post_e = (pre_mass * acc("energy1") + acc("ener_flux")
                      - acc("ener_flux", off)) / jnp.maximum(post_mass, 1e-10)
            return {"density1": rho, "energy1": post_e, "post_mass": post_mass}

        rt.par_loop(
            f"advec_cell3d_{sweep}_update", self.block, rng,
            [self.d(flux), self.d("pre_mass"), self.d("ener_flux"),
             self.d("pre_vol"), self.d("post_vol"), self.d("density1"),
             self.d("energy1"), self.d("post_mass")],
            k_update,
        )

    def _advec_mom(self, rt, sweep, vel):
        """Three loops as in the original: mass flux -> momentum flux (work
        array) -> velocity update (zero-stencil RW)."""
        flux = f"mass_flux_{sweep}"
        vflux = f"vol_flux_{sweep}"
        off = _AXES[sweep]
        moff = tuple(-o for o in off)
        rng = self._adv_range()
        v1 = f"{vel}1"
        mom = "advec_vol"

        def k_mf(acc):
            return {flux: acc(vflux) * 0.5 * (acc("density1") + acc("density1", off))}

        rt.par_loop(
            f"advec_mom3d_{sweep}_{vel}_mf", self.block, rng,
            [self.d(vflux), self.d("density1"), self.d(flux)],
            k_mf,
        )

        def k_mom(acc):
            f = acc(flux)
            donor = jnp.where(f > 0, acc(v1, moff), acc(v1))
            return {mom: f * donor}

        rt.par_loop(
            f"advec_mom3d_{sweep}_{vel}_flx", self.block, rng,
            [self.d(flux), self.d(v1), self.d(mom)],
            k_mom,
        )

        def k_up(acc):
            node_mass = jnp.maximum(acc("post_mass"), 1e-10)
            return {v1: acc(v1) + (acc(mom) - acc(mom, off)) / node_mass}

        rt.par_loop(
            f"advec_mom3d_{sweep}_{vel}_up", self.block, rng,
            [self.d(mom), self.d("post_mass"), self.d(v1)],
            k_up,
        )

    def _reset_field(self, rt):
        pairs = [("density0", "density1"), ("energy0", "energy1"),
                 ("xvel0", "xvel1"), ("yvel0", "yvel1"), ("zvel0", "zvel1")]

        def k(acc):
            return {dst: acc(src) for dst, src in pairs}

        rt.par_loop(
            "reset_field3d", self.block, self._interior(),
            [self.d(src) for _, src in pairs]
            + [self.d(dst) for dst, _ in pairs],
            k,
        )

    # -- drivers --------------------------------------------------------------
    def record_timestep(self, rt: Session) -> None:
        self._ideal_gas(rt, "density0", "energy0", "")
        self._viscosity(rt)
        self._pdv(rt, True, "predict")
        self._ideal_gas(rt, "density1", "energy1", "_pdv")
        self._revert(rt)
        self._accelerate(rt)
        self._pdv(rt, False, "correct")
        self._flux_calc(rt)
        order = ["xyz", "yzx", "zxy"][self.step_count % 3]
        for sweep in order:
            self._advec_cell(rt, sweep)
            for vel in ("xvel", "yvel", "zvel"):
                self._advec_mom(rt, sweep, vel)
        self._reset_field(rt)
        self.step_count += 1

    def record_summary(self, rt: Session) -> List[str]:
        def k(acc):
            rho = acc("density0")
            ke = 0.5 * rho * (acc("xvel0") ** 2 + acc("yvel0") ** 2 + acc("zvel0") ** 2)
            return {
                "sum_mass": jnp.sum(rho * acc("volume")),
                "sum_ie": jnp.sum(rho * acc("energy0") * acc("volume")),
                "sum_ke": jnp.sum(ke * acc("volume")),
                "max_p": jnp.max(acc("pressure")),
                "min_rho": jnp.min(rho),
            }

        specs = [ReductionSpec("sum_mass", "sum"), ReductionSpec("sum_ie", "sum"),
                 ReductionSpec("sum_ke", "sum"), ReductionSpec("max_p", "max"),
                 ReductionSpec("min_rho", "min")]
        rt.par_loop(
            "field_summary3d", self.block, self._interior(),
            [self.d(n) for n in ("density0", "energy0", "xvel0", "yvel0",
                                  "zvel0", "volume", "pressure")],
            k, reductions=specs,
        )
        return [s.name for s in specs]

    def run(self, rt: Session, steps: int, dt_every: bool = True) -> Dict[str, float]:
        self.record_init(rt)
        rt.flush()
        rt.cyclic = True
        out: Dict[str, float] = {}
        for s in range(steps):
            self._ideal_gas(rt, "density0", "energy0", "_dt")
            self._viscosity(rt)
            self._calc_dt(rt)
            if dt_every:
                self.dt = float(min(1e-4, rt.reduction("dt")))
            self.record_timestep(rt)
            if self.summary_every and (s + 1) % self.summary_every == 0:
                for name in self.record_summary(rt):
                    out[name] = float(rt.reduction(name))
        rt.flush()
        return out

    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.dats.values())
