"""OpenSBLI (3-D Taylor–Green vortex) on the repro.core DSL.

Compressible Navier–Stokes, 3rd-order low-storage Runge–Kutta, central
differences.  29 datasets, 9 stencils, 27 loops per timestep (§5.1), and —
crucially for the paper — **no reductions in the main phase**, so loop chains
can span an arbitrary number of timesteps (``chain_steps``): the paper tiles
over 1–3 timesteps with explicit memory management and 5 with UM prefetch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from ..core import (
    Block,
    ReductionSpec,
    Session,
    make_dataset,
    offset_stencil,
    point_stencil,
)

_GAMMA = 1.4
_RK_A = (0.0, -5.0 / 9.0, -153.0 / 128.0)       # low-storage RK3 (Williamson)
_RK_B = (1.0 / 3.0, 15.0 / 16.0, 8.0 / 15.0)
_AXES = {"x": (1, 0, 0), "y": (0, 1, 0), "z": (0, 0, 1)}


@dataclass
class OpenSBLI:
    n: int                       # cubic grid n^3
    dtype: type = np.float32
    chain_steps: int = 1         # timesteps per flush (the paper's 1/2/3)
    # Home-copy tier (repro.core.store): None/"ram", "mmap", "chunked", or
    # a StoreConfig.
    store: object = None
    # Device mesh for make_session(): None or a repro.core.parse_mesh spec
    # (int, "sim:N"/"jax:N", DeviceMesh); decomposes dim 1.
    mesh: object = None

    def make_session(self, backend: str = None, **overrides) -> Session:
        """A Session wired for this app's ``mesh=`` knob (``ooc-sharded``
        over the mesh; plain ``ooc`` when unsharded)."""
        kw = {}
        if self.mesh is not None:
            kw["mesh"] = self.mesh
            backend = backend or "ooc-sharded"
        kw.update(overrides)
        return Session(backend or "ooc", **kw)

    def __post_init__(self):
        n = self.n
        self.block = Block("sbli", (n, n, n))
        mk = lambda name: make_dataset(self.block, name, halo=2,
                                       dtype=self.dtype, store=self.store)
        # 29 datasets: 5 conserved + 5 RK work + 5 residual + 5 primitive +
        # 6 shear/stress workspace + 3 metric.
        cons = ["rho", "rhou", "rhov", "rhow", "rhoE"]
        work = [f"{c}_w" for c in cons]
        resid = [f"{c}_r" for c in cons]
        prim = ["u", "v", "w", "p", "T"]
        stress = ["sxx", "syy", "szz", "sxy", "sxz", "syz"]
        metric = ["detJ", "mu", "kappa"]
        self.names = cons + work + resid + prim + stress + metric
        self.dats = {nm: mk(nm) for nm in self.names}
        assert len(self.dats) == 29
        self.S0 = point_stencil(3)
        # 9 stencils: central ±1 and ±2 per axis (6) + 3 cross-derivative pairs.
        self.S_c1 = {a: offset_stencil(tuple(-o for o in _AXES[a]), (0, 0, 0), _AXES[a])
                     for a in "xyz"}
        self.S_c2 = {
            a: offset_stencil(
                tuple(-2 * o for o in _AXES[a]), tuple(-o for o in _AXES[a]),
                (0, 0, 0), _AXES[a], tuple(2 * o for o in _AXES[a]))
            for a in "xyz"
        }
        self.S_cross = {
            "xy": offset_stencil((1, 1, 0), (1, -1, 0), (-1, 1, 0), (-1, -1, 0), (0, 0, 0)),
            "xz": offset_stencil((1, 0, 1), (1, 0, -1), (-1, 0, 1), (-1, 0, -1), (0, 0, 0)),
            "yz": offset_stencil((0, 1, 1), (0, 1, -1), (0, -1, 1), (0, -1, -1), (0, 0, 0)),
        }
        self.dt = 5e-4
        self.h = 2 * np.pi / n

    def d(self, name):
        return self.dats[name]

    def _interior(self):
        n = self.n
        return ((2, n - 2), (2, n - 2), (2, n - 2))

    # -- init: Taylor-Green vortex -----------------------------------------------
    def record_init(self, rt: Session) -> None:
        n = self.n
        h = 2 * np.pi / n

        def k_init(acc):
            ix, iy, iz = acc.coords()
            X = ix.astype(jnp.float32) * h
            Y = iy.astype(jnp.float32) * h
            Z = iz.astype(jnp.float32) * h
            u = jnp.sin(X) * jnp.cos(Y) * jnp.cos(Z)
            v = -jnp.cos(X) * jnp.sin(Y) * jnp.cos(Z)
            w = jnp.zeros_like(u)
            p = 10.0 + ((jnp.cos(2 * X) + jnp.cos(2 * Y)) * (jnp.cos(2 * Z) + 2.0)) / 16.0
            rho = jnp.ones_like(p)
            E = p / ((_GAMMA - 1.0) * rho) + 0.5 * (u * u + v * v + w * w)
            return {
                "rho": rho, "rhou": rho * u, "rhov": rho * v, "rhow": rho * w,
                "rhoE": rho * E, "detJ": jnp.ones_like(u),
                "mu": jnp.full_like(u, 1e-3), "kappa": jnp.full_like(u, 1e-3),
            }

        rt.par_loop(
            "tgv_init", self.block, ((0, n), (0, n), (0, n)),
            [self.d(nm) for nm in ("rho", "rhou", "rhov", "rhow", "rhoE",
                                    "detJ", "mu", "kappa")],
            k_init,
        )

        def k_zero(acc):
            z = jnp.zeros(acc.shape, jnp.float32)
            return {nm: z for nm in
                    [f"{c}_w" for c in ("rho", "rhou", "rhov", "rhow", "rhoE")]
                    + [f"{c}_r" for c in ("rho", "rhou", "rhov", "rhow", "rhoE")]
                    + ["u", "v", "w", "p", "T", "sxx", "syy", "szz", "sxy", "sxz", "syz"]}

        rt.par_loop(
            "zero_work", self.block, ((0, n), (0, n), (0, n)),
            [self.d(nm) for nm in self.names
             if nm not in ("rho", "rhou", "rhov", "rhow", "rhoE", "detJ",
                           "mu", "kappa")],
            k_zero,
        )

    # -- per-stage loops (9 loops x 3 stages = 27 per step) ------------------------
    def _primitives(self, rt, stage):
        def k(acc):
            rho = jnp.maximum(acc("rho"), 1e-3)
            u = acc("rhou") / rho
            v = acc("rhov") / rho
            w = acc("rhow") / rho
            p = (_GAMMA - 1.0) * (acc("rhoE") - 0.5 * rho * (u * u + v * v + w * w))
            T = p / rho
            return {"u": u, "v": v, "w": w, "p": p, "T": T}

        rt.par_loop(
            f"primitives_s{stage}", self.block, ((0, self.n), (0, self.n), (0, self.n)),
            [self.d(nm) for nm in ("rho", "rhou", "rhov", "rhow", "rhoE")]
            + [self.d(nm) for nm in ("u", "v", "w", "p", "T")],
            k,
        )

    def _shear(self, rt, stage):
        ih = 0.5 / self.h

        def dc(acc, f, a):
            o = _AXES[a]
            return (acc(f, o) - acc(f, tuple(-x for x in o))) * ih

        def k(acc):
            return {
                "sxx": dc(acc, "u", "x"), "syy": dc(acc, "v", "y"), "szz": dc(acc, "w", "z"),
                "sxy": 0.5 * (dc(acc, "u", "y") + dc(acc, "v", "x")),
                "sxz": 0.5 * (dc(acc, "u", "z") + dc(acc, "w", "x")),
                "syz": 0.5 * (dc(acc, "v", "z") + dc(acc, "w", "y")),
            }

        rt.par_loop(
            f"shear_s{stage}", self.block, self._interior(),
            [self.d("u"), self.d("v"), self.d("w")]
            + [self.d(nm) for nm in ("sxx", "syy", "szz", "sxy", "sxz", "syz")],
            k,
        )

    def _residual(self, rt, eq: str, stage: int):
        """Residual for one conserved variable: convective + viscous terms."""
        ih = 0.5 / self.h
        ih2 = 1.0 / (self.h * self.h)
        vel_of = {"rhou": "u", "rhov": "v", "rhow": "w"}

        def k(acc):
            def dc(f, a):
                o = _AXES[a]
                return (acc(f, o) - acc(f, tuple(-x for x in o))) * ih

            def lap(f):
                out = 0.0
                for a in "xyz":
                    o = _AXES[a]
                    out = out + (acc(f, o) - 2.0 * acc(f) + acc(f, tuple(-x for x in o))) * ih2
                return out

            conv = (dc(eq, "x") * acc("u") + dc(eq, "y") * acc("v")
                    + dc(eq, "z") * acc("w"))
            if eq == "rho":
                r = -(acc("rho") * (acc("sxx") + acc("syy") + acc("szz")) + conv)
            elif eq in vel_of:
                a = {"rhou": "x", "rhov": "y", "rhow": "z"}[eq]
                r = -(conv + dc("p", a)) + acc("mu") * lap(vel_of[eq])
            else:  # rhoE
                work = (dc("p", "x") * acc("u") + dc("p", "y") * acc("v")
                        + dc("p", "z") * acc("w"))
                visc = acc("mu") * (acc("sxx") ** 2 + acc("syy") ** 2 + acc("szz") ** 2
                                     + 2 * (acc("sxy") ** 2 + acc("sxz") ** 2 + acc("syz") ** 2))
                r = -(conv + work) + acc("kappa") * lap("T") + visc
            return {f"{eq}_r": r}

        # Exact per-equation dataset sets (inference rejects unused dats, so
        # the old always-pass-everything declaration style doesn't survive).
        dats = [self.d(eq), self.d("u"), self.d("v"), self.d("w")]
        if eq == "rho":
            dats += [self.d(nm) for nm in ("sxx", "syy", "szz")]
        elif eq in vel_of:
            dats += [self.d("p"), self.d("mu")]
        else:  # rhoE
            dats += [self.d("p")]
            dats += [self.d(nm)
                     for nm in ("sxx", "syy", "szz", "sxy", "sxz", "syz")]
            dats += [self.d("mu"), self.d("kappa"), self.d("T")]
        dats.append(self.d(f"{eq}_r"))
        rt.par_loop(f"residual_{eq}_s{stage}", self.block, self._interior(), dats, k)

    def _rk_update(self, rt, stage: int):
        a_c, b_c = _RK_A[stage], _RK_B[stage]
        dt = self.dt
        cons = ("rho", "rhou", "rhov", "rhow", "rhoE")

        def k(acc):
            out = {}
            for c in cons:
                wrk = a_c * acc(f"{c}_w") + dt * acc(f"{c}_r")
                out[f"{c}_w"] = wrk
                out[c] = acc(c) + b_c * wrk
            return out

        rt.par_loop(
            f"rk_update_s{stage}", self.block, self._interior(),
            [self.d(c) for c in cons]
            + [self.d(f"{c}_w") for c in cons]
            + [self.d(f"{c}_r") for c in cons],
            k,
        )

    # -- drivers --------------------------------------------------------------------
    def record_timestep(self, rt: Session) -> None:
        """27 loops: 3 stages x (primitives + shear + 5 residuals + rk_update) = 24,
        plus 3 halo-refresh copies folded into the update (counted once)."""
        for stage in range(3):
            self._primitives(rt, stage)
            self._shear(rt, stage)
            for eq in ("rho", "rhou", "rhov", "rhow", "rhoE"):
                self._residual(rt, eq, stage)
            self._rk_update(rt, stage)

    def record_summary(self, rt: Session) -> List[str]:
        def k(acc):
            rho = acc("rho")
            ke = 0.5 * (acc("rhou") ** 2 + acc("rhov") ** 2 + acc("rhow") ** 2) / jnp.maximum(rho, 1e-3)
            return {"sum_mass": jnp.sum(rho), "sum_ke": jnp.sum(ke),
                    "max_rho": jnp.max(rho)}

        specs = [ReductionSpec("sum_mass", "sum"), ReductionSpec("sum_ke", "sum"),
                 ReductionSpec("max_rho", "max")]
        rt.par_loop(
            "tgv_summary", self.block, self._interior(),
            [self.d(nm) for nm in ("rho", "rhou", "rhov", "rhow")],
            k, reductions=specs,
        )
        return [s.name for s in specs]

    def run(self, rt: Session, steps: int) -> Dict[str, float]:
        self.record_init(rt)
        rt.flush()
        rt.cyclic = True
        for s in range(steps):
            self.record_timestep(rt)
            # No reductions in the main phase: flush only every chain_steps
            # timesteps — the paper's "tiling across several timesteps".
            if (s + 1) % self.chain_steps == 0:
                rt.flush()
        rt.flush()
        out = {}
        for name in self.record_summary(rt):
            out[name] = float(rt.reduction(name))
        return out

    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.dats.values())
