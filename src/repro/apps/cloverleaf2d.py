"""CloverLeaf 2D on the repro.core DSL.

Explicit compressible-Euler mini-app: staggered grid (density/energy/pressure
at cell centres, velocities at nodes), one timestep =

  ideal_gas -> viscosity -> calc_dt (MIN reduction, chain breaker) ->
  PdV(predictor) -> ideal_gas -> revert -> accelerate -> PdV(corrector) ->
  flux_calc -> advec_cell(x) -> advec_mom(x) -> advec_cell(y) ->
  advec_mom(y) -> reset_field

25 datasets, ~28 loops per step, sweep direction alternates per step; every
``summary_every`` steps a field-summary chain (5 reductions over 6 datasets)
reproduces the paper's "one long loop chain reading a large number of
datasets with a very poor copy/compute overlap".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import (
    Block,
    ReductionSpec,
    Session,
    make_dataset,
    offset_stencil,
    point_stencil,
    star_stencil,
)

_GAMMA = 1.4


@dataclass
class CloverLeaf2D:
    nx: int
    ny: int
    dtype: type = np.float32
    summary_every: int = 10
    # Home-copy tier for every dataset: None/"ram" (default), "mmap",
    # "chunked", or a repro.core.StoreConfig (see repro.core.store).
    store: object = None
    # Device mesh for make_session(): None (unsharded) or anything
    # repro.core.parse_mesh accepts — an int, "sim:N"/"jax:N", a DeviceMesh.
    # Decomposes dim 1, composing with out-of-core tiling along dim 0.
    mesh: object = None

    def __post_init__(self):
        nx, ny = self.nx, self.ny
        self.block = Block("clover2d", (nx, ny))
        mk = lambda name, halo=2: make_dataset(self.block, name, halo=halo,
                                               dtype=self.dtype,
                                               store=self.store)
        # 25 datasets, as in the original (§5.1).
        names_cell = [
            "density0", "density1", "energy0", "energy1", "pressure",
            "viscosity", "soundspeed", "volume",
            "vol_flux_x", "vol_flux_y", "mass_flux_x", "mass_flux_y",
            "pre_vol", "post_vol", "pre_mass", "post_mass", "advec_vol",
            "post_ener", "ener_flux", "xarea", "yarea",
        ]
        names_node = ["xvel0", "xvel1", "yvel0", "yvel1"]
        self.dats: Dict[str, "Dataset"] = {}
        for n in names_cell + names_node:
            self.dats[n] = mk(n)
        assert len(self.dats) == 25
        # Stencils (a representative subset of the original's 30).
        self.S0 = point_stencil(2)
        self.S_star = star_stencil(2, 1)
        self.S_xm = offset_stencil((0, 0), (-1, 0))
        self.S_xp = offset_stencil((0, 0), (1, 0))
        self.S_ym = offset_stencil((0, 0), (0, -1))
        self.S_yp = offset_stencil((0, 0), (0, 1))
        self.S_node = offset_stencil((0, 0), (-1, 0), (0, -1), (-1, -1))
        self.S_cellx = offset_stencil((0, 0), (1, 0), (0, 1), (1, 1))
        self.S_adv_x = offset_stencil((-2, 0), (-1, 0), (0, 0), (1, 0), (2, 0))
        self.S_adv_y = offset_stencil((0, -2), (0, -1), (0, 0), (0, 1), (0, 2))
        self.step_count = 0
        self.dt = 1e-4

    # -- helpers --------------------------------------------------------------
    def _interior(self):
        return ((0, self.nx), (0, self.ny))

    def d(self, name):
        return self.dats[name]

    def make_session(self, backend: str = None, **overrides) -> Session:
        """A Session wired for this app's ``mesh=`` knob: the ``ooc-sharded``
        backend over the configured device mesh (plain ``ooc`` when
        unsharded).  ``overrides`` are ExecutionConfig fields."""
        kw: Dict[str, object] = {}
        if self.mesh is not None:
            kw["mesh"] = self.mesh
            backend = backend or "ooc-sharded"
        kw.update(overrides)
        return Session(backend or "ooc", **kw)

    # -- initialisation chain ---------------------------------------------------
    def record_init(self, rt: Session, seed: int = 0) -> None:
        nx, ny = self.nx, self.ny
        blk = self.block
        hx, hy = 2 * np.pi / nx, 2 * np.pi / ny

        def k_init(acc):
            ix, iy = acc.coords()
            x = ix.astype(jnp.float32) * hx
            y = iy.astype(jnp.float32) * hy
            one = jnp.ones(acc.shape, jnp.float32)
            return {
                "density0": 1.0 + 0.2 * jnp.sin(x) * jnp.cos(y),
                "energy0": 2.5 + 0.5 * jnp.cos(x),
                "volume": one, "xarea": one, "yarea": one,
                "xvel0": 0.1 * jnp.sin(x),
                "yvel0": -0.1 * jnp.cos(y),
            }

        rt.par_loop(
            "initialise", blk, self._interior(),
            [self.d(n) for n in ("density0", "energy0", "volume", "xarea",
                                  "yarea", "xvel0", "yvel0")],
            k_init,
        )

        def k_zero(acc):
            z = jnp.zeros(acc.shape, jnp.float32)
            return {n: z for n in ("density1", "energy1", "pressure", "viscosity",
                                    "soundspeed", "xvel1", "yvel1")}

        rt.par_loop(
            "zero_fields", blk, self._interior(),
            [self.d(n) for n in ("density1", "energy1", "pressure",
                                  "viscosity", "soundspeed", "xvel1", "yvel1")],
            k_zero,
        )

    # -- physics loops ------------------------------------------------------------
    def _ideal_gas(self, rt, rho_name, e_name, tag):
        def k(acc):
            rho = acc(rho_name)
            e = acc(e_name)
            p = (_GAMMA - 1.0) * rho * e
            ss = jnp.sqrt(jnp.maximum(_GAMMA * p / jnp.maximum(rho, 1e-10), 1e-10))
            return {"pressure": p, "soundspeed": ss}

        rt.par_loop(
            f"ideal_gas{tag}", self.block, self._interior(),
            [self.d(rho_name), self.d(e_name), self.d("pressure"),
             self.d("soundspeed")],
            k,
        )

    def _viscosity(self, rt):
        def k(acc):
            du = acc("xvel0", (1, 0)) - acc("xvel0")
            dv = acc("yvel0", (0, 1)) - acc("yvel0")
            div = du + dv
            visc = jnp.where(div < 0.0, 2.0 * acc("density0") * div * div, 0.0)
            return {"viscosity": visc}

        rt.par_loop(
            "viscosity", self.block, self._interior(),
            [self.d("xvel0"), self.d("yvel0"), self.d("density0"),
             self.d("viscosity")],
            k,
        )

    def _calc_dt(self, rt):
        def k(acc):
            ss = acc("soundspeed")
            u = acc("xvel0")
            v = acc("yvel0")
            speed = ss + jnp.abs(u) + jnp.abs(v)
            dt_local = 0.5 / jnp.maximum(speed, 1e-6) / max(self.nx, self.ny)
            return {"dt": jnp.min(dt_local)}

        rt.par_loop(
            "calc_dt", self.block, self._interior(),
            [self.d("soundspeed"), self.d("xvel0"), self.d("yvel0")],
            k, reductions=[ReductionSpec("dt", "min")],
        )

    def _pdv(self, rt, predict: bool, tag: str):
        dt = self.dt * (0.5 if predict else 1.0)
        dst_rho = "density1"
        dst_e = "energy1"

        def k(acc):
            div = (acc("xvel0", (1, 0)) - acc("xvel0")) + (acc("yvel0", (0, 1)) - acc("yvel0"))
            vol_change = 1.0 + dt * div
            rho = acc("density0") / jnp.maximum(vol_change, 0.1)
            e = acc("energy0") - dt * acc("pressure") * div / jnp.maximum(acc("density0"), 1e-10)
            return {dst_rho: rho, dst_e: e}

        rt.par_loop(
            f"pdv_{tag}", self.block, self._interior(),
            [self.d("xvel0"), self.d("yvel0"), self.d("density0"),
             self.d("energy0"), self.d("pressure"), self.d(dst_rho),
             self.d(dst_e)],
            k,
        )

    def _revert(self, rt):
        def k(acc):
            return {"density1": acc("density0"), "energy1": acc("energy0")}

        rt.par_loop(
            "revert", self.block, self._interior(),
            [self.d("density0"), self.d("energy0"), self.d("density1"),
             self.d("energy1")],
            k,
        )

    def _accelerate(self, rt):
        dt = self.dt
        rng = ((1, self.nx), (1, self.ny))

        def k(acc):
            # node-centred density from 4 surrounding cells
            nodal_mass = 0.25 * (acc("density0") + acc("density0", (-1, 0))
                                 + acc("density0", (0, -1)) + acc("density0", (-1, -1)))
            px = (acc("pressure") - acc("pressure", (-1, 0))
                  + acc("viscosity") - acc("viscosity", (-1, 0)))
            py = (acc("pressure") - acc("pressure", (0, -1))
                  + acc("viscosity") - acc("viscosity", (0, -1)))
            xv = acc("xvel0") - dt * px / jnp.maximum(nodal_mass, 1e-10)
            yv = acc("yvel0") - dt * py / jnp.maximum(nodal_mass, 1e-10)
            return {"xvel1": xv, "yvel1": yv}

        rt.par_loop(
            "accelerate", self.block, rng,
            [self.d("density0"), self.d("pressure"), self.d("viscosity"),
             self.d("xvel0"), self.d("yvel0"), self.d("xvel1"),
             self.d("yvel1")],
            k,
        )

    def _flux_calc(self, rt):
        dt = self.dt

        def k(acc):
            fx = 0.5 * dt * (acc("xvel1") + acc("xvel1", (0, 1))) * acc("xarea")
            fy = 0.5 * dt * (acc("yvel1") + acc("yvel1", (1, 0))) * acc("yarea")
            return {"vol_flux_x": fx, "vol_flux_y": fy}

        rt.par_loop(
            "flux_calc", self.block, self._interior(),
            [self.d("xvel1"), self.d("yvel1"), self.d("xarea"),
             self.d("yarea"), self.d("vol_flux_x"), self.d("vol_flux_y")],
            k,
        )

    def _advec_cell(self, rt, sweep: str):
        """Directionally-split donor-cell advection of density & energy."""
        flux = f"vol_flux_{sweep}"
        S_don = self.S_adv_x if sweep == "x" else self.S_adv_y
        off = (1, 0) if sweep == "x" else (0, 1)
        moff = (-1, 0) if sweep == "x" else (0, -1)
        rng = ((2, self.nx - 2), (2, self.ny - 2))

        def k_prevol(acc):
            pre = acc("volume") + (acc(flux, off) - acc(flux))
            post = acc("volume")
            return {"pre_vol": pre, "post_vol": post}

        rt.par_loop(
            f"advec_cell_{sweep}_vol", self.block, rng,
            [self.d("volume"), self.d(flux), self.d("pre_vol"),
             self.d("post_vol")],
            k_prevol,
        )

        def k_flux(acc):
            f = acc(flux)
            donor_rho = jnp.where(f > 0, acc("density1", moff), acc("density1"))
            donor_e = jnp.where(f > 0, acc("energy1", moff), acc("energy1"))
            return {"pre_mass": donor_rho * jnp.abs(f),
                    "ener_flux": donor_rho * donor_e * jnp.abs(f) * jnp.sign(f)}

        # explicit_stencil escape hatch: the simplified donor formula only
        # reads offsets {-1, 0}, but the original CloverLeaf second-order
        # scheme reads the full 5-point advection stencil — keeping the wider
        # declared footprint preserves the paper's skew/footprint behaviour.
        rt.par_loop(
            f"advec_cell_{sweep}_flux", self.block, rng,
            [self.d(flux), self.d("density1"), self.d("energy1"),
             self.d("pre_mass"), self.d("ener_flux")],
            k_flux,
            explicit_stencil={"density1": S_don, "energy1": S_don},
        )

        def k_update(acc):
            f = acc(flux)
            fp = acc(flux, off)
            mflux_in = jnp.where(f > 0, acc("pre_mass"), -acc("pre_mass"))
            mflux_out = jnp.where(fp > 0, acc("pre_mass", off), -acc("pre_mass", off))
            pre_mass = acc("density1") * acc("pre_vol")
            post_mass = pre_mass + mflux_in - mflux_out
            rho = post_mass / jnp.maximum(acc("post_vol"), 1e-10)
            e_in = acc("ener_flux")
            e_out = acc("ener_flux", off)
            post_e = (pre_mass * acc("energy1") + e_in - e_out) / jnp.maximum(post_mass, 1e-10)
            return {"density1": rho, "energy1": post_e, "post_mass": post_mass}

        rt.par_loop(
            f"advec_cell_{sweep}_update", self.block, rng,
            [self.d(flux), self.d("pre_mass"), self.d("ener_flux"),
             self.d("pre_vol"), self.d("post_vol"), self.d("density1"),
             self.d("energy1"), self.d("post_mass")],
            k_update,
        )

    def _advec_mom(self, rt, sweep: str, vel: str):
        """Momentum advection, three loops as in the original: mass flux ->
        momentum flux (work array) -> velocity update (zero-stencil RW)."""
        flux = f"mass_flux_{sweep}"
        vflux = f"vol_flux_{sweep}"
        off = (1, 0) if sweep == "x" else (0, 1)
        moff = (-off[0], -off[1])
        rng = ((2, self.nx - 2), (2, self.ny - 2))
        v1 = f"{vel}1"
        mom = "advec_vol"  # momentum-flux work array (original: mom_flux)

        def k_mass_flux(acc):
            return {flux: acc(vflux) * 0.5 * (acc("density1") + acc("density1", off))}

        rt.par_loop(
            f"advec_mom_{sweep}_{vel}_mf", self.block, rng,
            [self.d(vflux), self.d("density1"), self.d(flux)],
            k_mass_flux,
        )

        def k_mom_flux(acc):
            f = acc(flux)
            donor = jnp.where(f > 0, acc(v1, moff), acc(v1))
            return {mom: f * donor}

        rt.par_loop(
            f"advec_mom_{sweep}_{vel}_flx", self.block, rng,
            [self.d(flux), self.d(v1), self.d(mom)],
            k_mom_flux,
        )

        def k_update(acc):
            node_mass = jnp.maximum(acc("post_mass"), 1e-10)
            return {v1: acc(v1) + (acc(mom) - acc(mom, off)) / node_mass}

        rt.par_loop(
            f"advec_mom_{sweep}_{vel}_up", self.block, rng,
            [self.d(mom), self.d("post_mass"), self.d(v1)],
            k_update,
        )

    def _update_halo(self, rt, fields, tag: str, depth: int = 2):
        """Reflective halo update, one loop per halo row/col per side (the
        original CloverLeaf's update_halo): writes halo cells from mirrored
        interior cells.  Besides fidelity (the original has ~70 such loop
        instances per step), this WARMS the halo rows so the §4.1 write-first
        elision applies to more data (cold-read uploads shrink)."""
        nx, ny = self.nx, self.ny
        sites = []
        # dim-0 (rows) first: row -k-1 mirrors row k; row nx+k mirrors nx-1-k
        for k in range(depth):
            sites.append((((-k - 1, -k), (0, ny)), (2 * k + 1, 0)))
            sites.append((((nx + k, nx + k + 1), (0, ny)), (-2 * k - 1, 0)))
        # dim-1 (cols) second, over the EXTENDED row range so the corners get
        # written too (as the original does — and the out-of-core download of
        # a halo row must not contain never-written bytes).
        for k in range(depth):
            sites.append((((-depth, nx + depth), (-k - 1, -k)), (0, 2 * k + 1)))
            sites.append((((-depth, nx + depth), (ny + k, ny + k + 1)),
                          (0, -2 * k - 1)))
        for i, (rng, off) in enumerate(sites):

            def k_halo(acc, fields=fields, off=off):
                return {f: acc(f, off) for f in fields}

            # Reads mirror cells, writes halo cells: inference splits each
            # field into READ(offset stencil) + WRITE(zero) args itself.
            rt.par_loop(
                f"update_halo_{tag}_{i}", self.block, rng,
                [self.d(f) for f in fields],
                k_halo,
            )

    def _reset_field(self, rt):
        def k(acc):
            return {"density0": acc("density1"), "energy0": acc("energy1"),
                    "xvel0": acc("xvel1"), "yvel0": acc("yvel1")}

        rt.par_loop(
            "reset_field", self.block, self._interior(),
            [self.d("density1"), self.d("energy1"), self.d("xvel1"),
             self.d("yvel1"), self.d("density0"), self.d("energy0"),
             self.d("xvel0"), self.d("yvel0")],
            k,
        )

    # -- drivers ------------------------------------------------------------------
    def record_timestep(self, rt: Session) -> None:
        """Record one timestep's loop chain (without the dt chain breaker):
        27 physics loops + 3 update_halo phases x 8 = 51 loops."""
        self._ideal_gas(rt, "density0", "energy0", "")
        self._viscosity(rt)
        self._update_halo(rt, ["pressure", "viscosity", "soundspeed"], "eos")
        self._pdv(rt, True, "predict")
        self._ideal_gas(rt, "density1", "energy1", "_pdv")
        self._revert(rt)
        self._accelerate(rt)
        self._pdv(rt, False, "correct")
        self._flux_calc(rt)
        self._update_halo(rt, ["vol_flux_x", "vol_flux_y", "xvel1", "yvel1"], "flux")
        first = "x" if self.step_count % 2 == 0 else "y"
        second = "y" if first == "x" else "x"
        for sweep in (first, second):
            self._advec_cell(rt, sweep)
            self._advec_mom(rt, sweep, "xvel")
            self._advec_mom(rt, sweep, "yvel")
            if sweep == first:
                self._update_halo(rt, ["density1", "energy1"], "advec")
        self._reset_field(rt)
        self.step_count += 1

    def record_summary(self, rt: Session) -> List[str]:
        """Field summary: the paper's every-10-steps long chain of reductions."""
        names = []
        def k(acc):
            rho = acc("density0")
            e = acc("energy0")
            u = acc("xvel0")
            v = acc("yvel0")
            vol = acc("volume")
            ke = 0.5 * rho * (u * u + v * v)
            return {
                "sum_mass": jnp.sum(rho * vol),
                "sum_ie": jnp.sum(rho * e * vol),
                "sum_ke": jnp.sum(ke * vol),
                "max_p": jnp.max(acc("pressure")),
                "min_rho": jnp.min(rho),
            }

        specs = [ReductionSpec("sum_mass", "sum"), ReductionSpec("sum_ie", "sum"),
                 ReductionSpec("sum_ke", "sum"), ReductionSpec("max_p", "max"),
                 ReductionSpec("min_rho", "min")]
        rt.par_loop(
            "field_summary", self.block, self._interior(),
            [self.d(n) for n in ("density0", "energy0", "xvel0", "yvel0",
                                  "volume", "pressure")],
            k, reductions=specs,
        )
        return [s.name for s in specs]

    def run(self, rt: Session, steps: int, dt_every: bool = True) -> Dict[str, float]:
        """Full driver: init, then per-step chains with the paper's breakers."""
        self.record_init(rt)
        rt.flush()
        rt.cyclic = True  # paper §4.1: set after the initialisation phase
        out: Dict[str, float] = {}
        for s in range(steps):
            self._ideal_gas(rt, "density0", "energy0", "_dt")
            self._viscosity(rt)
            self._calc_dt(rt)
            if dt_every:
                self.dt = float(min(1e-4, rt.reduction("dt")))  # chain breaker
            self.record_timestep(rt)
            if self.summary_every and (s + 1) % self.summary_every == 0:
                for name in self.record_summary(rt):
                    out[name] = float(rt.reduction(name))
        rt.flush()
        return out

    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.dats.values())
