"""Structured-mesh blocks — the coordinate frames datasets live on.

Mirrors ``ops_block`` from the OPS DSL: a block is an n-dimensional
Cartesian index space.  Datasets (:mod:`repro.core.dataset`) are defined on a
block; parallel loops iterate over sub-boxes of a block.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class Block:
    """An n-dimensional structured grid index space.

    Attributes:
      name: unique identifier.
      size: grid points per dimension (interior, excluding halos).
    """

    name: str
    size: Tuple[int, ...]

    def __post_init__(self) -> None:
        if not self.size or any(s <= 0 for s in self.size):
            raise ValueError(f"block {self.name!r}: bad size {self.size}")

    @property
    def ndim(self) -> int:
        return len(self.size)

    def full_range(self) -> Tuple[Tuple[int, int], ...]:
        """Iteration range covering the whole interior: ((0, n0), (0, n1), ...)."""
        return tuple((0, s) for s in self.size)

    def points(self) -> int:
        n = 1
        for s in self.size:
            n *= s
        return n
