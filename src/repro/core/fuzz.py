"""Plan fuzzer: mutate valid plans, assert the verifier flags every one.

The verifier (:mod:`repro.core.verify`) is only a safety net if it has no
false negatives over the corruption classes it claims to catch.  This
module enumerates *targeted* mutations of a valid plan — drop an op,
shrink a staging interval, reorder a dependency, skew a slot assignment,
misdeclare the §4.1 contract — each gated by an applicability predicate
strong enough to *guarantee* the mutant is unsound.  Every
:class:`Mutation` records the diagnostic categories the verifier must
emit (`expect`) and at what severity, so a test can assert zero false
negatives mechanically:

    for m in enumerate_mutations(plan):
        result = verify_plan(m.plan)
        assert any(d.category in m.expect for d in result.diagnostics)

With `hypothesis` installed, tests additionally sample random mutation
*pairs* and assert the verifier still fires (mutations only add
corruption, never cancel); without it, a fixed-seed subset runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .plan import (
    CarryEdge,
    Compute,
    Download,
    Elide,
    FetchHome,
    HaloExchange,
    HaloUnpack,
    Plan,
    PlanOp,
    SpillHome,
    Upload,
)
from .verify import ERROR, WARN, Ivs, _add, _inter, _sub


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One corrupted variant of a valid plan.

    ``expect`` lists diagnostic categories, *any one* of which counts as
    the verifier catching this mutant; ``severity`` is the minimum
    severity the finding must carry."""

    name: str
    plan: Plan
    expect: Tuple[str, ...]
    severity: str = ERROR

    def caught_by(self, diagnostics: Tuple) -> bool:
        sev_ok = (ERROR,) if self.severity == ERROR else (ERROR, WARN)
        return any(d.category in self.expect and d.severity in sev_ok
                   for d in diagnostics)


def _with_ops(plan: Plan, ops: List[PlanOp]) -> Plan:
    return dataclasses.replace(plan, ops=tuple(ops))


def _drop(plan: Plan, idx: int) -> Plan:
    return _with_ops(plan, [op for i, op in enumerate(plan.ops) if i != idx])


def _tile_writes(plan: Plan, tile: int, name: str) -> Ivs:
    """Rows of ``name`` written by ``tile``'s compute (dirty in its slot)."""
    out: Ivs = ()
    for op in plan.ops:
        if isinstance(op, Compute) and op.tile == tile:
            for wname, rows in op.writes:
                if wname == name:
                    for lo, hi in rows:
                        out = _add(out, lo, hi)
    return out


def _tile_retired_elsewhere(plan: Plan, tile: int, name: str) -> Ivs:
    """Rows of ``name`` that leave ``tile``'s slot by carry or elision —
    dropping the tile's download cannot orphan these."""
    out: Ivs = ()
    for op in plan.ops:
        if isinstance(op, CarryEdge) and op.tile == tile:
            for iname, lo, hi in op.items:
                if iname == name:
                    out = _add(out, lo, hi)
        elif isinstance(op, Elide) and op.tile == tile:
            for iname, lo, hi in op.items:
                if iname == name:
                    out = _add(out, lo, hi)
    return out


def _carried_into(plan: Plan, tile: int, name: str) -> Ivs:
    """Rows of ``name`` carried INTO ``tile``'s slot (from tile-1)."""
    out: Ivs = ()
    for op in plan.ops:
        if isinstance(op, CarryEdge) and op.tile == tile - 1:
            for iname, lo, hi in op.items:
                if iname == name:
                    out = _add(out, lo, hi)
    return out


def enumerate_mutations(plan: Plan) -> List[Mutation]:
    """Every targeted corruption of ``plan`` whose detection is guaranteed.

    Mutation classes (ISSUE: "drop an op, shrink an interval, reorder a
    dep" — plus the slot/contract skews the PR 5 hazards suggest):

    * drop a tile's Upload / Compute            -> ``missing-op``
    * drop a Download owing dirty rows          -> ``dirty-loss``
    * drop an Elide (its rows stay dirty)       -> ``dirty-loss``
    * drop a CarryEdge (edge rows orphaned)     -> ``dirty-loss`` or
      ``uninit-download`` in the next tile
    * shrink a Download interval by one row     -> ``dirty-loss``
    * shrink an Upload interval by one row      -> ``uninit-download``
    * move a Download before its Compute        -> ``missing-dep``
    * swap HaloExchange and HaloUnpack          -> ``halo-order``
    * skew an Upload's slot by one              -> ``slot-conflict``
    * clear ``cyclic`` while Elides remain      -> ``illegal-elide``
    * add an elided dataset to ``keep_live``    -> ``illegal-elide``
      (the PR 5 stale cross-segment elision)
    * shrink HaloExchange depth below the skirt -> ``halo-depth``
    * drop HaloUnpack / FetchHome / SpillHome   -> warn-severity
      ``unreachable-handle`` / ``disk-unfetched`` / ``disk-unspilled``
    """
    muts: List[Mutation] = []
    ops = plan.ops
    ns = max(1, plan.num_slots)

    for idx, op in enumerate(ops):
        if isinstance(op, Upload):
            t = op.tile
            muts.append(Mutation(
                name=f"drop-upload[{idx}]", plan=_drop(plan, idx),
                expect=("missing-op",)))
            if ns > 1:
                skew = dataclasses.replace(op, slot=(op.slot + 1) % ns)
                muts.append(Mutation(
                    name=f"skew-upload-slot[{idx}]",
                    plan=_with_ops(plan, [skew if i == idx else o
                                          for i, o in enumerate(ops)]),
                    expect=("slot-conflict",)))
            # Shrink: a staged row the download ships but nothing writes.
            for j, (name, lo, hi) in enumerate(op.items):
                if hi - lo < 2:
                    continue
                row = (hi - 1, hi)
                dl = next((d for d in ops if isinstance(d, Download)
                           and d.tile == t), None)
                if dl is None or not any(
                        n == name and _inter(((dlo, dhi),), *row)
                        for n, dlo, dhi in dl.items):
                    continue
                if _inter(_tile_writes(plan, t, name), *row):
                    continue
                if _inter(_carried_into(plan, t, name), *row):
                    continue
                items = list(op.items)
                items[j] = (name, lo, hi - 1)
                new = dataclasses.replace(op, items=tuple(items))
                muts.append(Mutation(
                    name=f"shrink-upload[{idx}].{name}",
                    plan=_with_ops(plan, [new if i == idx else o
                                          for i, o in enumerate(ops)]),
                    expect=("uninit-download",)))
                break
        elif isinstance(op, Compute):
            muts.append(Mutation(
                name=f"drop-compute[{idx}]", plan=_drop(plan, idx),
                expect=("missing-op",)))
        elif isinstance(op, Download):
            t = op.tile
            owed = False
            for name, lo, hi in op.items:
                # Rows this download retires that nothing else retires:
                # tile-written, minus carried/elided away.
                left = _inter(_tile_writes(plan, t, name), lo, hi)
                for elo, ehi in _tile_retired_elsewhere(plan, t, name):
                    left = _sub(left, elo, ehi)
                if not left:
                    continue
                owed = True
                # Shrink by one row, only when the dropped row is owed
                # (the last row of the item must sit in the owed region).
                _rlo, rhi = left[-1]
                for j, (iname, ilo, ihi) in enumerate(op.items):
                    if iname == name and ihi == rhi and ihi - ilo >= 2:
                        items = list(op.items)
                        items[j] = (iname, ilo, ihi - 1)
                        new = dataclasses.replace(op, items=tuple(items))
                        muts.append(Mutation(
                            name=f"shrink-download[{idx}].{name}",
                            plan=_with_ops(plan,
                                           [new if i == idx else o
                                            for i, o in enumerate(ops)]),
                            expect=("dirty-loss",)))
                        break
            if owed:
                muts.append(Mutation(
                    name=f"drop-download[{idx}]", plan=_drop(plan, idx),
                    expect=("dirty-loss",)))
            # Reorder: hoist the download above its tile's compute.
            cm_idx = next((i for i, o in enumerate(ops)
                           if isinstance(o, Compute) and o.tile == t), None)
            if cm_idx is not None and cm_idx < idx:
                moved = [o for i, o in enumerate(ops) if i != idx]
                moved.insert(cm_idx, op)
                muts.append(Mutation(
                    name=f"hoist-download[{idx}]",
                    plan=_with_ops(plan, moved),
                    expect=("missing-dep",)))
        elif isinstance(op, CarryEdge):
            # A carry of purely read-only skew edge rows (the consumer's
            # *reads* are not in the IR) is undetectable if the next tile's
            # download doesn't need them; only emit the mutant when its
            # detection is guaranteed.
            if op.items and _carry_drop_detectable(plan, op):
                muts.append(Mutation(
                    name=f"drop-carry[{idx}]", plan=_drop(plan, idx),
                    expect=("dirty-loss", "uninit-download", "uninit-read")))
        elif isinstance(op, Elide):
            if op.items:
                muts.append(Mutation(
                    name=f"drop-elide[{idx}]", plan=_drop(plan, idx),
                    expect=("dirty-loss",)))
        elif isinstance(op, HaloExchange):
            up_idx = next((i for i, o in enumerate(ops)
                           if isinstance(o, HaloUnpack)), None)
            if up_idx is not None and up_idx > idx:
                swapped = list(ops)
                swapped[idx], swapped[up_idx] = swapped[up_idx], swapped[idx]
                muts.append(Mutation(
                    name=f"swap-exchange-unpack[{idx}]",
                    plan=_with_ops(plan, swapped),
                    expect=("halo-order",)))
            reach = _skirt_reach(plan)
            if plan.device > 0 and plan.mesh_devices > 1 and reach > 0 \
                    and op.depth >= reach:
                shallow = dataclasses.replace(op, depth=reach - 1)
                muts.append(Mutation(
                    name=f"shrink-halo-depth[{idx}]",
                    plan=_with_ops(plan, [shallow if i == idx else o
                                          for i, o in enumerate(ops)]),
                    expect=("halo-depth",)))
        elif isinstance(op, HaloUnpack):
            muts.append(Mutation(
                name=f"drop-unpack[{idx}]", plan=_drop(plan, idx),
                expect=("unreachable-handle",), severity=WARN))
        elif isinstance(op, FetchHome):
            if plan.spill_home and op.items:
                muts.append(Mutation(
                    name=f"drop-fetch[{idx}]", plan=_drop(plan, idx),
                    expect=("disk-unfetched",), severity=WARN))
        elif isinstance(op, SpillHome):
            muts.append(Mutation(
                name=f"drop-spill[{idx}]", plan=_drop(plan, idx),
                expect=("disk-unspilled",), severity=WARN))

    # Contract skews (plan-level, not per-op).
    if any(isinstance(o, Elide) and o.items for o in ops):
        if plan.cyclic:
            muts.append(Mutation(
                name="clear-cyclic", plan=dataclasses.replace(
                    plan, cyclic=False),
                expect=("illegal-elide",)))
        elided = next(name for o in ops if isinstance(o, Elide)
                      for name, _lo, _hi in o.items)
        if elided not in plan.keep_live:
            muts.append(Mutation(
                name=f"keep-live-elided[{elided}]",
                plan=dataclasses.replace(
                    plan, keep_live=tuple(plan.keep_live) + (elided,)),
                expect=("illegal-elide",)))
    return muts


def _carry_drop_detectable(plan: Plan, carry: CarryEdge) -> bool:
    """True when removing ``carry`` must trip the verifier: either it moves
    dirty rows nothing else retires from the source slot, or the next
    tile's download ships rows only the carry makes valid."""
    t = carry.tile
    dl_t = next((o for o in plan.ops if isinstance(o, Download)
                 and o.tile == t), None)
    dl_n = next((o for o in plan.ops if isinstance(o, Download)
                 and o.tile == t + 1), None)
    up_n = next((o for o in plan.ops if isinstance(o, Upload)
                 and o.tile == t + 1), None)
    for name, lo, hi in carry.items:
        # (a) orphaned dirty rows in the source slot.
        dirty = _inter(_tile_writes(plan, t, name), lo, hi)
        if dl_t is not None:
            for n, dlo, dhi in dl_t.items:
                if n == name:
                    dirty = _sub(dirty, dlo, dhi)
        for o in plan.ops:
            if isinstance(o, Elide) and o.tile == t:
                for n, elo, ehi in o.items:
                    if n == name:
                        dirty = _sub(dirty, elo, ehi)
        if dirty:
            return True
        # (b) next tile's download needs rows only this carry provides.
        if dl_n is None:
            continue
        need: Ivs = ()
        for n, dlo, dhi in dl_n.items:
            if n == name:
                for ilo, ihi in _inter(((lo, hi),), dlo, dhi):
                    need = _add(need, ilo, ihi)
        if up_n is not None:
            for n, ulo, uhi in up_n.items:
                if n == name:
                    need = _sub(need, ulo, uhi)
        for wlo, whi in _tile_writes(plan, t + 1, name):
            need = _sub(need, wlo, whi)
        if need:
            return True
    return False


def _skirt_reach(plan: Plan) -> int:
    """Deepest row below the shard origin the stream touches."""
    lo_min = 0
    for op in plan.ops:
        if isinstance(op, Upload):
            for _name, lo, _hi in op.items:
                lo_min = min(lo_min, lo)
        elif isinstance(op, Compute):
            for _name, rows in op.writes:
                for lo, _hi in rows:
                    lo_min = min(lo_min, lo)
    return -lo_min


def check_mutations(plan: Plan,
                    mutations: Optional[List[Mutation]] = None
                    ) -> Dict[str, bool]:
    """Run the verifier over every mutation; map mutation name -> caught.

    A value of ``False`` anywhere is a verifier false negative."""
    from .verify import verify_plan

    result: Dict[str, bool] = {}
    for m in (enumerate_mutations(plan) if mutations is None else mutations):
        r = verify_plan(m.plan)
        result[m.name] = m.caught_by(r.diagnostics)
    return result
