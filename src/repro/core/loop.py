"""Parallel loops — the unit of computation the runtime schedules (``ops_par_loop``).

A loop owns: an iteration box over a block, a list of dataset arguments
(dataset + stencil + access mode), optional global reductions, and a
*vectorised* kernel.  The kernel receives an :class:`Accessor` and returns a
dict mapping written-dataset names to value arrays over the iteration box
(plus reduction contributions).  Point-order independence — the core OPS
contract that legitimises re-scheduling — is preserved by construction:
kernels are pure array functions of their stencil reads.

Write/RW/INC arguments must use the zero stencil (same restriction as OPS);
READ arguments may use any stencil.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

from .block import Block
from .dataset import Dataset
from .stencil import Stencil


class AccessMode(enum.Enum):
    READ = "read"
    WRITE = "write"
    RW = "rw"
    INC = "inc"

    @property
    def reads(self) -> bool:
        return self in (AccessMode.READ, AccessMode.RW, AccessMode.INC)

    @property
    def writes(self) -> bool:
        return self in (AccessMode.WRITE, AccessMode.RW, AccessMode.INC)


# Short aliases, OPS-style.
READ = AccessMode.READ
WRITE = AccessMode.WRITE
RW = AccessMode.RW
INC = AccessMode.INC


@dataclass(frozen=True)
class Arg:
    """One dataset argument of a parallel loop."""

    dat: Dataset
    stencil: Stencil
    mode: AccessMode

    def __post_init__(self) -> None:
        if self.stencil.ndim != self.dat.ndim:
            raise ValueError(
                f"arg {self.dat.name!r}: stencil ndim {self.stencil.ndim} != "
                f"dat ndim {self.dat.ndim}"
            )
        if self.mode.writes and not self.stencil.is_zero():
            raise ValueError(
                f"arg {self.dat.name!r}: {self.mode.value} access requires the "
                f"zero stencil (got {self.stencil.name!r})"
            )


class Accessor:
    """What kernels see: ``acc(name, offset)`` -> array over the iteration box.

    Concrete accessors are provided by the execution engines (in-core, tiled,
    out-of-core, Pallas); kernels never touch raw storage.  ``acc.shape`` is
    the (static) iteration-box shape; ``acc.coords()`` returns per-dimension
    global grid coordinates over the box (OPS's ``ops_arg_idx``) — kernels
    that need spatial position MUST use it so they stay correct under tiling.
    """

    shape: Tuple[int, ...] = ()

    def __call__(self, name: str, offset: Tuple[int, ...] = None):  # pragma: no cover
        raise NotImplementedError

    def coords(self):  # pragma: no cover
        raise NotImplementedError


Kernel = Callable[[Accessor], Dict[str, "jax.Array"]]  # noqa: F821


@dataclass
class ReductionSpec:
    """A global reduction produced by a loop (forces a chain boundary)."""

    name: str
    op: str = "sum"  # sum | min | max

    def combine(self, a, b):
        import jax.numpy as jnp

        if self.op == "sum":
            return a + b
        if self.op == "min":
            return jnp.minimum(a, b)
        if self.op == "max":
            return jnp.maximum(a, b)
        raise ValueError(self.op)

    def identity(self):
        import numpy as np

        return {"sum": 0.0, "min": np.inf, "max": -np.inf}[self.op]


@dataclass
class ParallelLoop:
    """A recorded (lazy) loop over ``range_`` applying ``kernel``."""

    name: str
    block: Block
    range_: Tuple[Tuple[int, int], ...]
    args: Tuple[Arg, ...]
    kernel: Kernel
    reductions: Tuple[ReductionSpec, ...] = ()

    def __post_init__(self) -> None:
        if len(self.range_) != self.block.ndim:
            raise ValueError(f"loop {self.name!r}: range arity mismatch")
        for a, b in self.range_:
            if b < a:
                raise ValueError(f"loop {self.name!r}: empty/negative range {self.range_}")
        seen_writes = set()
        for arg in self.args:
            if arg.dat.block is not self.block:
                raise ValueError(
                    f"loop {self.name!r}: dat {arg.dat.name!r} on a different block"
                )
            if arg.mode.writes:
                if arg.dat.name in seen_writes:
                    raise ValueError(
                        f"loop {self.name!r}: dat {arg.dat.name!r} written twice"
                    )
                seen_writes.add(arg.dat.name)
        # A dat written by this loop may only be READ at zero offset within the
        # same loop — UNLESS the read and write regions are provably disjoint
        # (halo-update loops: write halo rows, mirror-read the interior).
        # Offset reads of self-written data otherwise race under any parallel
        # schedule AND break skewed tiling (intra-loop WAR across tiles); OPS
        # imposes the same restriction; real codes split such loops in two.
        for arg in self.args:
            if (arg.mode is AccessMode.READ and arg.dat.name in seen_writes
                    and not arg.stencil.is_zero()):
                disjoint = False
                for d in range(self.block.ndim):
                    lo, hi = self.range_[d]
                    mn, mx = arg.stencil.extent(d)
                    # read interval [lo+mn, hi+mx) vs write interval [lo, hi)
                    if hi + mx <= lo or lo + mn >= hi:
                        disjoint = True
                        break
                if not disjoint:
                    raise ValueError(
                        f"loop {self.name!r}: {arg.dat.name!r} is written by this "
                        f"loop but read with non-zero stencil {arg.stencil.name!r} "
                        "over an overlapping region — split the loop"
                    )
        # Validate that loop range (extended by read stencils) stays within
        # dataset bounds — catches missing halo allocation at record time,
        # the moral equivalent of OPS's runtime bounds checks.
        for arg in self.args:
            for d in range(self.block.ndim):
                lo_off, hi_off = arg.stencil.extent(d)
                lo, hi = self.range_[d]
                blo, bhi = arg.dat.bounds(d)
                if arg.mode.reads and (lo + lo_off < blo or hi + hi_off > bhi):
                    raise ValueError(
                        f"loop {self.name!r}: read of {arg.dat.name!r} out of bounds "
                        f"in dim {d}: range [{lo},{hi}) + stencil [{lo_off},{hi_off}] "
                        f"vs dat bounds [{blo},{bhi})"
                    )
                if arg.mode.writes and (lo < blo or hi > bhi):
                    raise ValueError(
                        f"loop {self.name!r}: write of {arg.dat.name!r} out of bounds"
                    )

    # -- classification helpers used by dependency analysis ------------------
    def reads_of(self, dat_name: str) -> Sequence[Arg]:
        return [a for a in self.args if a.dat.name == dat_name and a.mode.reads]

    def writes_of(self, dat_name: str) -> Sequence[Arg]:
        return [a for a in self.args if a.dat.name == dat_name and a.mode.writes]

    @property
    def dat_names(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(a.dat.name for a in self.args))

    def bytes_moved(self) -> int:
        """The paper's bandwidth accounting: 1x for R or W, 2x for RW/INC,
        over the iteration box (useful-byte convention, §5.1)."""
        box = 1
        for a, b in self.range_:
            box *= b - a
        total = 0
        for arg in self.args:
            mult = 2 if (arg.mode.reads and arg.mode.writes) else 1
            total += mult * box * arg.dat.dtype.itemsize
        return total

    def flops(self, flops_per_point: Optional[int] = None) -> int:
        fpp = flops_per_point if flops_per_point is not None else 8 * len(self.args)
        box = 1
        for a, b in self.range_:
            box *= b - a
        return fpp * box
