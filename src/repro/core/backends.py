"""String-keyed backend registry: how a :class:`~repro.core.program.Session`
turns an :class:`~repro.core.program.ExecutionConfig` into something that can
run loop chains.

A backend is any object with ``run_chain(loops) -> {reduction: value}``;
optional attributes the session surfaces when present: ``history`` (per-chain
:class:`~repro.core.executor.ChainStats`), ``cfg`` (for the cyclic flag), and
``plan_hits``/``plan_misses``/``plan_time_s`` (chain-plan cache counters).

Built-ins:

==============  ===============================================================
``reference``   eager NumPy oracle, program order, no tiling (tests)
``resident``    paper baseline: everything in fast memory, raises beyond it
``ooc``         3-slot out-of-core streaming executor (Algorithm 1)
``ooc-async``   ``ooc`` with the threaded transfer engine: staging on
                background workers overlapping compute (bit-identical output)
``ooc-cyclic``  ``ooc`` with the §4.1 unsafe-temporaries elision pre-enabled
``sim``         ``ooc`` without the data plane: the same Plan IR stream,
                interpreted by the ledger interpreter only (modelled runs)
``ooc-sharded`` device-mesh execution: the grid decomposed along
                ``shard_dim`` over ``config.mesh`` (``"sim:N"`` virtual or
                ``"jax:N"`` real devices), every shard running the full
                out-of-core machinery with one accumulated-depth halo
                exchange per chain (paper §5.2)
``pallas``      eager backend routing tagged star-sweep loops through the
                Pallas TPU kernels in :mod:`repro.kernels` (fast path), with
                the reference path for everything else
==============  ===============================================================

Any ``ooc``-family backend given a multi-device ``mesh=`` transparently
routes through the sharded executor — the mesh is an orthogonal axis of the
config, not a separate code path.

The ``ooc``-family backends (including ``sim`` and ``resident``'s inner
executor) all lower chains to the typed instruction stream of
:mod:`repro.core.plan` and execute it through the shared interpreters in
:mod:`repro.core.interp` — ``Session.plan()``/``explain()``/``tune()`` work
on any of them.

Register your own with::

    @register_backend("my-backend")
    def _build(config: ExecutionConfig):
        return MyExecutor(...)
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .loop import AccessMode, ParallelLoop
from .reference import (
    merge_loop_reductions,
    run_chain_reference,
    run_loop_reference,
)

_REGISTRY: Dict[str, Callable] = {}


def register_backend(name: str):
    """Decorator registering ``factory(config) -> backend`` under ``name``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_backend(config):
    """Instantiate the backend ``config.backend`` names."""
    factory = _REGISTRY.get(config.backend)
    if factory is None:
        raise ValueError(
            f"unknown backend {config.backend!r}; "
            f"available: {', '.join(available_backends())}")
    return factory(config)


# -- built-in backends ------------------------------------------------------------


class ReferenceBackend:
    """Eager NumPy oracle (what :class:`ReferenceRuntime` used to be)."""

    def __init__(self):
        self.history: List = []

    def run_chain(self, loops: Sequence[ParallelLoop]):
        return run_chain_reference(loops)


class PallasBackend:
    """Eager backend with a Pallas fast path for tagged star-sweep loops.

    Loops whose kernel carries a ``pallas_op`` tag (built by
    :func:`repro.kernels.star2d_kernel` / ``star3d_kernel``) execute through
    the Pallas TPU kernels (``stencil2d``/``stencil3d``); untagged loops fall
    back to the reference path, so arbitrary chains still run correctly.
    """

    def __init__(self):
        self.history: List = []
        self.pallas_loops = 0
        self.fallback_loops = 0

    def run_chain(self, loops: Sequence[ParallelLoop]):
        merged: Dict[str, np.ndarray] = {}
        for lp in loops:
            op = getattr(lp.kernel, "pallas_op", None)
            if op is not None and self._try_pallas(lp, op):
                self.pallas_loops += 1
                continue
            self.fallback_loops += 1
            merge_loop_reductions(merged, lp, run_loop_reference(lp))
        return merged

    def _try_pallas(self, lp: ParallelLoop, op) -> bool:
        kind, src, dst, coeffs = op
        if lp.reductions or kind not in ("stencil2d", "stencil3d"):
            return False
        dats = {a.dat.name: a.dat for a in lp.args}
        if src not in dats or dst not in dats:
            return False
        src_dat, dst_dat = dats[src], dats[dst]
        # The fast path overwrites exactly dst from src: any other write arg,
        # an INC dst, or src==dst must take the general path.
        write_args = [a for a in lp.args if a.mode.writes]
        if (src == dst or len(write_args) != 1
                or write_args[0].dat.name != dst
                or write_args[0].mode is AccessMode.INC):
            return False
        box = lp.range_
        halo_box = tuple((a - 1, b + 1) for a, b in box)
        for d, (lo, hi) in enumerate(halo_box):
            blo, bhi = src_dat.bounds(d)
            if lo < blo or hi > bhi:
                return False
        from .. import kernels  # lazy: pulls in jax.experimental.pallas

        fn = kernels.stencil2d if kind == "stencil2d" else kernels.stencil3d
        padded = np.ascontiguousarray(src_dat.read(halo_box))
        out = fn(padded, np.asarray(coeffs, np.float32))
        dst_dat.write(box, np.asarray(out, dtype=dst_dat.dtype))
        return True


@register_backend("reference")
def _reference(config):
    return ReferenceBackend()


@register_backend("pallas")
def _pallas(config):
    return PallasBackend()


@register_backend("resident")
def _resident(config):
    from .executor import ResidentExecutor

    return ResidentExecutor(hw=config.hw, capacity_bytes=config.capacity_bytes)


def _ooc_executor(config, shared_plans=None, **overrides):
    """The shared ooc-family builder: a plain executor, or — when the config
    carries a multi-device mesh — the sharded one wrapping a per-device
    executor per mesh entry.  ``shared_plans`` (a serving-layer
    :class:`~repro.serve.SharedPlanCache`) attaches a cross-executor plan
    cache to unsharded executors; sharded executors plan per-device and keep
    their caches private."""
    from .executor import OutOfCoreExecutor
    from .sharded import ShardedOutOfCoreExecutor

    ooc_cfg = config.ooc_config(**overrides)
    mesh = getattr(config, "mesh", None)
    if mesh is not None and mesh.num_devices > 1:
        return ShardedOutOfCoreExecutor(
            ooc_cfg, mesh=mesh, shard_dim=config.shard_dim,
            halo_depth=config.halo_depth)
    return OutOfCoreExecutor(ooc_cfg, shared_plans=shared_plans)


@register_backend("ooc")
def _ooc(config):
    return _ooc_executor(config)


@register_backend("ooc-cyclic")
def _ooc_cyclic(config):
    return _ooc_executor(config, cyclic=True)


@register_backend("ooc-async")
def _ooc_async(config):
    """``ooc`` with the threaded transfer engine pre-enabled: uploads and
    downloads stage on background workers and genuinely overlap compute.
    Bit-identical to ``ooc`` (tasks touch disjoint regions; functional
    updates commute) — threading changes wall-clock behaviour only."""
    return _ooc_executor(config, transfer="threaded")


@register_backend("sim")
def _sim(config):
    return _ooc_executor(config, simulate_only=True)


@register_backend("ooc-sharded")
def _ooc_sharded(config):
    """Device-mesh execution, explicitly: always the sharded executor, even
    on a 1-device mesh (where it is bit-identical to ``ooc`` and simply
    skips decomposition and exchange)."""
    from .mesh import DeviceMesh
    from .sharded import ShardedOutOfCoreExecutor

    mesh = getattr(config, "mesh", None) or DeviceMesh.sim(1)
    return ShardedOutOfCoreExecutor(
        config.ooc_config(), mesh=mesh, shard_dim=config.shard_dim,
        halo_depth=config.halo_depth)
