"""Memory-hierarchy model: hardware presets, transfer ledger, timeline sim.

The container is CPU-only, so *wall-clock* numbers here are CPU numbers; the
paper's platform figures are reproduced through a calibrated bandwidth/latency
model.  Every byte the executor moves is recorded as a ledger event with
explicit dependencies mirroring Algorithm 1's three streams; the modelled
makespan is the longest path through that event graph with per-stream FIFO
serialisation — exactly how CUDA streams compose.

Presets carry the paper's measured numbers (STREAM/device copy bandwidths,
PCIe/NVLink throughputs as achieved, not peak) plus the TPU v5e target.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class HardwareModel:
    """Bandwidths in bytes/s, latencies in s, compute in flop/s."""

    name: str
    fast_capacity: float        # fast memory size (bytes)
    fast_bw: float              # fast-memory stream bandwidth
    slow_bw: float              # slow (DDR4/host) bandwidth
    up_bw: float                # slow->fast link bandwidth (achieved)
    down_bw: float              # fast->slow link bandwidth (achieved)
    dd_bw: float                # fast-memory device-device copy bandwidth
    link_latency: float = 10e-6
    flops: float = 1e12
    page_bytes: int = 2 << 20   # UM/cache page granularity
    page_fault_latency: float = 50e-6  # per-page miss service latency (UM)
    # -- host tier (the HostModel): how much slow memory there is, and how
    # fast the disk tier behind it moves when home copies spill past it.
    host_capacity: float = float("inf")  # host-RAM size (bytes)
    disk_bw: float = 2e9                 # spill-store streaming bandwidth
    disk_latency: float = 100e-6         # per-op service latency (seek/queue)
    # -- network (device-mesh halo exchanges): per-message launch latency and
    # achieved point-to-point bandwidth of the interconnect the sharded
    # backend's HaloExchange ops ride (defaults ~100 GbE as achieved).
    net_bw: float = 12.5e9               # bytes/s per link
    net_latency: float = 20e-6           # per-message latency

    def with_(self, **kw) -> "HardwareModel":
        return replace(self, **kw)


GB = 1e9

# Paper-measured numbers (§5): KNL 7210 quadrant/cache; P100 PCIe & NVLink.
KNL_7210 = HardwareModel(
    name="knl-7210",
    fast_capacity=16 * GB,
    fast_bw=291 * GB,       # STREAM triad, cache mode, dynamic alloc (§5.2)
    slow_bw=60.8 * GB,      # DDR4 flat
    up_bw=60.8 * GB,        # MCDRAM fills come from DDR4
    down_bw=60.8 * GB,
    dd_bw=314 * GB,         # MCDRAM flat bandwidth
    flops=2.6e12,
)
P100_PCIE = HardwareModel(
    name="p100-pcie",
    fast_capacity=16 * GB,
    fast_bw=509.7 * GB,     # measured device-device streaming copy (§5.3)
    slow_bw=60 * GB,
    up_bw=11 * GB,          # achieved PCIe throughput (§5.3)
    down_bw=11 * GB,
    dd_bw=509.7 * GB,
    flops=10e12,
)
P100_NVLINK = P100_PCIE.with_(name="p100-nvlink", up_bw=30 * GB, down_bw=30 * GB)
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    fast_capacity=16 * GB,
    fast_bw=819 * GB,
    slow_bw=100 * GB,
    up_bw=32 * GB,          # PCIe gen4 x16 host<->HBM, achieved-ish
    down_bw=32 * GB,
    dd_bw=819 * GB,
    flops=197e12,           # bf16
)
PRESETS = {m.name: m for m in (KNL_7210, P100_PCIE, P100_NVLINK, TPU_V5E)}


@dataclass
class Event:
    eid: int
    stream: int            # 0 = compute/edge, 1 = upload, 2 = download,
    #                        3 = disk, 4 = network (halo exchange)
    kind: str              # upload | download | edge | compute | prefetch
    #                        | fetch_home | spill_home
    #                        | halo_pack | halo_exchange | halo_unpack
    nbytes: int
    duration: float
    deps: Tuple[int, ...] = ()
    t_start: float = 0.0
    t_end: float = 0.0


class TransferLedger:
    """Records events; computes the modelled timeline (3-stream overlap)."""

    def __init__(self, hw: HardwareModel):
        self.hw = hw
        self.events: List[Event] = []
        self.totals: Dict[str, int] = {}

    def add(self, stream: int, kind: str, nbytes: int, duration: float,
            deps: Tuple[int, ...] = ()) -> int:
        eid = len(self.events)
        self.events.append(Event(eid, stream, kind, int(nbytes), duration, tuple(deps)))
        self.totals[kind] = self.totals.get(kind, 0) + int(nbytes)
        return eid

    # duration helpers -------------------------------------------------------
    def t_up(self, nbytes: int) -> float:
        return self.hw.link_latency + nbytes / self.hw.up_bw if nbytes else 0.0

    def t_down(self, nbytes: int) -> float:
        return self.hw.link_latency + nbytes / self.hw.down_bw if nbytes else 0.0

    def t_dd(self, nbytes: int) -> float:
        return nbytes / self.hw.dd_bw if nbytes else 0.0

    def t_disk(self, nbytes: int) -> float:
        return self.hw.disk_latency + nbytes / self.hw.disk_bw if nbytes else 0.0

    def t_net(self, nbytes: int, messages: int = 1) -> float:
        """Halo-exchange time: per-message launch latency plus payload on the
        interconnect (messages overlap across links; latency does not)."""
        if not nbytes and not messages:
            return 0.0
        return messages * self.hw.net_latency + nbytes / self.hw.net_bw

    def t_compute(self, nbytes: int, flops: int) -> float:
        return max(nbytes / self.hw.fast_bw, flops / self.hw.flops)

    # timeline ----------------------------------------------------------------
    def simulate(self) -> float:
        """Longest-path schedule with per-stream FIFO ordering; returns makespan.

        Speculative-prefetch events schedule normally (they occupy stream 1)
        but do not extend the makespan: their tail runs during the NEXT
        chain's ramp-up — that is the whole point of the optimisation."""
        stream_free: Dict[int, float] = {}
        for ev in self.events:  # events were appended in submission order
            start = stream_free.get(ev.stream, 0.0)
            for d in ev.deps:
                start = max(start, self.events[d].t_end)
            ev.t_start = start
            ev.t_end = start + ev.duration
            stream_free[ev.stream] = ev.t_end
        return max((ev.t_end for ev in self.events if ev.kind != "prefetch"),
                   default=0.0)

    def serialized_time(self) -> float:
        """What the same work would cost with no overlap (single stream)."""
        return sum(ev.duration for ev in self.events)

    def summary(self) -> Dict[str, float]:
        makespan = self.simulate()
        out = {f"bytes_{k}": float(v) for k, v in self.totals.items()}
        out["makespan_s"] = makespan
        out["serialized_s"] = self.serialized_time()
        out["overlap_efficiency"] = (
            out["serialized_s"] / makespan if makespan > 0 else 1.0
        )
        return out
