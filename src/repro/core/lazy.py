"""DEPRECATED imperative front end — thin shims over :class:`Session`.

``Runtime``/``ReferenceRuntime`` were the original lazy-recording API (record
loops, flush on data return).  That contract now lives in
:mod:`repro.core.program`; these classes remain so existing code and tests
keep working, at the cost of a :class:`DeprecationWarning`.  New code should
use::

    from repro.core import Session
    sess = Session("ooc")          # or "reference", "resident", "sim", ...
"""
from __future__ import annotations

import warnings

from .backends import ReferenceBackend
from .program import Session


class Runtime(Session):
    """Deprecated alias: ``Session`` wrapping an explicit executor object."""

    def __init__(self, executor=None):
        warnings.warn(
            "repro.core.Runtime is deprecated; use repro.core.Session "
            "(e.g. Session('ooc') or Session(backend=executor))",
            DeprecationWarning, stacklevel=2)
        if executor is None:
            from .executor import OutOfCoreExecutor

            executor = OutOfCoreExecutor()
        super().__init__(backend=executor)


class ReferenceRuntime(Session):
    """Deprecated alias: ``Session('reference')`` (eager NumPy oracle)."""

    def __init__(self):
        warnings.warn(
            "repro.core.ReferenceRuntime is deprecated; use "
            "repro.core.Session('reference')",
            DeprecationWarning, stacklevel=2)
        super().__init__(backend=ReferenceBackend())
