"""Lazy execution front end (OPS §3): record loops, flush on data return.

Users enqueue parallel loops; nothing executes until data must be returned
to user space (``fetch`` of a dataset, or reading a reduction result) — that
API call is the chain boundary, exactly as in OPS.  At flush time the queued
chain goes through dependency analysis → skewed tiling → the configured
executor.

``Runtime.cyclic`` is the paper's user flag: set it to True once the
application enters its cyclic main phase to enable the (unsafe) temporary-
dataset elision.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .block import Block
from .dataset import Dataset
from .executor import OOCConfig, OutOfCoreExecutor, ResidentExecutor
from .loop import Arg, Kernel, ParallelLoop, ReductionSpec
from .reference import run_chain_reference


class Runtime:
    """One lazy-execution context (one per application run)."""

    def __init__(self, executor: Union[OutOfCoreExecutor, ResidentExecutor, None] = None):
        self.executor = executor if executor is not None else OutOfCoreExecutor()
        self.queue: List[ParallelLoop] = []
        self._red_results: Dict[str, np.ndarray] = {}
        self.chains_flushed = 0

    # -- recording -------------------------------------------------------------
    def par_loop(
        self,
        name: str,
        block: Block,
        range_: Sequence[Tuple[int, int]],
        args: Sequence[Arg],
        kernel: Kernel,
        reductions: Sequence[ReductionSpec] = (),
    ) -> None:
        lp = ParallelLoop(
            name=name,
            block=block,
            range_=tuple(tuple(r) for r in range_),
            args=tuple(args),
            kernel=kernel,
            reductions=tuple(reductions),
        )
        self.queue.append(lp)

    # -- the cyclic flag (paper §4.1) -------------------------------------------
    @property
    def cyclic(self) -> bool:
        cfg = getattr(self.executor, "cfg", None)
        return bool(cfg and cfg.cyclic)

    @cyclic.setter
    def cyclic(self, value: bool) -> None:
        cfg = getattr(self.executor, "cfg", None)
        if cfg is not None:
            cfg.cyclic = bool(value)

    # -- flushing ---------------------------------------------------------------
    def flush(self) -> None:
        """Execute every queued loop, splitting chains at block boundaries."""
        if not self.queue:
            return
        queue, self.queue = self.queue, []
        chain: List[ParallelLoop] = []
        for lp in queue:
            if chain and lp.block is not chain[0].block:
                self._run(chain)
                chain = []
            chain.append(lp)
        if chain:
            self._run(chain)

    def _run(self, chain: List[ParallelLoop]) -> None:
        reds = self.executor.run_chain(chain)
        self._red_results.update(reds)
        self.chains_flushed += 1

    # -- data return (chain breakers) --------------------------------------------
    def fetch(self, dat: Dataset) -> np.ndarray:
        self.flush()
        return dat.interior().copy()

    def fetch_raw(self, dat: Dataset) -> np.ndarray:
        self.flush()
        return dat.data.copy()

    def reduction(self, name: str) -> np.ndarray:
        self.flush()
        if name not in self._red_results:
            raise KeyError(f"no reduction {name!r} has been produced")
        return self._red_results.pop(name)


class ReferenceRuntime(Runtime):
    """Same front end, eager NumPy oracle underneath (for tests)."""

    def __init__(self):
        super().__init__(executor=None)
        self.executor = None

    def _run(self, chain: List[ParallelLoop]) -> None:
        self._red_results.update(run_chain_reference(chain))
        self.chains_flushed += 1
