"""The unified ``StencilProgram``/``Session`` frontend.

This is the user-facing API of the runtime (the load-bearing seam every
backend plugs into):

* **Declarative kernel registration with inferred stencils** — instead of
  hand-building ``Arg(dat, stencil, mode)`` lists, users pass the datasets a
  loop touches and the runtime *traces* the kernel's :class:`Accessor` offset
  calls against abstract data to derive each READ stencil and every access
  mode.  ``explicit_stencil=`` is the escape hatch (e.g. to preserve a wider
  paper-fidelity footprint than the kernel formula reads), and
  ``validate_stencils=True`` cross-checks hand-declared ``Arg`` lists against
  the trace.
* **String-keyed backend registry** — ``Session("ooc")``,
  ``Session("reference")``, ... select execution strategies registered in
  :mod:`repro.core.backends`; one :class:`ExecutionConfig` absorbs the old
  ``OOCConfig`` + ``HardwareModel`` preset plumbing.
* **Memoised chain plans** — the executor caches the full
  ``analyze_chain`` → ``make_tile_schedule`` → engine pipeline keyed by a
  replay-safe chain signature, so cyclic applications (the 28-loop CloverLeaf
  timestep) pay analysis/scheduling once and replay it every following step;
  ``Session.plan_stats()`` reports the hit rate.

The lazy-recording contract is unchanged from OPS: loops queue up; data
returning to user space (``fetch``, reading a reduction) flushes the chain.
``Runtime``/``ReferenceRuntime`` in :mod:`repro.core.lazy` remain as thin
deprecation shims over :class:`Session`.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from .backends import make_backend
from .block import Block
from .dataset import Dataset
from .dependency import kernel_fingerprint
from .loop import AccessMode, Accessor, Arg, Kernel, ParallelLoop, ReductionSpec
from .memory import PRESETS, TPU_V5E, HardwareModel
from .stencil import Stencil, offset_stencil, point_stencil


class StencilValidationError(ValueError):
    """Declared stencils/modes disagree with what the kernel actually does."""


class SessionClosedError(RuntimeError):
    """Work was submitted to a Session after :meth:`Session.close`.

    ``close()`` itself is idempotent, and reads of already-materialised data
    (``fetch`` with an empty queue, ``reduction`` of a retained result) stay
    legal after close — only *new work* (``par_loop``, a flush with loops
    still queued) raises.  Server-registered sessions deregister their tenant
    on first close; this error is what a use-after-close gets instead of an
    AttributeError from a torn-down backend."""


@dataclass
class ExecutionConfig:
    """One config object selecting and parameterising a backend.

    ``hw`` accepts a :class:`HardwareModel` or a preset name from
    ``repro.core.memory.PRESETS`` (``"tpu-v5e"``, ``"p100-nvlink"``, ...).
    """

    backend: str = "ooc"
    hw: Union[HardwareModel, str] = TPU_V5E
    capacity_bytes: Optional[float] = None   # default: hw.fast_capacity
    num_slots: int = 3
    num_tiles: Optional[int] = None          # default: smallest that fits
    tiled_dim: int = 0
    cyclic: bool = False                     # §4.1 unsafe temporaries opt
    prefetch: bool = False                   # §4.1 speculative prefetch
    flops_per_point: Optional[int] = None
    simulate_only: bool = False              # schedule/ledger only
    validate_stencils: bool = False          # cross-check declared Args vs trace
    # -- transfer subsystem (repro.core.transfer) -----------------------------
    transfer: str = "sync"                   # "sync" | "threaded" workers
    codec: Union[str, Dict[str, str]] = "identity"   # per-dat: {"dat": name, "*": ...}
    pinned: Tuple[str, ...] = ()             # datasets kept device-resident
    # -- host tier (repro.core.store) -----------------------------------------
    # Host-RAM budget for dataset home copies; chains whose working set
    # exceeds it plan FetchHome/SpillHome ops against the disk-backed stores.
    host_capacity: Optional[float] = None    # default: hw.host_capacity
    # -- device mesh (repro.core.mesh / repro.core.sharded) --------------------
    # Grid decomposition along ``shard_dim``: a DeviceMesh, an int (virtual
    # sim:N mesh) or a "sim:N"/"jax:N" spec.  Any ooc-family backend with a
    # multi-device mesh routes through the sharded executor; ``halo_depth``
    # bounds the redundant-compute skirt (rows per interior side; default:
    # auto from the shard width).
    mesh: Union[None, int, str, "DeviceMesh"] = None  # noqa: F821
    shard_dim: int = 1
    halo_depth: Optional[int] = None
    # -- static verification (repro.core.verify) ------------------------------
    # Verify every plan before interpreting it; error-severity diagnostics
    # raise PlanVerificationError instead of executing a corrupting stream.
    debug: bool = False
    # -- observability (repro.obs) ---------------------------------------------
    # ``trace=True`` mints a span Tracer shared by every executor this config
    # builds (per-chain / per-op / transfer-lane spans, Chrome-trace export,
    # drift audit); pass an existing ``repro.obs.Tracer`` to share one spine
    # across sessions.  ``Session.trace()`` returns it.  Off by default.
    trace: object = None                     # None/False | True | obs.Tracer

    def __post_init__(self) -> None:
        if isinstance(self.hw, str):
            if self.hw not in PRESETS:
                raise ValueError(
                    f"unknown hardware preset {self.hw!r}; "
                    f"available: {sorted(PRESETS)}")
            self.hw = PRESETS[self.hw]
        from .mesh import parse_mesh

        self.mesh = parse_mesh(self.mesh)

    def ooc_config(self, **overrides):
        """Materialise the executor-level :class:`OOCConfig`."""
        from .executor import OOCConfig

        kw = dict(
            hw=self.hw, capacity_bytes=self.capacity_bytes,
            num_slots=self.num_slots, num_tiles=self.num_tiles,
            tiled_dim=self.tiled_dim, cyclic=self.cyclic,
            prefetch=self.prefetch, flops_per_point=self.flops_per_point,
            simulate_only=self.simulate_only,
            transfer=self.transfer, codec=self.codec,
            pinned=tuple(self.pinned),
            host_capacity=self.host_capacity,
            debug=self.debug,
            trace=self.trace,
        )
        kw.update(overrides)
        return OOCConfig(**kw)


# -- stencil inference ------------------------------------------------------------


class _TracingAccessor(Accessor):
    """Records every ``acc(name, offset)`` call against abstract data.

    The trace runs over a shrunken box (offsets are static Python tuples, so
    the access pattern is shape-independent); values are all-ones so kernels
    with divisions/sqrt trace cleanly.  Kernels must be pure array functions
    of their reads — the core OPS contract — which is exactly what makes this
    sound: one eager evaluation visits every access site.
    """

    def __init__(self, block: Block, range_: Tuple[Tuple[int, int], ...],
                 dats: Dict[str, Dataset]):
        self._block = block
        self._range = range_
        self._dats = dats
        self.shape = tuple(min(b - a, 3) for a, b in range_)
        self.reads: Dict[str, Set[Tuple[int, ...]]] = {}

    def coords(self):
        nd = self._block.ndim
        out = []
        for d in range(nd):
            lo = self._range[d][0]
            ar = np.arange(lo, lo + self.shape[d], dtype=np.int32)
            shape = [1] * nd
            shape[d] = self.shape[d]
            out.append(np.broadcast_to(ar.reshape(shape), self.shape))
        return tuple(out)

    def __call__(self, name: str, offset: Tuple[int, ...] = None):
        if name not in self._dats:
            raise KeyError(
                f"kernel reads dataset {name!r} which was not passed to "
                f"par_loop (known: {sorted(self._dats)})")
        nd = self._block.ndim
        if offset is None:
            offset = (0,) * nd
        offset = tuple(int(o) for o in offset)
        if len(offset) != nd:
            raise ValueError(
                f"kernel reads {name!r} with offset {offset} of arity "
                f"{len(offset)} != block ndim {nd}")
        self.reads.setdefault(name, set()).add(offset)
        return np.ones(self.shape, dtype=self._dats[name].dtype)


@dataclass(frozen=True)
class KernelTrace:
    """What one abstract evaluation of a kernel revealed."""

    reads: Dict[str, Tuple[Tuple[int, ...], ...]]   # name -> sorted offsets
    writes: Tuple[str, ...]                          # dat names produced


def trace_kernel(
    kernel: Kernel,
    block: Block,
    range_: Tuple[Tuple[int, int], ...],
    dats: Dict[str, Dataset],
    reductions: Sequence[ReductionSpec] = (),
) -> KernelTrace:
    """Run ``kernel`` once against abstract data and classify its accesses."""
    acc = _TracingAccessor(block, range_, dats)
    out = kernel(acc)
    if not isinstance(out, dict):
        raise TypeError(
            f"kernel must return a dict of written-dat/reduction arrays, "
            f"got {type(out).__name__}")
    red_names = {r.name for r in reductions}
    writes = []
    for name in out:
        if name in red_names:
            continue
        if name not in dats:
            raise KeyError(
                f"kernel produced {name!r} which is neither a dataset passed "
                f"to par_loop nor a declared reduction "
                f"(datasets: {sorted(dats)}; reductions: {sorted(red_names)})")
        writes.append(name)
    missing = red_names - set(out)
    if missing:
        raise KeyError(f"kernel did not produce reduction(s) {sorted(missing)}")
    return KernelTrace(
        reads={n: tuple(sorted(offs)) for n, offs in acc.reads.items()},
        writes=tuple(writes),
    )


def infer_args(
    kernel: Kernel,
    block: Block,
    range_: Tuple[Tuple[int, int], ...],
    dats: Sequence[Dataset],
    reductions: Sequence[ReductionSpec] = (),
    inc: Sequence[str] = (),
    explicit_stencil: Optional[Dict[str, Stencil]] = None,
    extra: Sequence[Arg] = (),
) -> Tuple[Arg, ...]:
    """Build the ``Arg`` list for ``dats`` from a kernel trace.

    ``extra`` are hand-declared args for additional datasets (mixed style);
    they participate in the trace's name resolution but are not re-derived.
    ``inc`` names datasets whose writes accumulate (INC) — accumulation is a
    semantic choice the trace cannot observe, so it stays an explicit hint.
    """
    explicit_stencil = explicit_stencil or {}
    by_name = {d.name: d for d in dats}
    for a in extra:
        by_name.setdefault(a.dat.name, a.dat)
    trace = trace_kernel(kernel, block, range_, by_name, reductions)
    nd = block.ndim
    zero = point_stencil(nd)
    written = set(trace.writes)
    inc = set(inc)
    inferred_names = {d.name for d in dats}
    unknown_inc = inc - inferred_names
    if unknown_inc:
        raise ValueError(f"inc= names not among the inferred datasets: "
                         f"{sorted(unknown_inc)}")
    unknown_sten = set(explicit_stencil) - inferred_names
    if unknown_sten:
        # A typo here would silently drop a declared-wider footprint.
        raise ValueError(f"explicit_stencil= names not among the inferred "
                         f"datasets: {sorted(unknown_sten)}")

    args: List[Arg] = []
    for dat in dats:
        nm = dat.name
        offs = trace.reads.get(nm, ())
        w = nm in written
        if not offs and not w:
            raise ValueError(
                f"dataset {nm!r} was passed to par_loop but the kernel "
                f"neither reads nor writes it")
        sten = explicit_stencil.get(nm)
        if sten is not None and offs:
            # The override exists to *widen* footprints; a stencil narrower
            # than the traced reads would silently mis-size tile halos.
            uncovered = set(offs) - set(sten.points)
            if uncovered:
                raise StencilValidationError(
                    f"explicit_stencil for {nm!r} does not cover traced read "
                    f"offsets {sorted(uncovered)}")
        if sten is None and offs:
            sten = offset_stencil(*offs)
        if w and offs:
            if all(all(o == 0 for o in p) for p in offs) and nm not in explicit_stencil:
                mode = AccessMode.INC if nm in inc else AccessMode.RW
                args.append(Arg(dat, zero, mode))
            else:
                # Offset reads of a written dat: split into READ(stencil) +
                # WRITE(zero) args — legal only when the regions are disjoint
                # (halo-mirror loops); ParallelLoop validates that.
                if nm in inc:
                    raise ValueError(
                        f"inc={nm!r}: accumulation cannot combine with "
                        f"non-zero-offset reads of the same dataset — split "
                        f"the loop")
                args.append(Arg(dat, sten, AccessMode.READ))
                args.append(Arg(dat, zero, AccessMode.WRITE))
        elif w:
            mode = AccessMode.INC if nm in inc else AccessMode.WRITE
            args.append(Arg(dat, zero, mode))
        else:
            args.append(Arg(dat, sten, AccessMode.READ))
    return tuple(args)


def validate_declared_args(
    kernel: Kernel,
    block: Block,
    range_: Tuple[Tuple[int, int], ...],
    declared: Sequence[Arg],
    reductions: Sequence[ReductionSpec] = (),
    loop_name: str = "?",
    extra_dats: Sequence[Dataset] = (),
) -> None:
    """Check hand-declared ``Arg`` lists against the kernel trace.

    Declared READ stencils must *cover* the traced offsets (wider is fine —
    structural-fidelity footprints are legitimate); declared writes must
    exactly match the names the kernel produces.  ``extra_dats`` are
    inference-covered datasets of a mixed-style loop: they participate in
    the trace's name resolution but their accesses are not checked here
    (inference derives them exactly).
    """
    by_name = {a.dat.name: a.dat for a in declared}
    declared_names = set(by_name)
    for d in extra_dats:
        by_name.setdefault(d.name, d)
    trace = trace_kernel(kernel, block, range_, by_name, reductions)
    problems: List[str] = []
    declared_reads: Dict[str, Set[Tuple[int, ...]]] = {}
    declared_writes: Set[str] = set()
    for a in declared:
        if a.mode.reads:
            declared_reads.setdefault(a.dat.name, set()).update(a.stencil.points)
        if a.mode.writes:
            declared_writes.add(a.dat.name)
    for nm, offs in trace.reads.items():
        if nm not in declared_names:
            continue  # inference-covered
        missing = set(offs) - declared_reads.get(nm, set())
        if missing:
            problems.append(
                f"read of {nm!r} at offsets {sorted(missing)} not covered by "
                f"declared stencil(s) {sorted(declared_reads.get(nm, set()))}")
    traced_writes = set(trace.writes) & declared_names
    if traced_writes != declared_writes:
        only_decl = declared_writes - traced_writes
        only_trace = traced_writes - declared_writes
        if only_decl:
            problems.append(f"declared writes never produced: {sorted(only_decl)}")
        if only_trace:
            problems.append(f"kernel writes undeclared dats: {sorted(only_trace)}")
    if problems:
        raise StencilValidationError(
            f"loop {loop_name!r}: " + "; ".join(problems))


# -- the session ------------------------------------------------------------------


class Session:
    """One lazy-execution context over a registry-selected backend.

    Construction::

        Session()                      # default out-of-core backend
        Session("reference")           # by backend name
        Session("ooc", hw="p100-nvlink", prefetch=True)   # name + overrides
        Session(ExecutionConfig(backend="sim", num_tiles=8))
        Session(backend=my_executor)   # power users: a ready run_chain object

    Loops record via :meth:`par_loop`; chains flush when data returns to user
    space (:meth:`fetch`, :meth:`reduction`), exactly as in OPS.
    """

    def __init__(self, config: Union[ExecutionConfig, str, None] = None, *,
                 backend=None, **overrides):
        if backend is not None:
            if config is not None or overrides:
                raise ValueError("pass either a config/name or a backend object")
            self.config: Optional[ExecutionConfig] = None
            self.backend = backend
        else:
            if isinstance(config, str):
                config = ExecutionConfig(backend=config, **overrides)
            elif config is None:
                config = ExecutionConfig(**overrides)
            elif overrides:
                config = replace(config, **overrides)
            self.config = config
            self.backend = make_backend(config)
        # Old name, kept so code written against Runtime keeps working.
        self.executor = self.backend
        self.queue: List[ParallelLoop] = []
        self._red_results: Dict[str, np.ndarray] = {}
        self.chains_flushed = 0
        # Every dataset any recorded loop has touched, by name — what
        # checkpoint()/restore() cover when no explicit list is given.
        self.datasets: Dict[str, Dataset] = {}
        # LRU-bounded like the executor's plan cache: kernels capturing a
        # per-step constant mint a new fingerprint every step.
        self._arg_cache: "OrderedDict[Tuple, Tuple[Arg, ...]]" = OrderedDict()
        self._max_arg_cache = 512
        self._closed = False

    # -- recording -------------------------------------------------------------
    def par_loop(
        self,
        name: str,
        block: Block,
        range_: Sequence[Tuple[int, int]],
        args: Sequence[Union[Arg, Dataset]],
        kernel: Kernel,
        reductions: Sequence[ReductionSpec] = (),
        *,
        inc: Sequence[str] = (),
        explicit_stencil: Optional[Dict[str, Stencil]] = None,
    ) -> None:
        """Record one parallel loop.

        ``args`` entries are either bare :class:`Dataset` handles — access
        modes and READ stencils are then *inferred* by tracing ``kernel`` —
        or fully-explicit :class:`Arg` declarations (the two styles mix).
        ``explicit_stencil={name: stencil}`` overrides the inferred READ
        stencil for that dataset; ``inc=[name]`` marks accumulating writes.
        """
        if self._closed:
            raise SessionClosedError(
                f"par_loop({name!r}) on a closed Session")
        range_t = tuple((int(a), int(b)) for a, b in range_)
        declared: List[Arg] = []
        inferred_dats: List[Dataset] = []
        for a in args:
            if isinstance(a, Arg):
                declared.append(a)
            elif isinstance(a, Dataset):
                inferred_dats.append(a)
            else:
                raise TypeError(
                    f"loop {name!r}: args entries must be Arg or Dataset, "
                    f"got {type(a).__name__}")
        validate = self.config is not None and self.config.validate_stencils
        kernel_fp = None
        if inferred_dats:
            kernel_fp = kernel_fingerprint(kernel)
            inferred = self._infer_cached(
                kernel_fp, block, range_t, inferred_dats, kernel,
                tuple(reductions), tuple(inc), explicit_stencil,
                tuple(declared))
            all_args = tuple(declared) + inferred
            if validate and declared:
                validate_declared_args(
                    kernel, block, range_t, declared, reductions, name,
                    extra_dats=inferred_dats)
        else:
            # inc/explicit_stencil only shape *inference* — with an all-Arg
            # loop they would be silently dropped, so reject them loudly.
            if inc or explicit_stencil:
                raise ValueError(
                    f"loop {name!r}: inc=/explicit_stencil= given but every "
                    f"args entry is an explicit Arg — nothing to infer")
            all_args = tuple(declared)
            if validate:
                validate_declared_args(
                    kernel, block, range_t, declared, reductions, name)
        lp = ParallelLoop(
            name=name, block=block, range_=range_t, args=all_args,
            kernel=kernel, reductions=tuple(reductions),
        )
        for a in all_args:
            self.datasets[a.dat.name] = a.dat
        if kernel_fp is not None:
            lp.__dict__["_kernel_fp"] = kernel_fp  # reused by plan_signature
        self.queue.append(lp)

    def _infer_cached(self, kernel_fp, block, range_t, dats, kernel,
                      reductions, inc, explicit_stencil, declared
                      ) -> Tuple[Arg, ...]:
        key = (
            kernel_fp,
            tuple((d.name, id(d), d.dtype.str) for d in dats),
            tuple((a.dat.name, id(a.dat), a.stencil.points, a.mode.value)
                  for a in declared),
            tuple((r.name, r.op) for r in reductions),
            inc,
            tuple(sorted((n, s.points) for n, s in (explicit_stencil or {}).items())),
        )
        cached = self._arg_cache.get(key)
        if cached is None:
            cached = infer_args(
                kernel, block, range_t, dats, reductions, inc,
                explicit_stencil, extra=declared)
            self._arg_cache[key] = cached
            if len(self._arg_cache) > self._max_arg_cache:
                self._arg_cache.popitem(last=False)
        else:
            self._arg_cache.move_to_end(key)
        return cached

    # -- the cyclic flag (paper §4.1) -------------------------------------------
    @property
    def cyclic(self) -> bool:
        cfg = getattr(self.backend, "cfg", None)
        return bool(cfg and cfg.cyclic)

    @cyclic.setter
    def cyclic(self, value: bool) -> None:
        cfg = getattr(self.backend, "cfg", None)
        if cfg is not None:
            cfg.cyclic = bool(value)

    # -- flushing ---------------------------------------------------------------
    def flush(self) -> None:
        """Execute every queued loop, splitting chains at block boundaries.

        Reduction results from *previous* flushes are dropped here: a
        reduction stays readable (any number of times) until the next flush
        that actually executes loops replaces it."""
        if not self.queue:
            return
        if self._closed:
            # Unreachable through the public API (par_loop refuses to record
            # after close), but a queue mutated by hand must not silently run
            # on a torn-down backend.
            raise SessionClosedError("flush() of queued loops on a closed Session")
        self._red_results.clear()
        queue, self.queue = self.queue, []
        chain: List[ParallelLoop] = []
        for lp in queue:
            if chain and lp.block is not chain[0].block:
                self._run(chain)
                chain = []
            chain.append(lp)
        if chain:
            self._run(chain)

    def _run(self, chain: List[ParallelLoop]) -> None:
        reds = self.backend.run_chain(chain)
        self._red_results.update(reds)
        self.chains_flushed += 1

    # -- data return (chain breakers) --------------------------------------------
    def fetch(self, dat: Dataset) -> np.ndarray:
        self.flush()
        return dat.interior().copy()

    def fetch_raw(self, dat: Dataset) -> np.ndarray:
        self.flush()
        return np.array(dat.materialize(), copy=True)

    def reduction(self, name: str) -> np.ndarray:
        """Flush and return reduction ``name``.  Results are *retained* until
        the next flush, so reading the same reduction twice is legal (it used
        to raise ``KeyError`` on the second read)."""
        self.flush()
        if name not in self._red_results:
            raise KeyError(f"no reduction {name!r} has been produced")
        return self._red_results[name]

    # -- plans: inspect before you execute -----------------------------------------
    def _planning_executor(self):
        """The OOC executor that builds Plan IRs for this session's backend."""
        from .executor import OutOfCoreExecutor, ResidentExecutor
        from .sharded import ShardedOutOfCoreExecutor

        be = self.backend
        if isinstance(be, (OutOfCoreExecutor, ShardedOutOfCoreExecutor)):
            return be
        if isinstance(be, ResidentExecutor):
            return be._inner
        raise ValueError(
            f"backend {type(be).__name__} does not build plans; use an "
            f"ooc/ooc-async/ooc-cyclic/ooc-sharded/sim/resident session")

    def plan(self, loops=None):
        """Lower the queued loops (or ``loops``) to their Plan IRs *without*
        executing anything — the queue is untouched.  Returns one
        :class:`~repro.core.plan.Plan` per chain, in execution order,
        including the chains a MemoryError split would produce."""
        loops = list(self.queue) if loops is None else list(loops)
        if not loops:
            return []
        ex = self._planning_executor()
        plans = []
        chain: List[ParallelLoop] = []
        for lp in loops:
            if chain and lp.block is not chain[0].block:
                plans.extend(self._plan_split(ex, chain, frozenset()))
                chain = []
            chain.append(lp)
        if chain:
            plans.extend(self._plan_split(ex, chain, frozenset()))
        return plans

    def _plan_split(self, ex, loops, keep_live, warm=frozenset()):
        """Mirror ``run_chain``'s MemoryError chain splitting, plans only.
        Sharded backends plan per device (segments x shards): their chain
        plans carry a tuple of device-annotated Plan IRs, flattened here.
        The split policy must stay in lock-step with
        ``OutOfCoreExecutor.run_chain`` and
        ``ShardedOutOfCoreExecutor._plan_local``."""
        try:
            ir = ex.plan_chain(loops, keep_live, warm=warm).ir
            return list(ir) if isinstance(ir, tuple) else [ir]
        except MemoryError:
            if len(loops) <= 1:
                raise
            mid = len(loops) // 2
            head, tail = loops[:mid], loops[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            head_writes = frozenset(
                a.dat.name for lp in head for a in lp.args if a.mode.writes)
            return (self._plan_split(ex, head, keep_live | tail_reads, warm)
                    + self._plan_split(ex, tail, keep_live,
                                       warm | head_writes))

    def verify(self, loops=None):
        """Statically verify the plans for the queued loops (or ``loops``)
        without executing anything.  Returns a
        :class:`~repro.core.verify.VerifyResult` — every chain's stream is
        abstract-interpreted for residency/dirty-loss/halo soundness and
        transfer-lane ordering, and on a sharded session the per-device
        plans are cross-checked for exchange consistency.
        ``session.verify().ok`` is the machine-checkable answer to "will
        this step's plans corrupt data"."""
        from .verify import verify_plans

        return verify_plans(self.plan(loops))

    def explain(self, loops=None, *, verify: bool = False) -> str:
        """Human-readable per-tile op listing for the queued loops (or
        ``loops``): staging/compute/carry/download per tile with modelled
        bytes, op totals, and the ledger-modelled makespan per chain.  On a
        sharded session every device's stream is listed (with its halo ops
        and per-device makespan), followed by a mesh summary line.  With
        ``verify=True`` the static verifier's diagnostic summary is
        appended."""
        from .plan import format_plan

        plans = self.plan(loops)
        if not plans:
            return "(nothing queued: record loops before explain())"
        hw = self.config.hw if self.config is not None else getattr(
            getattr(self.backend, "cfg", None), "hw", None)
        from .interp import simulate_plan

        per_dev: Dict[int, float] = {}
        msgs = nbytes = 0
        blocks = []
        for i, p in enumerate(plans):
            title = (f"chain {i}/{len(plans)}"
                     + (f" · device {p.device}/{p.mesh_devices}"
                        if p.mesh_devices > 1 else ""))
            if p.mesh_devices > 1 and hw is not None:
                # Simulate once: the per-plan makespan line and the mesh
                # summary share the same result.
                res = simulate_plan(p, hw)
                bw = (p.loop_bytes / res.makespan / 1e9
                      if res.makespan else 0.0)
                blocks.append(
                    format_plan(p, None, title=title)
                    + f"\n  modelled makespan (device {p.device}, "
                    f"{hw.name}): {res.makespan * 1e3:.3f} ms"
                    f"  ({bw:.1f} GB/s avg)")
                per_dev[p.device] = per_dev.get(p.device, 0.0) + res.makespan
                tot = p.totals()
                msgs += tot["halo_messages"]
                nbytes += tot["halo_bytes"]
            else:
                blocks.append(format_plan(p, hw, title=title))
        if per_dev:
            devs = " ".join(f"d{d}={t * 1e3:.3f}ms"
                            for d, t in sorted(per_dev.items()))
            blocks.append(
                f"mesh summary: per-device makespans {devs}; critical "
                f"device {max(per_dev.values()) * 1e3:.3f} ms; halo "
                f"{msgs} msgs / {nbytes / 1e6:.3f} MB")
        if verify:
            from .verify import verify_plans

            blocks.append(verify_plans(plans).summary())
        return "\n\n".join(blocks)

    def tune(self, loops=None, *, apply: bool = False, repeats: int = 2,
             **grids):
        """Enumerate candidate configs (``num_tiles`` × ``tiled_dim`` ×
        ``num_slots`` × codec), cost each on the queued loops (or ``loops``)
        via the sim interpreter, and return the best as a
        :class:`~repro.core.tune.TuneResult` — modelled makespan never worse
        than this session's config, which is always a candidate.  With
        ``apply=True`` the session's backend is rebuilt around the winner
        (the queue survives: loops reference datasets, not the backend)."""
        from .tune import tune_configs

        loops = list(self.queue) if loops is None else list(loops)
        if self.config is None:
            raise ValueError(
                "sessions over a hand-built backend object have no "
                "ExecutionConfig to tune")
        result = tune_configs(loops, self.config, repeats=repeats, **grids)
        if apply:
            old = getattr(self.backend, "close", None)
            if old is not None:
                old()
            self.config = result.best
            self.backend = make_backend(result.best)
            self.executor = self.backend
        return result

    # -- checkpoint / restart -----------------------------------------------------
    def checkpoint(self, path: str, datasets=None) -> Dict:
        """Write a restartable snapshot to ``path`` (atomic write-then-rename).

        Flushes pending loops first, then captures every dataset this session
        has seen (or the explicit ``datasets``) — materialised home copies,
        versions — plus the plan-cache signature hashes for provenance.  A
        multi-hour out-of-core run killed after this call resumes
        bit-identically via :meth:`restore`.  Returns the manifest.

        App-level *scalars* (a CFL ``dt``, a step counter steering sweep
        direction) live outside the runtime; persist and restore those
        alongside the checkpoint yourself."""
        from .store import save_checkpoint

        self.flush()
        dats = list(datasets) if datasets is not None else list(
            self.datasets.values())
        # Sharded backends keep their plan caches on the per-device inner
        # executors — aggregate so multi-device checkpoints carry the same
        # plan-signature provenance as unsharded ones.
        plans = list(getattr(self.backend, "_plans", {}).values())
        for ex in getattr(self.backend, "inner", ()):
            plans.extend(getattr(ex, "_plans", {}).values())
        sigs = [cp.ir.sig_hash for cp in plans
                if getattr(cp, "ir", None) is not None]
        return save_checkpoint(path, dats,
                               chains_flushed=self.chains_flushed,
                               plan_signatures=sigs)

    def restore(self, path: str, datasets=None) -> Dict:
        """Load a :meth:`checkpoint` back into live datasets (matched by
        name; shapes/dtypes validated) and reset device-side data caches so
        nothing stale survives from before the snapshot.  In a fresh process
        the session has not seen any loops yet — pass the new app's datasets
        explicitly.  Pending queued loops are dropped (they reference
        pre-restore state).  Returns the manifest."""
        from .store import load_checkpoint

        dats = list(datasets) if datasets is not None else list(
            self.datasets.values())
        manifest = load_checkpoint(path, dats)
        for d in dats:
            self.datasets[d.name] = d
        self.queue.clear()
        self._red_results.clear()
        reset = getattr(self.backend, "reset_data_caches", None)
        if reset is not None:
            reset()
        return manifest

    # -- introspection -----------------------------------------------------------
    @property
    def history(self):
        """Per-chain :class:`ChainStats` from the backend (empty if eager)."""
        return getattr(self.backend, "history", [])

    def plan_stats(self) -> Dict[str, float]:
        """Chain-plan cache counters (zeros for backends that don't plan)."""
        hits = getattr(self.backend, "plan_hits", 0)
        misses = getattr(self.backend, "plan_misses", 0)
        tot = hits + misses
        return {
            "plan_hits": hits,
            "plan_misses": misses,
            "plan_hit_rate": hits / tot if tot else 0.0,
            "plan_time_s": getattr(self.backend, "plan_time_s", 0.0),
        }

    def close(self) -> None:
        """Flush pending loops and release backend resources (the threaded
        transfer engine's worker threads, for ``ooc``-family backends; the
        server-side tenant registration, for serving clients).  Idempotent:
        the second and later calls are no-ops."""
        if self._closed:
            return
        self.flush()
        self._closed = True
        fn = getattr(self.backend, "close", None)
        if fn is not None:
            fn()

    # -- context manager: worker threads must not outlive the with-block ------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            # The body died mid-recording: executing a half-recorded queue
            # during unwinding would mutate dataset homes the user never
            # asked for (and could mask the original exception).  Drop the
            # queue, release backend resources, let the exception propagate.
            self.queue.clear()
            if not self._closed:
                self._closed = True
                fn = getattr(self.backend, "close", None)
                if fn is not None:
                    fn()
            return
        self.close()

    def trace(self):
        """The observability spine's span buffer (:class:`repro.obs.Tracer`)
        when this session was built with ``trace=``, else ``None``.  Use
        ``trace().save(path)`` for a Perfetto-viewable Chrome trace, or feed
        it with a backend ledger to :func:`repro.obs.audit.compare`."""
        tr = getattr(self.backend, "tracer", None)
        if tr is not None and getattr(tr, "enabled", False):
            return tr
        return None

    def transfer_stats(self) -> Dict[str, float]:
        """Transfer-subsystem counters: raw vs post-codec wire bytes, the
        achieved compression ratio, queue-wait time, and per-lane queue-wait
        / service-time histograms under ``"lanes"`` (zeros/defaults for
        backends without a transfer engine)."""
        fn = getattr(self.backend, "transfer_stats", None)
        if fn is not None:
            return fn()
        return {
            "mode": "none", "bytes_up_raw": 0, "bytes_down_raw": 0,
            "bytes_up_wire": 0, "bytes_down_wire": 0, "bytes_moved_wire": 0,
            "compression_ratio": 1.0, "queue_wait_s": 0.0,
            "elided_rows": 0, "evictions": 0, "pinned_hits": 0,
            "bytes_disk_read": 0, "bytes_disk_written": 0,
            "halo_messages": 0, "halo_bytes": 0, "lanes": {},
        }


# ``StencilProgram`` is the declarative-frontend name from the redesign;
# ``Session`` emphasises the execution-context role.  Same object.
StencilProgram = Session
