r"""Skewed tile schedule construction (the paper's §3/§4 core).

Tiles are slabs along one dimension (``tiled_dim``, default 0 — the
outermost/contiguous dimension, so host<->device transfers are contiguous).
Tiles execute left-to-right; within a tile the chain's loops execute in
program order over *shifted* sub-ranges.

Correctness of the uniform skew (σ = chain max read-stencil extent along the
tiled dim, ``shift_k = (n-1-k)·σ`` for loop index k of n):

* RAW — loop j reads data produced by loop i<j at positions up to
  ``end_j + σ = E + (n-1-j)σ + σ ≤ E + (n-1-i)σ = end_i``: already computed
  by loop i *in this tile*.
* WAR — loop j>i overwrites a dat loop i reads.  In tile t+1 loop i reads
  *old* values at positions ≥ ``start_i − σ = E + (n-1-i)σ − σ ≥
  E + (n-1-j)σ = end_j(t)``: loop j in tile t stopped exactly below every
  position tile t+1's loop i still needs (half-open ranges meet exactly at
  j = i+1).

Footprint algebra for out-of-core staging (paper Fig. 2):
  full footprint  F(d,t) = ∪ over accesses of [start+min_off, end+max_off)
  right footprint = F(d,t) \ F(d,t-1)   (new data → upload)
  left  footprint = F(d,t) \ F(d,t+1)   (retired data → download)
  right edge      = F(d,t) ∩ F(d,t+1)   (overlap → device-side copy to next slot)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .dependency import ChainInfo


@dataclass(frozen=True)
class Interval:
    lo: int
    hi: int  # half-open

    @property
    def empty(self) -> bool:
        return self.hi <= self.lo

    @property
    def length(self) -> int:
        return max(0, self.hi - self.lo)

    def clamp(self, lo: int, hi: int) -> "Interval":
        return Interval(max(self.lo, lo), min(self.hi, hi))

    def union(self, other: "Interval") -> "Interval":
        if self.empty:
            return other
        if other.empty:
            return self
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def intersect(self, other: "Interval") -> "Interval":
        return Interval(max(self.lo, other.lo), min(self.hi, other.hi))

    def difference(self, other: "Interval") -> Tuple["Interval", ...]:
        """self \\ other as up to two pieces.  Skewed schedules can produce
        NON-monotone footprints (an early loop runs to the grid end inside
        tile t while tile t+1 only runs late loops that stop short), so both
        the left piece [lo, other.lo) and the right piece [other.hi, hi) can
        be non-empty — dropping the right piece loses written data."""
        if self.empty:
            return ()
        if other.empty or other.hi <= self.lo or other.lo >= self.hi:
            return (self,)
        pieces = []
        if other.lo > self.lo:
            pieces.append(Interval(self.lo, other.lo))
        if other.hi < self.hi:
            pieces.append(Interval(other.hi, self.hi))
        return tuple(pieces)


EMPTY = Interval(0, 0)


@dataclass
class TilePlan:
    """Everything needed to stage and execute one tile."""

    index: int
    # Per loop: the full iteration box for this tile (tiled dim sub-range
    # substituted), or None if the loop's sub-range is empty in this tile.
    loop_ranges: List[Optional[Tuple[Tuple[int, int], ...]]]
    footprint: Dict[str, Interval]            # full footprint per dat (tiled dim)
    upload: Dict[str, Tuple[Interval, ...]]   # right footprint F \ F_prev (new data)
    download: Dict[str, Tuple[Interval, ...]] # left footprint F \ F_next (retired)
    edge_to_next: Dict[str, Interval]         # right edge F ∩ F_next (overlap)

    def work_points(self) -> int:
        total = 0
        for box in self.loop_ranges:
            if box is None:
                continue
            n = 1
            for a, b in box:
                n *= b - a
            total += n
        return total


@dataclass
class TileSchedule:
    chain: ChainInfo
    tiles: List[TilePlan]
    boundaries: List[int]
    # Slot sizing: max footprint length per dat over all tiles (uniform slot
    # arrays keep the jit cache small: interior tiles share one signature).
    max_fp_len: Dict[str, int]

    @property
    def num_tiles(self) -> int:
        return len(self.tiles)

    def slot_bytes(self, exclude: frozenset = frozenset()) -> int:
        """Fast-memory bytes one slot occupies (slab: full extent in the
        non-tiled dims, max footprint in the tiled dim).  ``exclude`` names
        datasets staged outside the slot pool (pinned: whole-array resident,
        accounted separately by the residency manager)."""
        total = 0
        td = self.chain.tiled_dim
        for name, ln in self.max_fp_len.items():
            if name in exclude:
                continue
            dat = self.chain.datasets[name]
            other = 1
            for d, s in enumerate(dat.padded_shape):
                if d != td:
                    other *= s
            total += ln * other * dat.dtype.itemsize
        return total


def _loop_tiled_range(lp, td: int) -> Tuple[int, int]:
    return lp.range_[td]


def make_tile_schedule(chain: ChainInfo, num_tiles: int,
                       skew: str = "perloop") -> TileSchedule:
    """Build the skewed schedule with ``num_tiles`` slabs along the tiled dim.

    ``skew``: "perloop" (default) accumulates per-loop read extents backwards
    — shift_k = shift_{k+1} + max(e_k, e_{k+1}) — which satisfies both RAW
    (increment_{j-1} >= e_j) and WAR (increment_i >= e_i) for every pair,
    and adds ZERO skew across runs of loops with no tiled-dim reads (y/z
    sweeps in 3-D chains).  "uniform" is the conservative (n-1-k)*sigma slope
    (kept for the EXPERIMENTS.md §Perf comparison).
    """
    td = chain.tiled_dim
    n = chain.num_loops
    sigma = chain.skew_slope

    g_lo = min(_loop_tiled_range(lp, td)[0] for lp in chain.loops)
    g_hi = max(_loop_tiled_range(lp, td)[1] for lp in chain.loops)
    span = g_hi - g_lo
    num_tiles = max(1, min(num_tiles, span))
    # Nominal boundaries (uniform; remainder spread over the first tiles).
    base = span // num_tiles
    rem = span % num_tiles
    boundaries = [g_lo]
    for t in range(num_tiles):
        boundaries.append(boundaries[-1] + base + (1 if t < rem else 0))

    # Per-loop sub-range ends per tile: end_k^t = min(hi_k, E_{t+1} + shift_k).
    if skew == "uniform" or not chain.loop_extents:
        shifts = [(n - 1 - k) * sigma for k in range(n)]
    else:
        e = chain.loop_extents
        shifts = [0] * n
        for k in range(n - 2, -1, -1):
            shifts[k] = shifts[k + 1] + max(e[k], e[k + 1])
    ends: List[List[int]] = []  # [tile][loop]
    for t in range(num_tiles):
        row = []
        for k, lp in enumerate(chain.loops):
            lo_k, hi_k = _loop_tiled_range(lp, td)
            if t == num_tiles - 1:
                row.append(hi_k)
            else:
                row.append(max(lo_k, min(hi_k, boundaries[t + 1] + shifts[k])))
        ends.append(row)

    # Assemble tiles with footprints.
    raw_fps: List[Dict[str, Interval]] = []
    tiles: List[TilePlan] = []
    for t in range(num_tiles):
        loop_ranges: List[Optional[Tuple[Tuple[int, int], ...]]] = []
        fp: Dict[str, Interval] = {}
        for k, lp in enumerate(chain.loops):
            lo_k, _ = _loop_tiled_range(lp, td)
            start = lo_k if t == 0 else ends[t - 1][k]
            end = ends[t][k]
            if end <= start:
                loop_ranges.append(None)
                continue
            box = list(lp.range_)
            box[td] = (start, end)
            loop_ranges.append(tuple(box))
            for arg in lp.args:
                blo, bhi = arg.dat.bounds(td)
                if arg.mode.reads:
                    mn, mx = arg.stencil.extent(td)
                    iv = Interval(start + mn, end + mx).clamp(blo, bhi)
                else:
                    iv = Interval(start, end).clamp(blo, bhi)
                cur = fp.get(arg.dat.name, EMPTY)
                fp[arg.dat.name] = cur.union(iv)
        raw_fps.append(fp)
        tiles.append(
            TilePlan(
                index=t,
                loop_ranges=loop_ranges,
                footprint=fp,
                upload={},
                download={},
                edge_to_next={},
            )
        )

    # Pass-through closure: a row written in tile t1 and read again in tile
    # t2 > t1 must stay slot-resident through every intermediate tile (edge
    # copies are the only transport for write-first data).  Close each dat's
    # footprint sequence so f'(t) ⊇ f(t) ∪ (hull_past(t) ∩ hull_future(t));
    # this restores interval-monotone coverage even when early loops finish
    # the grid inside one tile (non-monotone raw footprints).
    all_names = sorted({n for fp in raw_fps for n in fp})
    for name in all_names:
        seq = [fp.get(name, EMPTY) for fp in raw_fps]
        # prefix hulls
        pre: List[Interval] = []
        cur = EMPTY
        for f in seq:
            cur = cur.union(f)
            pre.append(cur)
        suf: List[Interval] = [EMPTY] * len(seq)
        cur = EMPTY
        for i in range(len(seq) - 1, -1, -1):
            cur = cur.union(seq[i])
            suf[i] = cur
        for t, f in enumerate(seq):
            passthrough = pre[t].intersect(suf[t + 1]) if t + 1 < len(seq) else EMPTY
            closed = f.union(passthrough) if not passthrough.empty else f
            if not closed.empty:
                raw_fps[t][name] = closed
                tiles[t].footprint[name] = closed

    # Footprint set algebra → upload / download / edge regions.
    for t, tile in enumerate(tiles):
        prev_fp = raw_fps[t - 1] if t > 0 else {}
        next_fp = raw_fps[t + 1] if t + 1 < num_tiles else {}
        for name, f in tile.footprint.items():
            if f.empty:
                continue
            pf = prev_fp.get(name, EMPTY)
            nf = next_fp.get(name, EMPTY)
            # upload: F \ F_prev — the overlap arrives via the edge copy.
            tile.upload[name] = f.difference(pf)
            # download: F \ F_next, clipped to rows the chain actually writes
            # (beyond-paper precision: never ship unwritten rows home — and
            # never clobber home with slot rows the chain only read).
            written = chain.written.get(name, [])
            pieces = []
            for piece in f.difference(nf):
                for wlo, whi in written:
                    clipped = piece.clamp(wlo, whi)
                    if not clipped.empty:
                        pieces.append(clipped)
            tile.download[name] = tuple(pieces)
            # right edge: overlap with next tile (device-side copy).
            tile.edge_to_next[name] = f.intersect(nf) if not nf.empty else EMPTY

    max_fp_len = {}
    for fp in raw_fps:
        for name, iv in fp.items():
            max_fp_len[name] = max(max_fp_len.get(name, 0), iv.length)

    return TileSchedule(chain=chain, tiles=tiles, boundaries=boundaries, max_fp_len=max_fp_len)


def choose_num_tiles(
    chain: ChainInfo,
    capacity_bytes: int,
    num_slots: int = 3,
    max_tiles: int = 4096,
) -> int:
    """Smallest tile count whose slots fit ``capacity_bytes`` of fast memory.

    Mirrors the paper's 'tile sizes set according to the size of the stacked
    memory'.  Returns 1 if the whole problem fits (no out-of-core needed).
    """
    if num_slots * make_tile_schedule(chain, 1).slot_bytes() <= capacity_bytes:
        return 1
    lo, hi = 1, max_tiles
    # slot_bytes is monotonically non-increasing in num_tiles; binary search.
    while lo < hi:
        mid = (lo + hi) // 2
        sched = make_tile_schedule(chain, mid)
        if num_slots * sched.slot_bytes() <= capacity_bytes:
            hi = mid
        else:
            lo = mid + 1
    sched = make_tile_schedule(chain, lo)
    if num_slots * sched.slot_bytes() > capacity_bytes:
        raise MemoryError(
            f"chain cannot fit: even {lo} tiles need "
            f"{num_slots * sched.slot_bytes()} bytes > capacity {capacity_bytes} "
            f"(skew span too large or non-tiled extent too big)"
        )
    return lo
