"""Plan interpreters: one op stream, two execution modes.

:class:`LedgerInterpreter` walks a :class:`~repro.core.plan.Plan` and
produces the modelled timeline — ledger events with the exact three-stream
dependency wiring Algorithm 1 implies (upload FIFO, per-slot reuse fences,
compute chaining, download-after-compute), plus residency bookkeeping so the
dirty-row invariants are enforced even in pure simulation.  This is the
``sim`` backend's whole execution path, and what :meth:`Session.explain`
and the autotuner cost plans with.

:class:`DataPlaneInterpreter` subclasses it and additionally moves real
bytes: slot arrays, staging tasks on the
:class:`~repro.core.transfer.TransferEngine` (coalesced per tile/direction),
codec round-trips with achieved wire bytes patched into the ledger after
drain, edge copies, pinned-array residency, speculative-prefetch capture and
restore, and the compiled :class:`~repro.core.engine.TileEngine` tiles.

Both interpreters execute the *same* instruction stream — the executor's
old inline ``sim``/real branches are now one code path with data hooks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .memory import HardwareModel, TransferLedger
from .plan import (
    CarryEdge,
    Compute,
    Download,
    Elide,
    Evict,
    FetchHome,
    HaloExchange,
    HaloPack,
    HaloUnpack,
    PinUpload,
    Plan,
    Prefetch,
    SpillHome,
    Upload,
    WritebackPinned,
)
from .tiling import Interval
from .transfer import ResidencyManager, Slot
from .transfer.engine import DISK, DOWN, UP
from ..obs.audit import STREAM_NAMES
from ..obs.tracer import AnyTracer, NULL_TRACER


class _SimArray:
    """Placeholder device array for simulated pinned caching."""

    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int):
        self.nbytes = int(nbytes)


@dataclass
class SpecState:
    """Cross-chain speculative-prefetch state (owned by the executor).

    ``uploaded``: what the last chain prefetched ({name: (Interval, ...)});
    ``data``: on real data-plane runs, the captured device arrays backing
    those intervals; ``sig``: the plan signature hash the guess came from.
    A hit restores captured data instead of re-staging from home; any
    identity/version mismatch degrades to a miss, never to stale data."""

    uploaded: Dict[str, Tuple[Interval, ...]] = field(default_factory=dict)
    data: Dict[str, list] = field(default_factory=dict)
    sig: Optional[str] = None


@dataclass
class InterpResult:
    """What one interpreted chain produced (metrics + reductions)."""

    reductions: Dict[str, np.ndarray]
    makespan: float
    uploaded: int
    downloaded: int
    uploaded_wire: int
    downloaded_wire: int
    edge_bytes: int
    prefetch_hits: int
    ledger: TransferLedger
    # Disk tier (FetchHome/SpillHome): modelled raw bytes in sim mode; the
    # executor replaces them with the stores' achieved counters on real runs.
    disk_read: int = 0
    disk_written: int = 0
    # Device mesh (HaloExchange): messages/bytes this device's exchange
    # received, straight from the plan annotations — the sharded executor
    # checks these against the runtime's achieved HaloExchangeStats.
    halo_messages: int = 0
    halo_bytes: int = 0


class LedgerInterpreter:
    """Cost a plan: ledger events + residency bookkeeping, no data plane.

    ``rm``/``spec`` default to throwaway instances (offline plan analysis);
    the executor passes its own so pinned caching and prefetch guessing work
    across chains exactly as on the data plane.  ``datasets`` (optional)
    enables pinned cache lookups keyed by dataset identity/version."""

    def __init__(self, plan: Plan, hw: HardwareModel,
                 rm: Optional[ResidencyManager] = None,
                 spec: Optional[SpecState] = None,
                 datasets: Optional[Dict[str, Any]] = None,
                 tracer: Optional[AnyTracer] = None,
                 trace_tag: str = "",
                 chain_index: int = 0):
        self.plan = plan
        self.hw = hw
        self.tracer: AnyTracer = tracer if tracer is not None else NULL_TRACER
        self.trace_tag = trace_tag
        self.chain_index = chain_index
        self.eid_op: Dict[int, int] = {}   # ledger eid -> plan op index (#N)
        self.rm = rm if rm is not None else ResidencyManager(
            capacity_bytes=float("inf"), num_slots=plan.num_slots)
        self.spec = spec if spec is not None else SpecState()
        self.datasets = datasets or {}
        self.ledger = TransferLedger(hw)
        self.row_bytes = dict(plan.row_bytes)
        self.ratios = dict(plan.codec_ratios)
        self.origins: List[Dict[str, int]] = [dict(o) for o in plan.tile_origins]
        # metrics
        self.uploaded = self.downloaded = 0
        self.uploaded_wire = self.downloaded_wire = 0
        self.edge_bytes = 0
        self.prefetch_hits = 0
        self.disk_read = self.disk_written = 0
        self.halo_messages = self.halo_bytes = 0
        self.reductions: Dict[str, np.ndarray] = {}
        # event-id cursors (the four-stream dependency wiring)
        self.last_upload_eid: Optional[int] = None
        self.last_compute_eid: Optional[int] = None
        self.last_download_eid: Dict[int, Optional[int]] = {}
        self.tile_up_eid: Dict[int, int] = {}
        self.compute_eids: Dict[int, int] = {}
        self.tile_slot: Dict[int, Any] = {}
        self.fetch_eids: Dict[int, int] = {}       # tile -> FetchHome event
        self.tile_down_eid: Dict[int, int] = {}    # tile -> Download event
        self._halo_pack_eid: Optional[int] = None
        self._halo_exchange_eid: Optional[int] = None

    # -- byte math over plan annotations --------------------------------------
    def _nbytes(self, name: str, lo: int, hi: int) -> int:
        return max(0, hi - lo) * self.row_bytes[name]

    def _wire(self, name: str, nb: int) -> int:
        return max(1, int(nb / self.ratios[name])) if nb else 0

    # -- driver ---------------------------------------------------------------
    _DISPATCH = {
        PinUpload.kind: "op_pin_upload",
        Upload.kind: "op_upload",
        Compute.kind: "op_compute",
        CarryEdge.kind: "op_carry",
        Elide.kind: "op_elide",
        Download.kind: "op_download",
        Evict.kind: "op_evict",
        Prefetch.kind: "op_prefetch",
        WritebackPinned.kind: "op_pin_flush",
        FetchHome.kind: "op_fetch_home",
        SpillHome.kind: "op_spill_home",
        HaloPack.kind: "op_halo_pack",
        HaloExchange.kind: "op_halo_exchange",
        HaloUnpack.kind: "op_halo_unpack",
    }

    # Ops whose ledger events are serviced by staged TransferHandles — their
    # achieved timing is the handle's, emitted as lane spans after drain, so
    # the dispatch span must NOT claim their eids.  Everything else executes
    # inline on the issue thread and the dispatch span is the achieved record.
    _HANDLE_KINDS = frozenset(
        (Upload.kind, Download.kind, FetchHome.kind, SpillHome.kind))

    # Sim mode replays the modelled timeline as spans (the drift-audit oracle
    # case); the data plane emits wall-clock spans instead.
    _trace_modelled = True

    def run(self) -> InterpResult:
        plan = self.plan
        self.spec_valid = (
            plan.prefetch
            and self.spec.sig is not None
            and self.spec.sig == plan.sig_hash
            and bool(self.spec.uploaded)
        )
        self.slots = self.rm.begin_chain(plan.num_slots)
        self.begin()
        if self.tracer.enabled:
            self._run_ops_traced(plan)
        else:
            for op in plan.ops:
                getattr(self, self._DISPATCH[op.kind])(op)
        self.finish()
        self.rm.end_chain()
        res = InterpResult(
            reductions=self.reductions,
            makespan=self.ledger.simulate(),
            uploaded=self.uploaded, downloaded=self.downloaded,
            uploaded_wire=self.uploaded_wire,
            downloaded_wire=self.downloaded_wire,
            edge_bytes=self.edge_bytes, prefetch_hits=self.prefetch_hits,
            ledger=self.ledger,
            disk_read=self.disk_read, disk_written=self.disk_written,
            halo_messages=self.halo_messages, halo_bytes=self.halo_bytes,
        )
        if self.tracer.enabled and self._trace_modelled:
            self._emit_modelled_spans()
        return res

    def _run_ops_traced(self, plan: Plan) -> None:
        """The dispatch loop with span emission: identical op semantics
        (bit-identity with the untraced loop), plus the eid -> op-index map
        both audit rows and modelled spans cite as ``#N``."""
        tr = self.tracer
        tag = self.trace_tag
        ci = self.chain_index
        wall = not self._trace_modelled
        events = self.ledger.events
        cur_tile: Optional[int] = None
        tile_t0 = 0.0
        for i, op in enumerate(plan.ops):
            tile = getattr(op, "tile", None)
            if wall and tile is not None and tile != cur_tile:
                now = tr.clock()
                if cur_tile is not None:
                    tr.emit(f"tile {cur_tile}", cat="tile",
                            track=tag + "tiles", t_start=tile_t0, t_end=now,
                            args={"chain": ci, "tile": cur_tile})
                cur_tile, tile_t0 = tile, now
            n0 = len(events)
            t0 = tr.clock()
            getattr(self, self._DISPATCH[op.kind])(op)
            t1 = tr.clock()
            n1 = len(events)
            for eid in range(n0, n1):
                self.eid_op[eid] = i
            if not wall:
                continue
            args: Dict[str, Any] = {"chain": ci, "op": i}
            if tile is not None:
                args["tile"] = tile
            if op.kind in self._HANDLE_KINDS or n1 == n0:
                track = tag + "dispatch"
            else:
                # Inline op: its dispatch IS the achieved timing for the
                # events it issued — land it on the stream's own track.
                args["eids"] = list(range(n0, n1))
                track = tag + STREAM_NAMES.get(
                    events[n0].stream, f"stream{events[n0].stream}")
            tr.emit(op.kind, cat="op", track=track,
                    t_start=t0, t_end=t1, args=args)
        if wall and cur_tile is not None:
            tr.emit(f"tile {cur_tile}", cat="tile", track=tag + "tiles",
                    t_start=tile_t0, t_end=tr.clock(),
                    args={"chain": ci, "tile": cur_tile})

    def _emit_modelled_spans(self) -> None:
        """Sim mode: replay the simulated ledger timeline as spans — one per
        event at its modelled ``t_start``/``t_end``.  Auditing these against
        the very same ledger must report per-stream drift of exactly 1.0."""
        tr = self.tracer
        tag = self.trace_tag
        ci = self.chain_index
        for ev in self.ledger.events:
            tr.emit(ev.kind, cat="model",
                    track=tag + STREAM_NAMES.get(ev.stream,
                                                 f"stream{ev.stream}"),
                    t_start=ev.t_start, t_end=ev.t_end,
                    args={"chain": ci, "eid": ev.eid,
                          "op": self.eid_op.get(ev.eid, -1),
                          "stream": ev.stream, "bytes": ev.nbytes})

    # -- lifecycle hooks (data plane overrides) -------------------------------
    def begin(self) -> None:
        pass

    def finish(self) -> None:
        pass

    # -- pinned residency -----------------------------------------------------
    def op_pin_upload(self, op: PinUpload) -> None:
        raw = wire = 0
        for name, nb in op.entries:
            r, w = self.pin_ensure(name, nb)
            raw += r
            wire += w
        self.uploaded += raw
        self.uploaded_wire += wire
        if wire:
            deps = ((self.last_upload_eid,)
                    if self.last_upload_eid is not None else ())
            self.last_upload_eid = self.ledger.add(
                1, "upload", wire, self.ledger.t_up(wire), deps)

    def pin_ensure(self, name: str, nb: int) -> Tuple[int, int]:
        """Make ``name`` device-resident; returns (raw, wire) actually moved
        (0, 0 on a cross-chain pinned-cache hit)."""
        dat = self.datasets.get(name)
        if dat is None:   # offline analysis: assume cold
            return nb, self._wire(name, nb)
        hit = self.rm.pinned_lookup(dat)
        if hit is not None:
            return 0, 0
        origin = -dat.halo[self.plan.tiled_dim][0]
        self.rm.pinned_store(dat, _SimArray(dat.nbytes), origin)
        return nb, self._wire(name, nb)

    # -- the disk tier (tiered host storage) ----------------------------------
    def op_fetch_home(self, op: FetchHome) -> None:
        """Disk -> host fetch of tile ``op.tile``'s staging rows: stream-3
        FIFO (positional), no cross-stream deps — the upload that *reads*
        these rows carries the dependency instead."""
        self.disk_read += op.raw
        eid = self.stage_fetch_home(op)
        if eid is not None:
            self.fetch_eids[op.tile] = eid

    def stage_fetch_home(self, op: FetchHome) -> Optional[int]:
        return self.ledger.add(3, "fetch_home", op.raw,
                               self.ledger.t_disk(op.raw), ())

    def op_spill_home(self, op: SpillHome) -> None:
        """Host -> disk retirement: waits for tile ``op.tile``'s download to
        land the rows home, then pushes them out on stream 3."""
        deps = ()
        if self.tile_down_eid.get(op.tile) is not None:
            deps = (self.tile_down_eid[op.tile],)
        self.disk_written += op.raw
        self.stage_spill_home(op, deps)

    def stage_spill_home(self, op: SpillHome,
                         deps: Tuple[int, ...]) -> Optional[int]:
        return self.ledger.add(3, "spill_home", op.raw,
                               self.ledger.t_disk(op.raw), deps)

    # -- the network stream (device-mesh halo exchange) -----------------------
    def op_halo_pack(self, op: HaloPack) -> None:
        """Host-side copy of boundary rows into send buffers: stream 4,
        costed at slow-memory bandwidth."""
        self._halo_pack_eid = self.ledger.add(
            4, "halo_pack", op.nbytes,
            op.nbytes / self.hw.slow_bw if op.nbytes else 0.0, ())

    def op_halo_exchange(self, op: HaloExchange) -> None:
        """The §5.2 once-per-chain accumulated-depth exchange: network event
        after the pack; the data plane additionally runs the real collective
        via :meth:`exec_halo_exchange`."""
        deps = ((self._halo_pack_eid,)
                if self._halo_pack_eid is not None else ())
        self.halo_messages += op.messages
        self.halo_bytes += op.nbytes
        self.exec_halo_exchange(op)
        self._halo_exchange_eid = self.ledger.add(
            4, "halo_exchange", op.nbytes,
            self.ledger.t_net(op.nbytes, op.messages), deps)

    def exec_halo_exchange(self, op: HaloExchange) -> None:
        pass

    def op_halo_unpack(self, op: HaloUnpack) -> None:
        """Received rows land in the home skirt.  The unpack event becomes
        the upload stream's FIFO head (``last_upload_eid``), so the chain's
        first staged upload — which reads those home rows — waits for it."""
        deps = ((self._halo_exchange_eid,)
                if self._halo_exchange_eid is not None else ())
        eid = self.ledger.add(
            4, "halo_unpack", op.nbytes,
            op.nbytes / self.hw.slow_bw if op.nbytes else 0.0, deps)
        self.last_upload_eid = eid

    # -- staging --------------------------------------------------------------
    def spec_lookup(self, name: str,
                    iv: Interval) -> Tuple[Interval, Optional[Any]]:
        """Resolve a speculative-prefetch hit for upload piece ``iv``:
        returns ``(miss_part, restore)`` — the sub-interval still needing a
        home upload, and the restore token (always None without a data
        plane: a modelled hit simply skips the traffic)."""
        for piv in self.spec.uploaded.get(name, ()):
            hit = iv.intersect(piv)
            if hit.empty or hit.lo != iv.lo:
                continue
            self.prefetch_hits += 1
            return Interval(hit.hi, iv.hi), None
        return iv, None

    def op_upload(self, op: Upload) -> None:
        slot = self.rm.acquire()
        org = self.origins[op.tile]
        slot.origins = org
        self.tile_slot[op.tile] = slot
        items: List[Tuple[str, Interval]] = []
        restores: List[Tuple] = []
        raw = 0
        for name, lo, hi in op.items:
            iv = Interval(lo, hi)
            if self.spec_valid and op.tile == 0:
                iv, restore = self.spec_lookup(name, iv)
                if restore is not None:
                    restores.append(restore)
            if iv.empty:
                continue
            raw += self._nbytes(name, iv.lo, iv.hi)
            items.append((name, iv))
        if not raw and not restores:
            return
        up_deps: List[int] = []
        if self.last_download_eid.get(slot.index) is not None:
            up_deps.append(self.last_download_eid[slot.index])  # reuse fence
        if self.last_upload_eid is not None:
            up_deps.append(self.last_upload_eid)                # stream-1 FIFO
        if self.fetch_eids.get(op.tile) is not None:
            up_deps.append(self.fetch_eids[op.tile])  # rows must be in RAM
        eid = self.stage_upload(op, slot, org, items, restores, raw,
                                tuple(up_deps))
        if eid is not None:
            self.tile_up_eid[op.tile] = eid
            self.last_upload_eid = eid

    def stage_upload(self, op: Upload, slot: Slot, org: Dict[str, int],
                     items: List[Tuple[str, Interval]],
                     restores: List[Tuple],
                     raw: int, deps: Tuple[int, ...]) -> Optional[int]:
        self.uploaded += raw
        wire = sum(self._wire(name, self._nbytes(name, iv.lo, iv.hi))
                   for name, iv in items)
        self.uploaded_wire += wire
        return self.ledger.add(1, "upload", wire, self.ledger.t_up(wire), deps)

    # -- compute --------------------------------------------------------------
    def op_compute(self, op: Compute) -> None:
        slot = self.tile_slot[op.tile]
        deps: List[int] = []
        if self.tile_up_eid.get(op.tile) is not None:
            deps.append(self.tile_up_eid[op.tile])
        if self.last_compute_eid is not None:
            deps.append(self.last_compute_eid)
        self.execute_tile(op, slot)
        eid = self.ledger.add(
            0, "compute", op.nbytes,
            self.ledger.t_compute(op.nbytes, op.flops), tuple(deps))
        self.last_compute_eid = eid
        self.compute_eids[op.tile] = eid
        # Residency bookkeeping: rows this tile wrote stay dirty until a
        # download, an edge carry, or a §4.1 elision retires them.
        for name, rows in op.writes:
            for lo, hi in rows:
                self.rm.mark_dirty(slot, name, lo, hi)

    def execute_tile(self, op: Compute, slot: Slot) -> None:
        pass

    # -- edge carry -----------------------------------------------------------
    def op_carry(self, op: CarryEdge) -> None:
        slot = self.tile_slot[op.tile]
        dst = self.tile_slot.get(op.tile + 1)
        if dst is None:     # 1-slot pool: the next tile continues in-place
            dst = slot
        next_org = self.origins[op.tile + 1]
        deps: List[int] = [self.last_compute_eid]
        if self.last_download_eid.get(dst.index) is not None:
            deps.append(self.last_download_eid[dst.index])
        self.copy_edges(op, slot, dst, next_org)
        for name, lo, hi in op.items:
            self.rm.carry(slot, dst, name, lo, hi)
        self.edge_bytes += op.nbytes
        self.last_compute_eid = self.ledger.add(
            0, "edge", op.nbytes, self.ledger.t_dd(2 * op.nbytes), tuple(deps))

    def copy_edges(self, op: CarryEdge, slot: Slot, dst: Slot,
                   next_org: Dict[str, int]) -> None:
        pass

    # -- retire ---------------------------------------------------------------
    def op_elide(self, op: Elide) -> None:
        slot = self.tile_slot[op.tile]
        for name, lo, hi in op.items:
            self.rm.elide(slot, name, lo, hi)

    def op_download(self, op: Download) -> None:
        slot = self.tile_slot[op.tile]
        deps = (self.compute_eids[op.tile],)
        self.downloaded += op.raw
        eid = self.stage_download(op, slot, deps)
        self.last_download_eid[slot.index] = eid
        self.tile_down_eid[op.tile] = eid

    def stage_download(self, op: Download, slot: Slot,
                       deps: Tuple[int, ...]) -> int:
        wire = sum(self._wire(name, self._nbytes(name, lo, hi))
                   for name, lo, hi in op.items)
        self.downloaded_wire += wire
        eid = self.ledger.add(2, "download", wire, self.ledger.t_down(wire),
                              deps)
        for name, lo, hi in op.items:
            self.rm.writeback(slot, name, lo, hi)
        return eid

    def op_evict(self, op: Evict) -> None:
        # The acquire in op_upload performs (and counts) the eviction; the op
        # exists so plan-level counts match residency statistics.
        pass

    # -- speculative prefetch -------------------------------------------------
    def op_prefetch(self, op: Prefetch) -> None:
        self.spec.uploaded = {
            name: tuple(Interval(lo, hi) for lo, hi in rows)
            for name, rows in op.items
        }
        self.spec.data = {}
        if op.wire:
            deps = ((self.last_upload_eid,)
                    if self.last_upload_eid is not None else ())
            self.ledger.add(1, "prefetch", op.wire,
                            self.ledger.t_up(op.wire), deps)
        self.spec.sig = self.plan.sig_hash
        self._prefetch_armed = True

    # -- pinned flush ---------------------------------------------------------
    def op_pin_flush(self, op: WritebackPinned) -> None:
        raw = wire = 0
        for name, rows, nb, w in op.entries:
            r2, w2 = self.flush_pinned(name, rows, nb, w)
            raw += r2
            wire += w2
            dat = self.datasets.get(name)
            if dat is not None:
                self.rm.pinned_mark_flushed(dat)
        if wire:
            self.downloaded += raw
            self.downloaded_wire += wire
            deps = ((self.last_compute_eid,)
                    if self.last_compute_eid is not None else ())
            self.ledger.add(2, "download", wire, self.ledger.t_down(wire), deps)

    def flush_pinned(self, name: str, rows: Tuple[Tuple[int, int], ...],
                     nb: int, wire: int) -> Tuple[int, int]:
        return nb, wire


def simulate_plan(plan: Plan, hw: HardwareModel) -> InterpResult:
    """Cost one plan on ``hw`` with cold caches (fresh residency/prefetch
    state) — what :meth:`Session.explain` and the autotuner report."""
    return LedgerInterpreter(plan, hw).run()


def predict_plans(plans: Sequence[Plan], hw: HardwareModel) -> Tuple[float, int]:
    """Admission-oracle prediction over one chain's (possibly split) plans:
    the summed cold-cache modelled makespan and the peak fast-memory
    footprint — slot pool plus pinned residency — any single plan claims
    while it runs.  Plans in a split chain execute back-to-back on one
    device, so footprints max (never sum) across them."""
    makespan = 0.0
    peak = 0
    for p in plans:
        makespan += simulate_plan(p, hw).makespan
        peak = max(peak, p.slot_bytes * p.num_slots + p.pinned_bytes)
    return makespan, peak


# -- the real data plane -----------------------------------------------------------


class DataPlaneInterpreter(LedgerInterpreter):
    """Execute a plan for real: slot arrays, transfer-engine staging tasks,
    codec round-trips, compiled tiles, pinned arrays and prefetch capture.

    ``cp`` is the executor's memoised :class:`~repro.core.executor.ChainPlan`
    (analysis, schedule, engine); ``tx`` the transfer engine; ``codecs`` the
    resolved per-dataset codec map.  Ledger transfer events are recorded with
    raw sizes at submission (dependency wiring needs ids in submission order)
    and patched with achieved post-codec wire bytes after the engine drains.
    """

    # Wall-clock spans (dispatch + lane); the ledger keeps the model.
    _trace_modelled = False

    def __init__(self, plan: Plan, hw: HardwareModel, *,
                 rm: ResidencyManager, spec: SpecState, cp: Any,
                 tx: Any, codecs: Dict[str, Any],
                 halo_runtime: Optional[Callable[[HaloExchange], None]]
                 = None,
                 tracer: Optional[AnyTracer] = None,
                 trace_tag: str = "",
                 chain_index: int = 0):
        super().__init__(plan, hw, rm=rm, spec=spec,
                         datasets=cp.info.datasets,
                         tracer=tracer, trace_tag=trace_tag,
                         chain_index=chain_index)
        # Collective halo-exchange hook (sharded execution): the mesh-owning
        # executor supplies a callable that moves the real rows (host copies
        # on a virtual mesh, exchange_halos/ppermute under shard_map on a
        # real one) exactly once per exchange epoch across all devices.
        self.halo_runtime = halo_runtime
        self.cp = cp
        self.info = cp.info
        self.sched = cp.sched
        self.engine = cp.engine
        self.tx = tx
        self.codecs = codecs
        self.td = plan.tiled_dim
        self.patches: List[Tuple[int, Any, str]] = []
        self.up_handles: Dict[int, Any] = {}
        self.fetch_handles: Dict[int, Any] = {}   # tile -> disk-fetch handle
        self.down_handles: Dict[int, Any] = {}    # tile -> download handle
        self.pinned_arrays: Dict[str, Any] = {}
        self.pinned_origins: Dict[str, int] = {}
        self.red_specs = {r.name: r for lp in cp.info.loops
                          for r in lp.reductions}
        self._prefetch_armed = False

    # -- home region helpers (store-routed: ram, mmap and chunked homes) -----
    def _dat_np_region(self, dat: Any, iv: Interval) -> np.ndarray:
        return dat.read_rows(self.td, iv.lo, iv.hi)

    def _write_np_region(self, dat: Any, iv: Interval,
                         values: np.ndarray) -> None:
        dat.write_rows(self.td, iv.lo, iv.hi, values)

    @staticmethod
    def _slot_slice(arr: Any, lo: int, hi: int,
                    td: int) -> Tuple[slice, ...]:
        idx = [slice(None)] * arr.ndim
        idx[td] = slice(lo, hi)
        return tuple(idx)

    # -- lifecycle ------------------------------------------------------------
    def begin(self) -> None:
        import jax.numpy as jnp

        td = self.td
        pinned = {n for n, _ in
                  (e for op in self.plan.ops if isinstance(op, PinUpload)
                   for e in op.entries)}
        for slot in self.slots:
            arrays = {}
            for name, ln in self.sched.max_fp_len.items():
                if name in pinned:
                    continue
                dat = self.info.datasets[name]
                shape = list(dat.padded_shape)
                shape[td] = ln
                arrays[name] = jnp.zeros(tuple(shape), dtype=dat.dtype)
            slot.arrays = arrays

    def finish(self) -> None:
        import jax.numpy as jnp

        self.tx.drain()
        # Patch transfer events with the achieved wire bytes (codec output is
        # data-dependent, so threaded tasks only report it after the fact).
        # ``ledger.totals`` accumulated the raw estimate at submission and
        # must shift by the same delta to stay consistent with the events.
        ledger = self.ledger
        for eid, handle, direction in self.patches:
            _, wire = handle.result
            ev = ledger.events[eid]
            ledger.totals[ev.kind] = (
                ledger.totals.get(ev.kind, 0) + wire - ev.nbytes)
            ev.nbytes = wire
            if direction == UP:
                ev.duration = ledger.t_up(wire)
                self.uploaded_wire += wire
            elif direction == DOWN:
                ev.duration = ledger.t_down(wire)
                self.downloaded_wire += wire
            else:   # DISK: achieved payload bytes (chunk-cache hits cost 0)
                ev.duration = ledger.t_disk(wire)
        tr = self.tracer
        if tr.enabled and self.patches:
            # Lane spans: the handles' own worker timestamps, one span per
            # staged ledger event — the achieved side of the drift audit for
            # the upload/download/disk streams.
            lane_track = {UP: "upload", DOWN: "download", DISK: "disk"}
            tag = self.trace_tag
            ci = self.chain_index
            for eid, handle, direction in self.patches:
                ev = ledger.events[eid]
                tr.emit(ev.kind, cat="lane",
                        track=tag + lane_track[direction],
                        t_start=handle.t_start, t_end=handle.t_end,
                        args={"chain": ci, "eid": eid,
                              "op": self.eid_op.get(eid, -1),
                              "queue_wait_s": handle.queue_wait_s,
                              "bytes": ev.nbytes})
        # Speculative-prefetch data capture: home is stable now that
        # downloads have drained, so snapshot the regions the next chain's
        # first tile is assumed to upload.  ``jnp.array`` copies — the
        # capture must not alias home rows a later chain will overwrite.
        if self._prefetch_armed:
            self.spec.data = {}
            for name, ivs in self.spec.uploaded.items():
                dat = self.info.datasets.get(name)
                if dat is None:
                    continue
                self.spec.data[name] = [
                    (iv, jnp.array(self._dat_np_region(dat, iv)), id(dat),
                     dat.version)
                    for iv in ivs]

    # -- pinned residency -----------------------------------------------------
    def pin_ensure(self, name: str, nb: int) -> Tuple[int, int]:
        import jax.numpy as jnp

        dat = self.info.datasets[name]
        origin = -dat.halo[self.td][0]
        hit = self.rm.pinned_lookup(dat)
        if hit is not None:
            arr, origin = hit
            self.pinned_arrays[name] = arr
            self.pinned_origins[name] = origin
            return 0, 0
        dec, raw, wire = self.codecs[name].roundtrip(dat.materialize())
        arr = jnp.asarray(np.asarray(dec, dtype=dat.dtype))
        self.rm.pinned_store(dat, arr, origin)
        self.pinned_arrays[name] = arr
        self.pinned_origins[name] = origin
        return raw, wire

    # -- the network stream (real halo exchange) ------------------------------
    def exec_halo_exchange(self, op: HaloExchange) -> None:
        if self.halo_runtime is not None:
            self.halo_runtime(op)

    # -- the disk tier (real store traffic on the third worker lane) ----------
    def stage_fetch_home(self, op: FetchHome) -> Optional[int]:
        """Disk -> host fetch of tile ``op.tile``'s rows on the DISK lane:
        decompresses the backing store's chunks into its cache (a no-op for
        RAM-resident stores) so the upload worker's staging read is a pure
        RAM hit.  The upload waits on this handle, not the other way round."""
        td = self.td
        datasets = self.info.datasets
        items = [(datasets[name], Interval(lo, hi))
                 for name, lo, hi in op.items]

        def task() -> Tuple[int, int]:
            read = 0
            for dat, iv in items:
                read += dat.prefetch_rows(td, iv.lo, iv.hi)
            return op.raw, read

        handle = self.tx.submit(DISK, task)
        self.fetch_handles[op.tile] = handle
        eid = self.ledger.add(3, "fetch_home", op.raw,
                              self.ledger.t_disk(op.raw), ())
        self.patches.append((eid, handle, DISK))
        return eid

    def stage_spill_home(self, op: SpillHome,
                         deps: Tuple[int, ...]) -> Optional[int]:
        """Host -> disk retirement on the DISK lane, gated on the download
        task that lands the rows home (handle dep, mirroring the ledger
        event's dep on the download event)."""
        td = self.td
        datasets = self.info.datasets
        items = [(datasets[name], Interval(lo, hi))
                 for name, lo, hi in op.items]
        dh = self.down_handles.get(op.tile)

        def task() -> Tuple[int, int]:
            written = 0
            for dat, iv in items:
                written += dat.spill_rows(td, iv.lo, iv.hi)
            return op.raw, written

        handle = self.tx.submit(DISK, task, deps=[dh] if dh is not None else [])
        eid = self.ledger.add(3, "spill_home", op.raw,
                              self.ledger.t_disk(op.raw), deps)
        self.patches.append((eid, handle, DISK))
        return eid

    # -- staging --------------------------------------------------------------
    def spec_lookup(self, name: str,
                    iv: Interval) -> Tuple[Interval, Optional[Any]]:
        """Data-plane prefetch resolution: a hit must be backed by a captured
        device array whose dataset identity/version still matches home —
        otherwise it degrades to a full miss (stage everything), never to
        stale data."""
        pre = self.spec.uploaded.get(name, ())
        for j, piv in enumerate(pre):
            hit = iv.intersect(piv)
            if hit.empty or hit.lo != iv.lo:
                continue
            ents = self.spec.data.get(name, ())
            ent = ents[j] if j < len(ents) else None
            dat = self.info.datasets[name]
            if (ent is not None and ent[0] == piv and ent[2] == id(dat)
                    and ent[3] == dat.version):
                self.prefetch_hits += 1
                return Interval(hit.hi, iv.hi), (name, hit, ent[1], piv.lo)
            return iv, None  # stale capture: stage everything from home
        return iv, None

    def _make_upload_task(self, slot: Slot, org: Dict[str, int],
                          items: List[Tuple[str, Interval]],
                          restores: List[Tuple]
                          ) -> Callable[[], Tuple[int, int]]:
        import jax.numpy as jnp

        td = self.td
        info = self.info
        codecs = self.codecs
        slot_slice = self._slot_slice
        dat_np_region = self._dat_np_region

        def task() -> Tuple[int, int]:
            raw = wire = 0
            # Prefetch restores: device-resident captures from the last
            # chain's speculative upload — no link traffic (it was charged
            # as the prefetch event back then).
            for name, hit, arr, arr_lo in restores:
                vals = arr[slot_slice(arr, hit.lo - arr_lo, hit.hi - arr_lo,
                                      td)]
                lo, hi = hit.lo - org[name], hit.hi - org[name]
                with slot.lock:
                    dst = slot.arrays[name]
                    slot.arrays[name] = dst.at[
                        slot_slice(dst, lo, hi, td)].set(vals)
            for name, use in items:
                dat = info.datasets[name]
                chunk = dat_np_region(dat, use)
                dec, r, w = codecs[name].roundtrip(chunk)
                raw += r
                wire += w
                vals = jnp.asarray(np.asarray(dec, dtype=dat.dtype))
                lo, hi = use.lo - org[name], use.hi - org[name]
                # Disjoint-region updates commute, but the functional
                # read-modify-write of the slot's dict entry must be atomic
                # against the main thread's edge copy.
                with slot.lock:
                    arr = slot.arrays[name]
                    slot.arrays[name] = arr.at[
                        slot_slice(arr, lo, hi, td)].set(vals)
            return raw, wire

        return task

    def stage_upload(self, op: Upload, slot: Slot, org: Dict[str, int],
                     items: List[Tuple[str, Interval]],
                     restores: List[Tuple],
                     raw: int, deps: Tuple[int, ...]) -> Optional[int]:
        # Home rows a still-pending download is writing back must land
        # before this staging read (cross-tile safety net; the footprint
        # algebra keeps these disjoint in practice).
        conflicts = [
            h for name, iv in items
            for h in self.rm.home_conflicts(name, iv.lo, iv.hi)]
        fh = self.fetch_handles.get(op.tile)
        if fh is not None:      # disk tier: rows must be host-resident first
            conflicts.append(fh)
        handle = self.tx.submit(
            UP, self._make_upload_task(slot, org, items, restores),
            deps=conflicts)
        self.up_handles[op.tile] = handle
        for name, iv in items:
            self.rm.note_home_read(name, iv.lo, iv.hi, handle)
        if not raw:
            # Pure prefetch restore: device-side only, no link event (the
            # traffic was charged as last chain's prefetch).
            return None
        self.uploaded += raw
        eid = self.ledger.add(1, "upload", raw, self.ledger.t_up(raw), deps)
        self.patches.append((eid, handle, UP))
        return eid

    # -- compute --------------------------------------------------------------
    def execute_tile(self, op: Compute, slot: Slot) -> None:
        handle = self.up_handles.get(op.tile)
        if handle is not None:
            handle.wait()   # tile's staging must have landed
        tile = self.sched.tiles[op.tile]
        run_arrays = {**slot.arrays, **self.pinned_arrays}
        run_origins = {**self.origins[op.tile], **self.pinned_origins}
        new_arrays, tile_reds = self.engine.run_tile(tile, run_arrays,
                                                     run_origins)
        for name in self.pinned_arrays:
            self.pinned_arrays[name] = new_arrays[name]
            self.rm.pinned_update(self.info.datasets[name], new_arrays[name])
        slot.arrays = {n: a for n, a in new_arrays.items()
                       if n not in self.pinned_arrays}
        for name, val in tile_reds.items():
            spec = self.red_specs[name]
            if name in self.reductions:
                self.reductions[name] = np.asarray(
                    spec.combine(self.reductions[name], val))
            else:
                self.reductions[name] = np.asarray(val)

    # -- edge carry -----------------------------------------------------------
    def copy_edges(self, op: CarryEdge, slot: Slot, dst: Slot,
                   next_org: Dict[str, int]) -> None:
        td = self.td
        org = self.origins[op.tile]
        for name, lo, hi in op.items:
            src = slot.arrays[name]
            vals = src[self._slot_slice(src, lo - org[name], hi - org[name],
                                        td)]
            with dst.lock:
                darr = dst.arrays[name]
                dst.arrays[name] = darr.at[
                    self._slot_slice(darr, lo - next_org[name],
                                     hi - next_org[name], td)].set(vals)

    # -- download -------------------------------------------------------------
    def _make_download_task(self, arrays: Dict[str, Any],
                            org: Dict[str, int],
                            items: List[Tuple[str, Interval]]
                            ) -> Callable[[], Tuple[int, int]]:
        td = self.td
        info = self.info
        codecs = self.codecs
        slot_slice = self._slot_slice
        write_np_region = self._write_np_region

        def task() -> Tuple[int, int]:
            raw = wire = 0
            for name, iv in items:
                dat = info.datasets[name]
                lo, hi = iv.lo - org[name], iv.hi - org[name]
                arr = arrays[name]
                vals = np.asarray(arr[slot_slice(arr, lo, hi, td)])
                dec, r, w = codecs[name].roundtrip(vals)
                raw += r
                wire += w
                write_np_region(dat, iv, np.asarray(dec, dat.dtype))
            return raw, wire

        return task

    def stage_download(self, op: Download, slot: Slot,
                       deps: Tuple[int, ...]) -> int:
        org = self.origins[op.tile]
        items = [(name, Interval(lo, hi)) for name, lo, hi in op.items]
        # Snapshot the arrays: a later tile's upload functionally replaces
        # dict entries, never the captured values.  The home write must also
        # wait for earlier-queued uploads still reading overlapping home rows
        # (tile t+1's upload is submitted before tile t's download).
        read_deps = [
            h for name, iv in items
            for h in self.rm.home_read_conflicts(name, iv.lo, iv.hi)]
        handle = self.tx.submit(
            DOWN, self._make_download_task(dict(slot.arrays), org, items),
            deps=read_deps)
        self.down_handles[op.tile] = handle
        eid = self.ledger.add(2, "download", op.raw,
                              self.ledger.t_down(op.raw), deps)
        self.patches.append((eid, handle, DOWN))
        for name, iv in items:
            self.rm.writeback(slot, name, iv.lo, iv.hi, handle)
        return eid

    # -- pinned flush ---------------------------------------------------------
    def flush_pinned(self, name: str, rows: Tuple[Tuple[int, int], ...],
                     nb: int, wire: int) -> Tuple[int, int]:
        dat = self.info.datasets[name]
        arr = self.pinned_arrays[name]
        origin = self.pinned_origins[name]
        raw_tot = wire_tot = 0
        for lo, hi in rows:
            vals = np.asarray(arr[self._slot_slice(
                arr, lo - origin, hi - origin, self.td)])
            dec, r, w = self.codecs[name].roundtrip(vals)
            raw_tot += r
            wire_tot += w
            self._write_np_region(dat, Interval(lo, hi),
                                  np.asarray(dec, dat.dtype))
        return raw_tot, wire_tot
