"""Distributed (multi-device) stencil execution: halo exchange per chain.

The paper (§5.2) notes tiling's second benefit: instead of exchanging halos
per-loop, OPS computes the accumulated halo depth of the whole loop chain and
exchanges once per chain — fewer, larger messages.  This module implements
both policies on a device mesh with ``shard_map`` + ``collective_permute``
so the trade-off is measurable and the schedule is visible in dry-run HLO.

Grids are decomposed along one axis (default: the *non*-tiled dim 1, so
out-of-core slab tiling along dim 0 composes with MPI-style decomposition
along dim 1, mirroring the paper's 4-process KNL runs).

The chain's accumulated halo depth for left-to-right execution is
``n_loops × σ`` per neighbour side (σ = max stencil extent): loop k may read
σ cells beyond what loop k-1 wrote, so a chain of n loops consumes up to n·σ
remote cells before requiring fresh data.  After the exchange, every rank
runs the whole chain redundantly on its extended region (halo-deep compute),
which is exactly the "compute tiles that do not depend on halo data first"
follow-up the paper sketches in its conclusion, minus the overlap.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import axis_size, shard_map
from .dependency import analyze_chain
from .loop import ParallelLoop


@dataclass
class HaloExchangeStats:
    messages: int = 0
    bytes: int = 0


def exchange_halos(arrays: Dict[str, jax.Array], depth: int, axis_name: str,
                   dim: int = 1, periodic: bool = False) -> Dict[str, jax.Array]:
    """One bidirectional halo exchange of ``depth`` cells along ``dim``.

    ``arrays`` are the per-device local shards *including* halo padding of at
    least ``depth`` on each side of ``dim``.  Neighbour interiors are pushed
    into our halo slots with two ``ppermute`` rings (up and down).

    Boundary semantics: by default the grid is NOT periodic — the edge ranks
    (first and last along the mesh axis) keep their outer halo slots
    *unchanged*, so whatever physical boundary data the caller placed there
    (mirrored cells, global-halo rows) survives the exchange.  The previous
    behaviour wrapped the ``ppermute`` ring around, silently handing edge
    ranks the opposite edge's interior even for non-periodic grids; pass
    ``periodic=True`` to request that wrap explicitly.

    Depth 0 is a fast path: a chain with no reads along ``dim`` (pointwise
    chains, sweeps along other axes) needs no neighbour data at all, so the
    collectives are skipped entirely — no ``ppermute``, no axis context
    required.
    """
    if depth <= 0:
        return dict(arrays)
    n = axis_size(axis_name)
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
    else:
        # Open chain: the wrap pairs are dropped, so the edge ranks receive
        # zeros from ppermute — masked back to their original halo below.
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
    rank = lax.axis_index(axis_name)
    out = {}
    for name, arr in arrays.items():
        size = arr.shape[dim]

        def take(lo, hi):
            sl = [slice(None)] * arr.ndim
            sl[dim] = slice(lo, hi)
            return arr[tuple(sl)]

        # our top interior -> neighbour's bottom halo, and vice versa
        send_up = take(size - 2 * depth, size - depth)
        send_dn = take(depth, 2 * depth)
        recv_dn = lax.ppermute(send_up, axis_name, fwd)   # from rank-1
        recv_up = lax.ppermute(send_dn, axis_name, bwd)   # from rank+1
        lo_sl = [slice(None)] * arr.ndim
        lo_sl[dim] = slice(0, depth)
        hi_sl = [slice(None)] * arr.ndim
        hi_sl[dim] = slice(size - depth, size)
        if not periodic:
            # Edge ranks: no neighbour on that side — keep the existing halo.
            recv_dn = jnp.where(rank == 0, arr[tuple(lo_sl)], recv_dn)
            recv_up = jnp.where(rank == n - 1, arr[tuple(hi_sl)], recv_up)
        arr = arr.at[tuple(lo_sl)].set(recv_dn)
        arr = arr.at[tuple(hi_sl)].set(recv_up)
        out[name] = arr
    return out


def exchange_message_count(n_ranks: int, n_arrays: int = 1,
                           periodic: bool = False) -> int:
    """Messages one halo exchange sends: 2 directions per neighbour pair per
    array — ``2·n`` pairs on a periodic ring, ``2·(n-1)`` on an open chain."""
    if n_ranks <= 1:
        return 0
    pairs = n_ranks if periodic else n_ranks - 1
    return 2 * pairs * n_arrays


def chain_message_count(n_ranks: int, n_arrays: int, n_loops: int = 1,
                        per_loop: bool = False, periodic: bool = False) -> int:
    """Total messages a chain moves under either exchange policy: the tiled
    policy exchanges once per chain (deep); the untiled policy exchanges
    before every loop (``n_loops`` shallow exchanges) — the §5.2 trade-off."""
    exchanges = n_loops if per_loop else 1
    return exchanges * exchange_message_count(n_ranks, n_arrays, periodic)


def chain_halo_depth(loops: Sequence[ParallelLoop], dim: int = 1) -> int:
    """Accumulated halo depth a whole chain needs along ``dim``."""
    sigma = 0
    for lp in loops:
        for arg in lp.args:
            if arg.mode.reads:
                sigma = max(sigma, arg.stencil.max_abs_extent(dim))
    return sigma * len(loops)


def make_sharded_chain_step(
    chain_fn: Callable[[Dict[str, jax.Array]], Dict[str, jax.Array]],
    mesh: Mesh,
    axis_name: str,
    depth: int,
    per_loop: bool = False,
    loop_fns: Sequence[Callable] = (),
    per_loop_depth: int = 1,
    dim: int = 1,
    periodic: bool = False,
):
    """Build a jitted sharded step: halo exchange(s) + local chain execution.

    ``per_loop=False`` (tiled policy): ONE deep exchange then the whole chain
    locally (each rank computes a ``depth``-wide skirt redundantly).
    ``per_loop=True`` (untiled policy): exchange before every loop —
    ``len(loop_fns)`` shallow messages, no redundant compute.

    Migration note: this low-level builder is superseded by the
    ``ooc-sharded`` backend (``Session("ooc-sharded", mesh="sim:4")`` /
    ``mesh="jax:4"``), which runs the same one-exchange-per-chain policy
    *composed with* out-of-core tiling, with halo ops in the Plan IR and
    modelled per-device makespans.  It remains for raw jitted-step use.

    The returned function carries message accounting for the §5.2 policy
    trade-off: ``fn.exchanges`` (exchange events per step) and
    ``fn.messages_per_array`` (ppermute messages per step per array).
    """
    n_ranks = int(mesh.shape[axis_name])

    def local(arrays: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        if per_loop:
            for fn in loop_fns:
                arrays = exchange_halos(arrays, per_loop_depth, axis_name,
                                        dim, periodic)
                arrays = fn(arrays)
            return arrays
        arrays = exchange_halos(arrays, depth, axis_name, dim, periodic)
        return chain_fn(arrays)

    spec = P(*[None if d != dim else axis_name for d in range(2)])
    # A single PartitionSpec broadcasts over the dict-of-arrays pytree.
    shard_fn = shard_map(
        local, mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False
    )
    jitted = jax.jit(shard_fn)

    # Thin wrapper: jitted callables reject attribute assignment on some JAX
    # versions, and the accounting must ride along with the step.
    def step(arrays: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        return jitted(arrays)

    step.exchanges = len(loop_fns) if per_loop else 1
    step.messages_per_array = chain_message_count(
        n_ranks, 1, n_loops=len(loop_fns), per_loop=per_loop,
        periodic=periodic)
    return step
