"""Distributed (multi-device) stencil execution: halo exchange per chain.

The paper (§5.2) notes tiling's second benefit: instead of exchanging halos
per-loop, OPS computes the accumulated halo depth of the whole loop chain and
exchanges once per chain — fewer, larger messages.  This module implements
both policies on a device mesh with ``shard_map`` + ``collective_permute``
so the trade-off is measurable and the schedule is visible in dry-run HLO.

Grids are decomposed along one axis (default: the *non*-tiled dim 1, so
out-of-core slab tiling along dim 0 composes with MPI-style decomposition
along dim 1, mirroring the paper's 4-process KNL runs).

The chain's accumulated halo depth for left-to-right execution is
``n_loops × σ`` per neighbour side (σ = max stencil extent): loop k may read
σ cells beyond what loop k-1 wrote, so a chain of n loops consumes up to n·σ
remote cells before requiring fresh data.  After the exchange, every rank
runs the whole chain redundantly on its extended region (halo-deep compute),
which is exactly the "compute tiles that do not depend on halo data first"
follow-up the paper sketches in its conclusion, minus the overlap.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import axis_size, shard_map
from .dependency import analyze_chain
from .loop import ParallelLoop


@dataclass
class HaloExchangeStats:
    messages: int = 0
    bytes: int = 0


def exchange_halos(arrays: Dict[str, jax.Array], depth: int, axis_name: str,
                   dim: int = 1) -> Dict[str, jax.Array]:
    """One bidirectional halo exchange of ``depth`` cells along ``dim``.

    ``arrays`` are the per-device local shards *including* halo padding of at
    least ``depth`` on each side of ``dim``.  Neighbour interiors are pushed
    into our halo slots with two ``ppermute`` rings (up and down).

    Depth 0 is a fast path: a chain with no reads along ``dim`` (pointwise
    chains, sweeps along other axes) needs no neighbour data at all, so the
    collectives are skipped entirely — no ``ppermute``, no axis context
    required.
    """
    if depth <= 0:
        return dict(arrays)
    n = axis_size(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    out = {}
    for name, arr in arrays.items():
        size = arr.shape[dim]

        def take(lo, hi):
            sl = [slice(None)] * arr.ndim
            sl[dim] = slice(lo, hi)
            return arr[tuple(sl)]

        # our top interior -> neighbour's bottom halo, and vice versa
        send_up = take(size - 2 * depth, size - depth)
        send_dn = take(depth, 2 * depth)
        recv_dn = lax.ppermute(send_up, axis_name, fwd)   # from rank-1
        recv_up = lax.ppermute(send_dn, axis_name, bwd)   # from rank+1
        lo_sl = [slice(None)] * arr.ndim
        lo_sl[dim] = slice(0, depth)
        hi_sl = [slice(None)] * arr.ndim
        hi_sl[dim] = slice(size - depth, size)
        arr = arr.at[tuple(lo_sl)].set(recv_dn)
        arr = arr.at[tuple(hi_sl)].set(recv_up)
        out[name] = arr
    return out


def chain_halo_depth(loops: Sequence[ParallelLoop], dim: int = 1) -> int:
    """Accumulated halo depth a whole chain needs along ``dim``."""
    sigma = 0
    for lp in loops:
        for arg in lp.args:
            if arg.mode.reads:
                sigma = max(sigma, arg.stencil.max_abs_extent(dim))
    return sigma * len(loops)


def make_sharded_chain_step(
    chain_fn: Callable[[Dict[str, jax.Array]], Dict[str, jax.Array]],
    mesh: Mesh,
    axis_name: str,
    depth: int,
    per_loop: bool = False,
    loop_fns: Sequence[Callable] = (),
    per_loop_depth: int = 1,
    dim: int = 1,
):
    """Build a jitted sharded step: halo exchange(s) + local chain execution.

    ``per_loop=False`` (tiled policy): ONE deep exchange then the whole chain
    locally (each rank computes a ``depth``-wide skirt redundantly).
    ``per_loop=True`` (untiled policy): exchange before every loop —
    ``len(loop_fns)`` shallow messages, no redundant compute.
    """
    def local(arrays: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        if per_loop:
            for fn in loop_fns:
                arrays = exchange_halos(arrays, per_loop_depth, axis_name, dim)
                arrays = fn(arrays)
            return arrays
        arrays = exchange_halos(arrays, depth, axis_name, dim)
        return chain_fn(arrays)

    spec = P(*[None if d != dim else axis_name for d in range(2)])
    # A single PartitionSpec broadcasts over the dict-of-arrays pytree.
    shard_fn = shard_map(
        local, mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False
    )
    return jax.jit(shard_fn)
