"""repro.core — the paper's contribution: runtime skewed tiling + out-of-core
streaming execution of stencil loop chains (OPS-style DSL in JAX)."""
from .backends import (
    PallasBackend,
    ReferenceBackend,
    available_backends,
    make_backend,
    register_backend,
)
from .block import Block
from .dataset import Dataset, make_dataset
from .dependency import ChainInfo, analyze_chain, chain_signature, plan_signature
from .executor import (
    ChainPlan,
    ChainStats,
    OOCConfig,
    OutOfCoreExecutor,
    ResidentExecutor,
)
from .lazy import ReferenceRuntime, Runtime
from .program import (
    ExecutionConfig,
    Session,
    StencilProgram,
    StencilValidationError,
    infer_args,
    trace_kernel,
)
from .loop import (
    INC,
    READ,
    RW,
    WRITE,
    AccessMode,
    Accessor,
    Arg,
    ParallelLoop,
    ReductionSpec,
)
from .memory import (
    GB,
    KNL_7210,
    P100_NVLINK,
    P100_PCIE,
    PRESETS,
    TPU_V5E,
    HardwareModel,
    TransferLedger,
)
from .stencil import Stencil, box_stencil, offset_stencil, point_stencil, star_stencil
from .tiling import TileSchedule, choose_num_tiles, make_tile_schedule
from .transfer import (
    Codec,
    ResidencyError,
    ResidencyManager,
    TransferEngine,
    TransferError,
    available_codecs,
    get_codec,
    register_codec,
)

__all__ = [
    "Block", "Dataset", "make_dataset", "ChainInfo", "analyze_chain",
    "chain_signature", "plan_signature",
    "ChainPlan", "ChainStats", "OOCConfig", "OutOfCoreExecutor",
    "ResidentExecutor", "ReferenceRuntime", "Runtime",
    "Session", "StencilProgram", "ExecutionConfig", "StencilValidationError",
    "infer_args", "trace_kernel",
    "available_backends", "make_backend", "register_backend",
    "ReferenceBackend", "PallasBackend",
    "AccessMode", "Accessor", "Arg",
    "ParallelLoop", "ReductionSpec", "READ", "WRITE", "RW", "INC",
    "GB", "KNL_7210", "P100_NVLINK", "P100_PCIE", "PRESETS", "TPU_V5E",
    "HardwareModel", "TransferLedger", "Stencil", "box_stencil",
    "offset_stencil", "point_stencil", "star_stencil", "TileSchedule",
    "choose_num_tiles", "make_tile_schedule",
    "Codec", "register_codec", "get_codec", "available_codecs",
    "TransferEngine", "TransferError", "ResidencyManager", "ResidencyError",
]
