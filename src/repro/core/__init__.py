"""repro.core — the paper's contribution: runtime skewed tiling + out-of-core
streaming execution of stencil loop chains (OPS-style DSL in JAX)."""
from .backends import (
    PallasBackend,
    ReferenceBackend,
    available_backends,
    make_backend,
    register_backend,
)
from .block import Block
from .dataset import Dataset, make_dataset
from .dependency import (ChainInfo, analyze_chain, chain_signature,
                         plan_signature, shared_plan_signature)
from .executor import (
    ChainPlan,
    ChainStats,
    OOCConfig,
    OutOfCoreExecutor,
    ResidentExecutor,
)
from .interp import (
    DataPlaneInterpreter,
    InterpResult,
    LedgerInterpreter,
    SpecState,
    simulate_plan,
)
from .lazy import ReferenceRuntime, Runtime
from .mesh import DeviceMesh, HaloSpec, MeshError, ShardGeometry, parse_mesh
from .plan import (
    CarryEdge,
    Compute,
    Download,
    Elide,
    Evict,
    FetchHome,
    HaloExchange,
    HaloPack,
    HaloUnpack,
    PinUpload,
    Plan,
    PlanError,
    PlanOp,
    Prefetch,
    SpillHome,
    Upload,
    WritebackPinned,
    build_plan,
    format_plan,
    plans_from_json,
    plans_to_json,
)
from .verify import (
    Diagnostic,
    PlanVerificationError,
    VerifyResult,
    verify_plan,
    verify_plans,
)
from .fuzz import Mutation, check_mutations, enumerate_mutations
from .sharded import ShardedOutOfCoreExecutor, ShardingError
from .store import (
    BackingStore,
    ChunkedStore,
    MmapStore,
    RamStore,
    StoreConfig,
    StoreError,
    available_stores,
    load_checkpoint,
    make_store,
    register_store,
    save_checkpoint,
)
from .tune import TuneResult, tune_configs
from .program import (
    ExecutionConfig,
    Session,
    SessionClosedError,
    StencilProgram,
    StencilValidationError,
    infer_args,
    trace_kernel,
)
from .loop import (
    INC,
    READ,
    RW,
    WRITE,
    AccessMode,
    Accessor,
    Arg,
    ParallelLoop,
    ReductionSpec,
)
from .memory import (
    GB,
    KNL_7210,
    P100_NVLINK,
    P100_PCIE,
    PRESETS,
    TPU_V5E,
    HardwareModel,
    TransferLedger,
)
from .stencil import Stencil, box_stencil, offset_stencil, point_stencil, star_stencil
from .tiling import TileSchedule, choose_num_tiles, make_tile_schedule
from .transfer import (
    Codec,
    ResidencyError,
    ResidencyManager,
    TransferEngine,
    TransferError,
    available_codecs,
    get_codec,
    register_codec,
)

__all__ = [
    "Block", "Dataset", "make_dataset", "ChainInfo", "analyze_chain",
    "chain_signature", "plan_signature", "shared_plan_signature",
    "ChainPlan", "ChainStats", "OOCConfig", "OutOfCoreExecutor",
    "ResidentExecutor", "ReferenceRuntime", "Runtime",
    "Session", "SessionClosedError", "StencilProgram", "ExecutionConfig",
    "StencilValidationError",
    "infer_args", "trace_kernel",
    "available_backends", "make_backend", "register_backend",
    "ReferenceBackend", "PallasBackend",
    "AccessMode", "Accessor", "Arg",
    "ParallelLoop", "ReductionSpec", "READ", "WRITE", "RW", "INC",
    "GB", "KNL_7210", "P100_NVLINK", "P100_PCIE", "PRESETS", "TPU_V5E",
    "HardwareModel", "TransferLedger", "Stencil", "box_stencil",
    "offset_stencil", "point_stencil", "star_stencil", "TileSchedule",
    "choose_num_tiles", "make_tile_schedule",
    "Codec", "register_codec", "get_codec", "available_codecs",
    "TransferEngine", "TransferError", "ResidencyManager", "ResidencyError",
    "Plan", "PlanError", "PlanOp", "Upload", "Download", "Compute",
    "CarryEdge", "Elide",
    "Evict", "Prefetch", "PinUpload", "WritebackPinned", "FetchHome",
    "SpillHome", "HaloPack", "HaloExchange", "HaloUnpack", "build_plan",
    "format_plan", "plans_to_json", "plans_from_json",
    "Diagnostic", "VerifyResult", "PlanVerificationError", "verify_plan",
    "verify_plans", "Mutation", "enumerate_mutations", "check_mutations",
    "DeviceMesh", "HaloSpec", "MeshError", "ShardGeometry", "parse_mesh",
    "ShardedOutOfCoreExecutor", "ShardingError",
    "BackingStore", "RamStore", "MmapStore", "ChunkedStore", "StoreConfig",
    "StoreError", "make_store", "register_store", "available_stores",
    "save_checkpoint", "load_checkpoint",
    "LedgerInterpreter", "DataPlaneInterpreter", "InterpResult", "SpecState",
    "simulate_plan", "TuneResult", "tune_configs",
]
