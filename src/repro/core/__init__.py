"""repro.core — the paper's contribution: runtime skewed tiling + out-of-core
streaming execution of stencil loop chains (OPS-style DSL in JAX)."""
from .block import Block
from .dataset import Dataset, make_dataset
from .dependency import ChainInfo, analyze_chain
from .executor import ChainStats, OOCConfig, OutOfCoreExecutor, ResidentExecutor
from .lazy import ReferenceRuntime, Runtime
from .loop import (
    INC,
    READ,
    RW,
    WRITE,
    AccessMode,
    Accessor,
    Arg,
    ParallelLoop,
    ReductionSpec,
)
from .memory import (
    GB,
    KNL_7210,
    P100_NVLINK,
    P100_PCIE,
    PRESETS,
    TPU_V5E,
    HardwareModel,
    TransferLedger,
)
from .stencil import Stencil, box_stencil, offset_stencil, point_stencil, star_stencil
from .tiling import TileSchedule, choose_num_tiles, make_tile_schedule

__all__ = [
    "Block", "Dataset", "make_dataset", "ChainInfo", "analyze_chain",
    "ChainStats", "OOCConfig", "OutOfCoreExecutor", "ResidentExecutor",
    "ReferenceRuntime", "Runtime", "AccessMode", "Accessor", "Arg",
    "ParallelLoop", "ReductionSpec", "READ", "WRITE", "RW", "INC",
    "GB", "KNL_7210", "P100_NVLINK", "P100_PCIE", "PRESETS", "TPU_V5E",
    "HardwareModel", "TransferLedger", "Stencil", "box_stencil",
    "offset_stencil", "point_stencil", "star_stencil", "TileSchedule",
    "choose_num_tiles", "make_tile_schedule",
]
