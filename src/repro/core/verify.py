"""Static verification of Plan IR instruction streams.

The paper's correctness rests on bookkeeping that is easy to get subtly
wrong: §4.1 transfer elision, skewed skirt extents, dirty-row retirement,
and (since the mesh redesign) halo-exchange gating.  PR 5 proved the point
with two silent data-corruption hazards — a warm-upload clobber (a
segmented chain's full-width download overwriting home halo columns with
zero-initialised slot rows) and a stale cross-segment cyclic elision (a
dead-temporary elision applied to a dataset the next chain still reads).
Both are *plan-level* defects: they are visible in the instruction stream
before a single byte moves.

:func:`verify_plan` abstract-interprets one plan's op stream with no data
plane, tracking per-dataset, per-row-interval state across four locations:

* **slots** — which rows of which dataset are *valid* (staged, written or
  carried in) and which are *dirty* (written, writeback still owed) in each
  slot of the pool, mirroring the runtime
  :class:`~repro.core.transfer.ResidencyManager` invariants;
* **home** — which home rows are *stale* (their authoritative copy lives in
  a slot) and which were retired by elision (never written back);
* **the disk tier** — which rows a ``spill_home`` plan fetched into host
  RAM ahead of their staging read;
* **the mesh** — how deep into the halo skirt the stream actually reaches,
  checked against the declared exchange depth.

On top of the state machine it rebuilds the transfer-lane dependency graph
the interpreters would wire (upload FIFO, per-slot reuse fences,
download-after-compute, spill-after-download, fetch-before-upload,
pack → exchange → unpack → first staging upload) and reports ordering
violations — a download submitted before its tile's compute, a spill whose
download handle does not exist, a halo exchange that no longer gates the
chain's first upload — as race/missing-dependency diagnostics, plus cycle
detection over the assembled graph.

Diagnostics are typed (:class:`Diagnostic`: severity, category, op index,
dataset, interval) and collected into a :class:`VerifyResult`.
``error``-severity findings mean executing the plan can corrupt data or
deadlock; ``warn`` findings are suspicious but survivable (e.g. a
``spill_home`` staging read with no disk prefetch ahead of it).

:func:`verify_plans` verifies a whole chain set (what ``Session.plan()``
returns) and additionally cross-checks sharded per-device plans for
exchange consistency: uniform depth, per-device message counts matching
the device's neighbour count.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .plan import (
    CarryEdge,
    Compute,
    Download,
    Elide,
    Evict,
    FetchHome,
    HaloExchange,
    HaloPack,
    HaloUnpack,
    PinUpload,
    Plan,
    PlanOp,
    Prefetch,
    SpillHome,
    Upload,
    WritebackPinned,
)

Ivs = Tuple[Tuple[int, int], ...]   # merged half-open row intervals

ERROR = "error"
WARN = "warn"

#: Every category the verifier can emit, for documentation and tests.
CATEGORIES: Tuple[str, ...] = (
    "stale-read",          # upload/prefetch reads home rows owned by a slot
    "uninit-download",     # download of rows never staged nor written
    "uninit-read",         # carry of rows never staged nor written
    "dirty-loss",          # dirty rows dropped (slot reuse / chain end / clobber)
    "illegal-elide",       # elision outside the §4.1 Cyclic contract
    "slot-conflict",       # op's slot disagrees with the pool's FIFO order
    "missing-op",          # a tile lost its upload or compute
    "duplicate-op",        # a tile acquired/computed twice
    "missing-dep",         # lane ordering violated (race at execution time)
    "unreachable-handle",  # an op's dependency handle never exists
    "halo-order",          # pack/exchange/unpack misordered vs staging
    "halo-depth",          # exchange depth < consumed skirt
    "halo-missing",        # skirt consumed but no exchange in the stream
    "exchange-mismatch",   # per-device exchange annotations disagree
    "pinned-conflict",     # dataset both pinned and staged/tiled
    "disk-unfetched",      # spill_home staging read with no FetchHome ahead
    "disk-unspilled",      # spill_home download never retired to disk
    "unknown-dataset",     # op names a dataset absent from plan.row_bytes
    "cycle",               # dependency graph has a cycle (deadlock)
)


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding, anchored to an op in the stream.

    ``op_index`` is the index into ``plan.ops`` (-1 for plan-level findings
    such as end-of-chain dirty rows); ``plan_index`` identifies the plan
    within a multi-chain/multi-device verification."""

    severity: str                   # ERROR | WARN
    category: str                   # one of CATEGORIES
    op_index: int
    message: str
    dataset: Optional[str] = None
    interval: Optional[Tuple[int, int]] = None
    plan_index: int = 0

    def __str__(self) -> str:
        where = f"op {self.op_index}" if self.op_index >= 0 else "plan"
        tgt = ""
        if self.dataset is not None:
            tgt = f" {self.dataset}"
            if self.interval is not None:
                tgt += f"[{self.interval[0]}:{self.interval[1]})"
        return (f"{self.severity}[{self.category}] plan {self.plan_index} "
                f"{where}:{tgt} {self.message}")


class PlanVerificationError(RuntimeError):
    """A plan failed verification with error-severity diagnostics."""

    def __init__(self, result: "VerifyResult", context: str = "plan"):
        self.result = result
        errs = result.errors
        lines = [f"{context} failed verification "
                 f"({len(errs)} error(s), {len(result.warnings)} warning(s)):"]
        lines += [f"  {d}" for d in errs[:8]]
        if len(errs) > 8:
            lines.append(f"  ... and {len(errs) - 8} more")
        super().__init__("\n".join(lines))


@dataclass(frozen=True)
class VerifyResult:
    """All diagnostics from verifying one plan (or a whole chain set)."""

    diagnostics: Tuple[Diagnostic, ...]
    plans: int = 1
    ops: int = 0

    @property
    def errors(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == WARN)

    @property
    def ok(self) -> bool:
        """No error-severity diagnostics (warnings allowed)."""
        return not self.errors

    def summary(self) -> str:
        head = (f"verify: {self.plans} plan(s), {self.ops} ops, "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        if not self.diagnostics:
            return head + " — clean"
        return "\n".join([head] + [f"  {d}" for d in self.diagnostics])

    def raise_for_errors(self, context: str = "plan") -> None:
        if self.errors:
            raise PlanVerificationError(self, context)


def merge_results(results: Sequence[VerifyResult]) -> VerifyResult:
    """Fold several results into one (diagnostics concatenated in order)."""
    diags: List[Diagnostic] = []
    ops = 0
    for r in results:
        diags.extend(r.diagnostics)
        ops += r.ops
    return VerifyResult(diagnostics=tuple(diags),
                        plans=sum(r.plans for r in results), ops=ops)


# -- merged-interval algebra --------------------------------------------------------


def _merge(ivs: Sequence[Tuple[int, int]]) -> Ivs:
    out: List[Tuple[int, int]] = []
    for lo, hi in sorted((lo, hi) for lo, hi in ivs if hi > lo):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return tuple(out)


def _add(a: Ivs, lo: int, hi: int) -> Ivs:
    return _merge(list(a) + [(lo, hi)])


def _sub(a: Ivs, lo: int, hi: int) -> Ivs:
    out: List[Tuple[int, int]] = []
    for alo, ahi in a:
        if ahi <= lo or alo >= hi:
            out.append((alo, ahi))
            continue
        if alo < lo:
            out.append((alo, lo))
        if ahi > hi:
            out.append((hi, ahi))
    return tuple(out)


def _inter(a: Ivs, lo: int, hi: int) -> Ivs:
    return tuple((max(alo, lo), min(ahi, hi)) for alo, ahi in a
                 if max(alo, lo) < min(ahi, hi))


def _uncovered(a: Ivs, lo: int, hi: int) -> Ivs:
    """The parts of ``[lo, hi)`` NOT covered by ``a``."""
    gaps: List[Tuple[int, int]] = []
    cur = lo
    for alo, ahi in a:
        if ahi <= lo or alo >= hi:
            continue
        if alo > cur:
            gaps.append((cur, min(alo, hi)))
        cur = max(cur, ahi)
        if cur >= hi:
            break
    if cur < hi:
        gaps.append((cur, hi))
    return tuple(gaps)


# -- the dependency graph -----------------------------------------------------------


def find_cycle(num_nodes: int,
               edges: Sequence[Tuple[int, int]]) -> Optional[List[int]]:
    """Return one cycle (as a node list) in the directed graph, or None.

    Used on the rebuilt transfer-lane dependency graph: a cycle means the
    engine's workers would deadlock waiting on each other's handles.
    """
    succ: Dict[int, List[int]] = {}
    for a, b in edges:
        succ.setdefault(a, []).append(b)
    state = [0] * num_nodes          # 0 unvisited / 1 on stack / 2 done
    stack: List[int] = []

    def visit(n: int) -> Optional[List[int]]:
        state[n] = 1
        stack.append(n)
        for m in succ.get(n, ()):
            if state[m] == 1:
                return stack[stack.index(m):] + [m]
            if state[m] == 0:
                cyc = visit(m)
                if cyc is not None:
                    return cyc
        stack.pop()
        state[n] = 2
        return None

    for n in range(num_nodes):
        if state[n] == 0:
            cyc = visit(n)
            if cyc is not None:
                return cyc
    return None


# -- per-slot abstract state --------------------------------------------------------


@dataclass
class _SlotState:
    tile: Optional[int] = None
    valid: Dict[str, Ivs] = field(default_factory=dict)
    dirty: Dict[str, Ivs] = field(default_factory=dict)
    carried: Dict[str, Ivs] = field(default_factory=dict)  # 1-slot in-place


class _Verifier:
    """One pass over ``plan.ops``; collects diagnostics."""

    def __init__(self, plan: Plan, plan_index: int = 0):
        self.plan = plan
        self.plan_index = plan_index
        self.diags: List[Diagnostic] = []
        self.row_bytes = dict(plan.row_bytes)
        ns = max(1, plan.num_slots)
        self.num_slots = ns
        self.slots = [_SlotState() for _ in range(ns)]
        self.home_stale: Dict[str, Ivs] = {}
        self.elided: Dict[str, Ivs] = {}
        self.pinned: Set[str] = set()
        self.fetched: Dict[int, Dict[str, Ivs]] = {}
        self.acquires = 0
        self.tile_upload: Dict[int, int] = {}     # tile -> op index
        self.tile_compute: Dict[int, int] = {}
        self.tile_download: Dict[int, int] = {}
        self.tile_spill: Dict[int, int] = {}
        self.pack_idx: Optional[int] = None
        self.exchange_idx: Optional[int] = None
        self.exchange_depth: Optional[int] = None
        self.unpack_idx: Optional[int] = None
        self.first_upload_idx: Optional[int] = None
        self.min_row = 0                          # deepest skirt row touched
        self.unknown: Set[str] = set()
        self.edges: List[Tuple[int, int]] = []    # dep graph over op indices

    # -- reporting ------------------------------------------------------------
    def diag(self, severity: str, category: str, idx: int, msg: str,
             dataset: Optional[str] = None,
             interval: Optional[Tuple[int, int]] = None) -> None:
        self.diags.append(Diagnostic(
            severity=severity, category=category, op_index=idx, message=msg,
            dataset=dataset, interval=interval, plan_index=self.plan_index))

    def _known(self, idx: int, name: str) -> bool:
        if name in self.row_bytes:
            return True
        if name not in self.unknown:
            self.unknown.add(name)
            self.diag(ERROR, "unknown-dataset", idx,
                      "op references a dataset absent from plan.row_bytes",
                      dataset=name)
        return False

    def _slot_check(self, idx: int, op: PlanOp, tile: int, slot: int) -> None:
        want = tile % self.num_slots
        if slot != want:
            self.diag(ERROR, "slot-conflict", idx,
                      f"{op.kind} of tile {tile} targets slot {slot}; the "
                      f"round-robin pool puts tile {tile} in slot {want}")

    # -- driver ---------------------------------------------------------------
    def run(self) -> VerifyResult:
        handlers = {
            Upload.kind: self.op_upload, Compute.kind: self.op_compute,
            CarryEdge.kind: self.op_carry, Elide.kind: self.op_elide,
            Download.kind: self.op_download, Evict.kind: self.op_evict,
            Prefetch.kind: self.op_prefetch,
            PinUpload.kind: self.op_pin_upload,
            WritebackPinned.kind: self.op_pin_flush,
            FetchHome.kind: self.op_fetch_home,
            SpillHome.kind: self.op_spill_home,
            HaloPack.kind: self.op_halo_pack,
            HaloExchange.kind: self.op_halo_exchange,
            HaloUnpack.kind: self.op_halo_unpack,
        }
        for idx, op in enumerate(self.plan.ops):
            handlers[op.kind](idx, op)
        self.finish()
        return VerifyResult(diagnostics=tuple(self.diags), plans=1,
                            ops=len(self.plan.ops))

    # -- the network stream ---------------------------------------------------
    def op_halo_pack(self, idx: int, op: HaloPack) -> None:
        if self.plan.mesh_devices <= 1:
            self.diag(WARN, "halo-order", idx,
                      "halo-pack in an unsharded plan")
        self.pack_idx = idx

    def op_halo_exchange(self, idx: int, op: HaloExchange) -> None:
        if self.pack_idx is None:
            self.diag(ERROR, "halo-order", idx,
                      "halo-exchange with no halo-pack before it: send "
                      "buffers are not staged")
        else:
            self.edges.append((self.pack_idx, idx))
        if self.first_upload_idx is not None:
            self.diag(ERROR, "halo-order", idx,
                      "halo-exchange after staging began: the chain's first "
                      f"upload (op {self.first_upload_idx}) read skirt rows "
                      "the exchange had not refreshed")
        self.exchange_idx = idx
        self.exchange_depth = op.depth

    def op_halo_unpack(self, idx: int, op: HaloUnpack) -> None:
        if self.exchange_idx is None:
            self.diag(ERROR, "halo-order", idx,
                      "halo-unpack with no halo-exchange before it")
        else:
            self.edges.append((self.exchange_idx, idx))
        if self.first_upload_idx is not None:
            self.diag(ERROR, "halo-order", idx,
                      "halo-unpack after staging began: it no longer gates "
                      "the chain's first upload")
        self.unpack_idx = idx

    # -- pinned residency -----------------------------------------------------
    def op_pin_upload(self, idx: int, op: PinUpload) -> None:
        for name, _nb in op.entries:
            if self._known(idx, name):
                self.pinned.add(name)

    def op_pin_flush(self, idx: int, op: WritebackPinned) -> None:
        for name, _rows, _nb, _w in op.entries:
            if name not in self.pinned:
                self.diag(WARN, "pinned-conflict", idx,
                          "writeback-pinned flushes a dataset no pin-upload "
                          "made resident", dataset=name)

    # -- the disk tier --------------------------------------------------------
    def op_fetch_home(self, idx: int, op: FetchHome) -> None:
        if not self.plan.spill_home:
            self.diag(WARN, "disk-unfetched", idx,
                      f"fetch-home for tile {op.tile} in a plan without "
                      "spill_home: no disk tier is planned")
        if op.tile in self.tile_upload:
            self.diag(ERROR, "missing-dep", idx,
                      f"fetch-home for tile {op.tile} appears after its "
                      f"upload (op {self.tile_upload[op.tile]}): the staging "
                      "read is not gated on the disk prefetch")
        per = self.fetched.setdefault(op.tile, {})
        for name, lo, hi in op.items:
            if self._known(idx, name):
                per[name] = _add(per.get(name, ()), lo, hi)

    def op_spill_home(self, idx: int, op: SpillHome) -> None:
        dl = self.tile_download.get(op.tile)
        if dl is None:
            self.diag(ERROR, "missing-dep", idx,
                      f"spill-home for tile {op.tile} has no download before "
                      "it: the disk lane would retire rows that never landed "
                      "home (its dependency handle does not exist)")
        else:
            self.edges.append((dl, idx))
        self.tile_spill[op.tile] = idx

    # -- staging --------------------------------------------------------------
    def op_upload(self, idx: int, op: Upload) -> None:
        t = op.tile
        self._slot_check(idx, op, t, op.slot)
        if t in self.tile_upload:
            self.diag(ERROR, "duplicate-op", idx,
                      f"tile {t} acquired twice (first at op "
                      f"{self.tile_upload[t]})")
            return
        want = self.acquires % self.num_slots
        if op.slot % self.num_slots != want:
            self.diag(ERROR, "slot-conflict", idx,
                      f"upload of tile {t} is acquisition #{self.acquires}: "
                      f"the FIFO pool returns slot {want}, plan says slot "
                      f"{op.slot} — staged rows would land in the wrong slot")
        self.acquires += 1
        self.tile_upload[t] = idx
        slot = self.slots[op.slot % self.num_slots]
        # Slot reuse: the residency manager refuses to evict dirty rows
        # (except the 1-slot pool, which continues in place after a carry).
        if self.num_slots > 1:
            for name, ivs in slot.dirty.items():
                for lo, hi in ivs:
                    self.diag(ERROR, "dirty-loss", idx,
                              f"tile {t} reuses slot {op.slot} while tile "
                              f"{slot.tile} still owes writeback — dirty "
                              "rows are dropped", dataset=name,
                              interval=(lo, hi))
            slot.valid = {}
            slot.dirty = {}
        else:
            # In-place continuation: only carried rows survive the origin
            # rebase; dirty rows that were not carried are lost.
            new_dirty: Dict[str, Ivs] = {}
            for name, ivs in slot.dirty.items():
                carried = slot.carried.get(name, ())
                kept: List[Tuple[int, int]] = []
                for lo, hi in ivs:
                    for glo, ghi in _uncovered(carried, lo, hi):
                        self.diag(ERROR, "dirty-loss", idx,
                                  f"tile {t} rebases the 1-slot pool but "
                                  "dirty rows were not carried across the "
                                  "origin shift", dataset=name,
                                  interval=(glo, ghi))
                for clo, chi in carried:
                    kept.extend(_inter(ivs, clo, chi))
                if kept:
                    new_dirty[name] = _merge(kept)
            slot.valid = {n: ivs for n, ivs in slot.carried.items()}
            slot.dirty = new_dirty
        slot.carried = {}
        slot.tile = t
        for name, lo, hi in op.items:
            if not self._known(idx, name):
                continue
            self.min_row = min(self.min_row, lo)
            if name in self.pinned:
                self.diag(ERROR, "pinned-conflict", idx,
                          "staged upload of a pinned (whole-array resident) "
                          "dataset", dataset=name, interval=(lo, hi))
            # Stale home read: rows whose authoritative copy is in a slot
            # (written, not yet downloaded) or was discarded by an elision.
            for slo, shi in _inter(self.home_stale.get(name, ()), lo, hi):
                via = ("retired by an earlier elision"
                       if _inter(self.elided.get(name, ()), slo, shi)
                       else "still dirty in a slot")
                self.diag(ERROR, "stale-read", idx,
                          f"upload for tile {t} reads home rows that are "
                          f"stale ({via}) — the upload lane races the "
                          "download lane for these rows", dataset=name,
                          interval=(slo, shi))
            for dlo, dhi in _inter(slot.dirty.get(name, ()), lo, hi):
                self.diag(ERROR, "dirty-loss", idx,
                          "upload overwrites unretired dirty rows in its "
                          "own slot with home data", dataset=name,
                          interval=(dlo, dhi))
            if self.plan.spill_home and name not in self.pinned:
                have = self.fetched.get(t, {}).get(name, ())
                for glo, ghi in _uncovered(have, lo, hi):
                    self.diag(WARN, "disk-unfetched", idx,
                              f"staging read of tile {t} has no fetch-home "
                              "covering it: the upload worker will fault the "
                              "rows in synchronously", dataset=name,
                              interval=(glo, ghi))
            slot.valid[name] = _add(slot.valid.get(name, ()), lo, hi)
        if self.first_upload_idx is None:
            self.first_upload_idx = idx
            if self.unpack_idx is not None:
                self.edges.append((self.unpack_idx, idx))

    # -- compute --------------------------------------------------------------
    def op_compute(self, idx: int, op: Compute) -> None:
        t = op.tile
        self._slot_check(idx, op, t, op.slot)
        if t in self.tile_compute:
            self.diag(ERROR, "duplicate-op", idx,
                      f"tile {t} computed twice (first at op "
                      f"{self.tile_compute[t]})")
            return
        up = self.tile_upload.get(t)
        if up is None:
            self.diag(ERROR, "missing-op", idx,
                      f"compute of tile {t} with no upload before it: the "
                      "tile's slot was never acquired, its staged rows never "
                      "requested")
        else:
            self.edges.append((up, idx))
        self.tile_compute[t] = idx
        slot = self.slots[op.slot % self.num_slots]
        for name, rows in op.writes:
            if not self._known(idx, name):
                continue
            if name in self.pinned:
                self.diag(ERROR, "pinned-conflict", idx,
                          "compute marks slot-dirty rows on a pinned "
                          "dataset (pinned writes are tracked separately)",
                          dataset=name)
                continue
            for lo, hi in rows:
                self.min_row = min(self.min_row, lo)
                slot.dirty[name] = _add(slot.dirty.get(name, ()), lo, hi)
                slot.valid[name] = _add(slot.valid.get(name, ()), lo, hi)
                self.home_stale[name] = _add(
                    self.home_stale.get(name, ()), lo, hi)
                self.elided[name] = _sub(self.elided.get(name, ()), lo, hi)

    # -- edge carry -----------------------------------------------------------
    def op_carry(self, idx: int, op: CarryEdge) -> None:
        t = op.tile
        self._slot_check(idx, op, t, op.slot)
        want_dst = (t + 1) % self.num_slots
        if op.dst_slot != want_dst:
            self.diag(ERROR, "slot-conflict", idx,
                      f"carry of tile {t} targets slot {op.dst_slot}; tile "
                      f"{t + 1} lives in slot {want_dst}")
        cm = self.tile_compute.get(t)
        if cm is None:
            self.diag(ERROR, "missing-dep", idx,
                      f"carry of tile {t} before its compute: the edge rows "
                      "do not exist yet")
        else:
            self.edges.append((cm, idx))
        if self.num_slots > 1 and (t + 1) not in self.tile_upload:
            self.diag(ERROR, "missing-dep", idx,
                      f"carry of tile {t} before tile {t + 1}'s upload "
                      "acquired the destination slot: the copy lands in a "
                      "slot still owned by a previous tile")
        src = self.slots[op.slot % self.num_slots]
        dst = self.slots[op.dst_slot % self.num_slots]
        for name, lo, hi in op.items:
            if not self._known(idx, name):
                continue
            for glo, ghi in _uncovered(src.valid.get(name, ()), lo, hi):
                self.diag(ERROR, "uninit-read", idx,
                          f"carry of tile {t} copies rows that were never "
                          "staged nor written in its slot", dataset=name,
                          interval=(glo, ghi))
            moved = _inter(src.dirty.get(name, ()), lo, hi)
            src.dirty[name] = _sub(src.dirty.get(name, ()), lo, hi)
            if dst is src:
                src.carried[name] = _add(src.carried.get(name, ()), lo, hi)
                for mlo, mhi in moved:
                    src.dirty[name] = _add(src.dirty[name], mlo, mhi)
            else:
                for mlo, mhi in moved:
                    dst.dirty[name] = _add(dst.dirty.get(name, ()), mlo, mhi)
                dst.valid[name] = _add(dst.valid.get(name, ()), lo, hi)

    # -- retire ---------------------------------------------------------------
    def op_elide(self, idx: int, op: Elide) -> None:
        t = op.tile
        self._slot_check(idx, op, t, op.slot)
        slot = self.slots[op.slot % self.num_slots]
        if not self.plan.cyclic:
            self.diag(ERROR, "illegal-elide", idx,
                      "elision in a non-cyclic plan: §4.1 Cyclic was not "
                      "enabled, so every dirty row owes a writeback")
        for name, lo, hi in op.items:
            if not self._known(idx, name):
                continue
            if name in self.plan.keep_live:
                self.diag(ERROR, "illegal-elide", idx,
                          "elision of a keep_live dataset: the chain's "
                          "remainder (or the next segment) still reads it — "
                          "its home copy goes stale exactly like the "
                          "cross-segment cyclic elision hazard",
                          dataset=name, interval=(lo, hi))
            live = _inter(slot.dirty.get(name, ()), lo, hi)
            for glo, ghi in _uncovered(live, lo, hi):
                self.diag(WARN, "illegal-elide", idx,
                          "elision of rows that are not dirty in the slot",
                          dataset=name, interval=(glo, ghi))
            slot.dirty[name] = _sub(slot.dirty.get(name, ()), lo, hi)
            self.elided[name] = _add(self.elided.get(name, ()), lo, hi)
            # home_stale keeps these rows: their home copy was never
            # refreshed, and a later read of it would be stale.

    def op_download(self, idx: int, op: Download) -> None:
        t = op.tile
        self._slot_check(idx, op, t, op.slot)
        cm = self.tile_compute.get(t)
        if cm is None:
            self.diag(ERROR, "missing-dep", idx,
                      f"download of tile {t} before its compute: the "
                      "download lane would ship rows the compute stream has "
                      "not produced (write-read race between streams 0/2)")
        else:
            self.edges.append((cm, idx))
        slot = self.slots[op.slot % self.num_slots]
        self.tile_download[t] = idx
        for name, lo, hi in op.items:
            if not self._known(idx, name):
                continue
            if name in self.pinned:
                self.diag(ERROR, "pinned-conflict", idx,
                          "download of a pinned dataset (pinned rows flush "
                          "once at chain end)", dataset=name,
                          interval=(lo, hi))
            for glo, ghi in _uncovered(slot.valid.get(name, ()), lo, hi):
                self.diag(ERROR, "uninit-download", idx,
                          f"download of tile {t} ships rows that were never "
                          "staged nor written — home rows are clobbered "
                          "with uninitialised slot content (the warm-upload "
                          "hazard)", dataset=name, interval=(glo, ghi))
            slot.dirty[name] = _sub(slot.dirty.get(name, ()), lo, hi)
            self.home_stale[name] = _sub(
                self.home_stale.get(name, ()), lo, hi)

    def op_evict(self, idx: int, op: Evict) -> None:
        self._slot_check(idx, op, op.tile, op.slot)
        if op.tile < self.num_slots:
            self.diag(WARN, "slot-conflict", idx,
                      f"evict for tile {op.tile}, which is the slot pool's "
                      "first pass — nothing to displace")

    # -- speculative prefetch -------------------------------------------------
    def op_prefetch(self, idx: int, op: Prefetch) -> None:
        for name, rows in op.items:
            if not self._known(idx, name):
                continue
            for lo, hi in rows:
                for slo, shi in _inter(self.home_stale.get(name, ()), lo, hi):
                    self.diag(ERROR, "stale-read", idx,
                              "speculative prefetch captures home rows that "
                              "are stale (dirty in a slot or elided)",
                              dataset=name, interval=(slo, shi))

    # -- end of stream --------------------------------------------------------
    def finish(self) -> None:
        plan = self.plan
        # Dirty rows surviving the chain: the exact residency invariant
        # ``ResidencyManager.end_chain`` asserts at runtime.
        for slot in self.slots:
            for name, ivs in slot.dirty.items():
                for lo, hi in ivs:
                    self.diag(ERROR, "dirty-loss", -1,
                              f"chain ends with dirty rows in slot (tile "
                              f"{slot.tile}): written data is never "
                              "downloaded, carried or legally elided",
                              dataset=name, interval=(lo, hi))
        # Per-tile completeness: every tile must acquire and compute.
        for t in range(plan.num_tiles):
            if t not in self.tile_upload:
                self.diag(ERROR, "missing-op", -1,
                          f"tile {t} has no upload op: its slot is never "
                          "acquired")
            if t not in self.tile_compute:
                self.diag(ERROR, "missing-op", -1,
                          f"tile {t} has no compute op")
        # Unreachable handles: deps that never exist anywhere in the stream.
        for t in self.fetched:
            if t not in self.tile_upload:
                self.diag(WARN, "unreachable-handle", -1,
                          f"fetch-home for tile {t} but no upload consumes "
                          "it")
        if self.pack_idx is not None and self.exchange_idx is None:
            self.diag(WARN, "unreachable-handle", -1,
                      "halo-pack staged send buffers but no halo-exchange "
                      "consumes them")
        if self.exchange_idx is not None and self.unpack_idx is None:
            self.diag(WARN, "unreachable-handle", -1,
                      "halo-exchange with no halo-unpack: received rows "
                      "never land in the home skirt")
        # Disk-tier retirement: every download in a spill plan should be
        # pushed out so the host working set stays inside the budget.
        if plan.spill_home:
            for t, dl in self.tile_download.items():
                if t not in self.tile_spill:
                    self.diag(WARN, "disk-unspilled", dl,
                              f"tile {t}'s download is never spilled to the "
                              "disk tier: its rows stay in host RAM")
        # Halo depth vs the consumed skirt.  Rows below 0 on a device with a
        # low neighbour must have been refreshed by the exchange.
        if plan.mesh_devices > 1 and plan.device > 0:
            reach = -self.min_row
            if reach > 0:
                if self.exchange_idx is None:
                    self.diag(ERROR, "halo-missing", -1,
                              f"device {plan.device} consumes {reach} skirt "
                              "row(s) below its shard but the stream has no "
                              "halo-exchange")
                elif self.exchange_depth is not None \
                        and self.exchange_depth < reach:
                    self.diag(ERROR, "halo-depth", self.exchange_idx,
                              f"halo-exchange depth {self.exchange_depth} < "
                              f"consumed skirt {reach}: the deepest staged/"
                              "computed rows were never refreshed")
        # Deadlock check over the rebuilt transfer-lane dependency graph.
        cyc = find_cycle(len(plan.ops), self.edges)
        if cyc is not None:
            self.diag(ERROR, "cycle", cyc[0],
                      "transfer dependency graph has a cycle through ops "
                      f"{cyc}: the lanes would deadlock")


# -- public API ---------------------------------------------------------------------


def verify_plan(plan: Plan, *, plan_index: int = 0) -> VerifyResult:
    """Statically verify one plan's instruction stream.

    Abstract-interprets the op stream with no data plane, checking the
    residency/dirty-row/staleness invariants the runtime enforces (or
    silently relies on), the transfer-lane ordering the interpreters would
    wire, and the halo-exchange depth against the consumed skirt.  Returns
    a :class:`VerifyResult`; ``result.ok`` means no error-severity
    diagnostics."""
    return _Verifier(plan, plan_index).run()


def _exchange_consistency(group: List[Tuple[int, Plan]]) -> List[Diagnostic]:
    """Cross-device checks over one segment's per-device plans."""
    diags: List[Diagnostic] = []
    info: List[Tuple[int, int, Plan, HaloExchange, Optional[HaloPack]]] = []
    for pi, p in group:
        ex = next((op for op in p.ops if isinstance(op, HaloExchange)), None)
        pk = next((op for op in p.ops if isinstance(op, HaloPack)), None)
        if ex is not None:
            info.append((pi, p.device, p, ex, pk))
    if len(info) < 2:
        return diags
    depths = {ex.depth for _, _, _, ex, _ in info}
    if len(depths) > 1:
        for pi, dev, _p, ex, _pk in info:
            diags.append(Diagnostic(
                severity=ERROR, category="exchange-mismatch", op_index=-1,
                message=(f"device {dev} exchanges at depth {ex.depth} but "
                         f"the segment's devices disagree ({sorted(depths)})"
                         " — neighbours would send/receive different row "
                         "counts"), plan_index=pi))
    for pi, dev, p, ex, pk in info:
        if pk is None:
            continue
        sides = (1 if dev > 0 else 0) + (1 if dev < p.mesh_devices - 1 else 0)
        want = len(pk.names) * sides
        if ex.messages != want:
            diags.append(Diagnostic(
                severity=ERROR, category="exchange-mismatch", op_index=-1,
                message=(f"device {dev}/{p.mesh_devices} declares "
                         f"{ex.messages} exchange message(s); "
                         f"{len(pk.names)} dataset(s) x {sides} "
                         f"neighbour(s) = {want}"), plan_index=pi))
    return diags


def verify_plans(plans: Sequence[Plan]) -> VerifyResult:
    """Verify a chain set (``Session.plan()`` output): every plan
    individually, plus exchange consistency across each sharded segment's
    per-device plans."""
    diags: List[Diagnostic] = []
    ops = 0
    for i, p in enumerate(plans):
        r = verify_plan(p, plan_index=i)
        diags.extend(r.diagnostics)
        ops += r.ops
    # Group consecutive mesh plans into segments (device ids restart).
    group: List[Tuple[int, Plan]] = []
    prev_dev = -1
    for i, p in enumerate(plans):
        if p.mesh_devices > 1:
            if group and p.device <= prev_dev:
                diags.extend(_exchange_consistency(group))
                group = []
            group.append((i, p))
            prev_dev = p.device
        else:
            if group:
                diags.extend(_exchange_consistency(group))
                group = []
            prev_dev = -1
    if group:
        diags.extend(_exchange_consistency(group))
    return VerifyResult(diagnostics=tuple(diags), plans=len(plans), ops=ops)
