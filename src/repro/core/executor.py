"""Out-of-core executors (the paper's §4, Algorithm 1).

``OutOfCoreExecutor`` — explicit memory management with three slots:
while tile *t* executes (stream 0), tile *t+1*'s right footprint uploads
(stream 1) and tile *t−1*'s left footprint downloads (stream 2); after each
tile the right edge is copied device-side into the next slot.  Transfer
elision per §4.1: read-only datasets never download, write-first datasets
never upload, Cyclic additionally skips the download of write-first
temporaries, and speculative prefetch uploads the *next* chain's first tile
during the current chain's last tile.

``ResidentExecutor`` — the paper's baseline: everything resident in fast
memory for the whole run (raises, like the paper's segfault, if it can't fit).

Data plane: home copies are NumPy (slow memory); slots are JAX device arrays;
uploads/downloads go through ``jnp.asarray``/``np.asarray`` so the data path
is real on every backend, while *timings* for the paper's platforms come from
the calibrated :class:`~repro.core.memory.HardwareModel` ledger.

The transfer layer itself lives in :mod:`repro.core.transfer`: a
:class:`~repro.core.transfer.TransferEngine` (``transfer="threaded"`` stages
uploads/downloads on background workers so tile *t+1*'s upload and tile
*t−1*'s download genuinely overlap tile *t*'s compute; ``"sync"`` is the
deterministic inline fallback), a
:class:`~repro.core.transfer.ResidencyManager` (LRU slot pool, dirty-range
tracking, pinned datasets, capacity accounting), and per-dataset compression
codecs whose achieved wire bytes are what the ledger charges.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .dependency import ChainInfo, analyze_chain, chain_signature, plan_signature
from .engine import TileEngine
from .loop import ParallelLoop
from .memory import HardwareModel, TPU_V5E, TransferLedger
from .tiling import (
    Interval,
    TileSchedule,
    choose_num_tiles,
    make_tile_schedule,
)
from .transfer import ResidencyManager, TransferEngine, resolve_codecs
from .transfer.engine import DOWN, UP


@dataclass
class OOCConfig:
    hw: HardwareModel = TPU_V5E
    capacity_bytes: Optional[float] = None   # default: hw.fast_capacity
    num_slots: int = 3
    num_tiles: Optional[int] = None          # default: smallest that fits
    tiled_dim: int = 0
    cyclic: bool = False                     # §4.1 unsafe temporaries opt
    prefetch: bool = False                   # §4.1 speculative prefetch
    flops_per_point: Optional[int] = None    # compute model override
    # Schedule/ledger only — no data plane.  For modelled benchmarks at
    # scaled-down sizes (correctness is covered by the executing tests).
    simulate_only: bool = False
    # -- transfer subsystem knobs --------------------------------------------
    transfer: str = "sync"                   # "sync" | "threaded"
    codec: Union[str, Dict[str, str]] = "identity"   # name or {dat: name, "*": ...}
    pinned: Tuple[str, ...] = ()             # datasets kept device-resident

    @property
    def capacity(self) -> float:
        return self.capacity_bytes if self.capacity_bytes is not None else self.hw.fast_capacity


@dataclass
class ChainStats:
    num_tiles: int
    loop_bytes: int            # the paper's 'useful bytes' for avg-BW metric
    uploaded: int              # raw (uncompressed) bytes staged up
    downloaded: int            # raw (uncompressed) bytes staged down
    edge_bytes: int
    prefetch_hits: int
    wall_s: float
    modelled_s: float
    achieved_bw_model: float   # loop_bytes / modelled makespan
    slot_bytes: int
    plan_cache_hit: bool = False   # chain plan replayed from cache
    plan_s: float = 0.0            # analysis + scheduling time (0 on hits)
    # -- transfer subsystem --------------------------------------------------
    uploaded_wire: int = 0         # post-codec bytes the link carried up
    downloaded_wire: int = 0       # post-codec bytes the link carried down
    compression_ratio: float = 1.0  # raw / wire over both directions
    queue_wait_s: float = 0.0      # submit-to-start latency summed over tasks
    transfer_mode: str = "sync"


@dataclass
class ChainPlan:
    """The memoised product of dependency analysis + tile scheduling + the
    compiled tile engine for one chain signature.  Cyclic loop chains
    (CloverLeaf/OpenSBLI timesteps) are structurally identical across steps,
    so every flush after the first replays one of these instead of paying
    ``analyze_chain`` + ``make_tile_schedule`` + jit-cache lookup again."""

    key: Tuple
    info: ChainInfo
    sched: TileSchedule
    engine: TileEngine
    slot_bytes: int     # per-slot bytes, pinned datasets excluded
    sig: Tuple          # structural chain_signature (prefetch guessing)
    plan_s: float       # construction cost (what cache hits save)
    pinned_names: frozenset = frozenset()   # pinned datasets this chain touches
    pinned_bytes: int = 0                   # their whole-array residency cost


class _SimArray:
    """Placeholder device array for ``simulate_only`` pinned caching."""

    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int):
        self.nbytes = int(nbytes)


def _region_to_slot(iv: Interval, origin: int) -> Tuple[int, int]:
    return iv.lo - origin, iv.hi - origin


class OutOfCoreExecutor:
    """Explicitly-managed 3-slot streaming executor (Algorithm 1)."""

    def __init__(self, config: OOCConfig = None):
        self.cfg = config or OOCConfig()
        # LRU-bounded: kernels capturing a per-step constant (a real dt
        # changing every step) legitimately produce a new plan per flush —
        # without a bound a long run would accumulate engines/ChainInfos
        # (and their jit caches) without limit.
        self._plans: "OrderedDict[Tuple, ChainPlan]" = OrderedDict()
        self._max_plans = 32
        self._no_fit: set = set()   # keys known to raise MemoryError
        self.plan_hits = 0
        self.plan_misses = 0
        self.plan_time_s = 0.0
        # The transfer subsystem: engine (worker threads or sync fallback)
        # and residency manager (slot pool, dirty tracking, pinned cache,
        # capacity accounting) are executor-lifetime so pinned device arrays
        # and transfer statistics persist across chains.
        self.transfer = TransferEngine(mode=self.cfg.transfer)
        self.residency = ResidencyManager(
            capacity_bytes=self.cfg.capacity, num_slots=self.cfg.num_slots,
            pinned=frozenset(self.cfg.pinned))
        # Speculative prefetch state: what we uploaded ahead for the next
        # chain: {dat_name: (Interval, ...)} plus the signature we guessed
        # from, and — on real data-plane runs — the captured device arrays
        # backing those intervals ({name: [(Interval, array, dat_id,
        # dat_version), ...]}).  A hit restores the captured data into the
        # slot instead of re-staging from home; any identity/version mismatch
        # degrades to a miss (full upload), never to stale data.
        self._spec_uploaded: Dict[str, Tuple[Interval, ...]] = {}
        self._spec_data: Dict[str, list] = {}
        self._spec_sig = None
        self.history: List[ChainStats] = []

    # -- helpers -------------------------------------------------------------
    def _dat_np_region(self, dat, iv: Interval) -> np.ndarray:
        td = self.cfg.tiled_dim
        h = dat.halo[td][0]
        idx = [slice(None)] * dat.ndim
        idx[td] = slice(iv.lo + h, iv.hi + h)
        return dat.data[tuple(idx)]

    def _write_np_region(self, dat, iv: Interval, values: np.ndarray) -> None:
        td = self.cfg.tiled_dim
        h = dat.halo[td][0]
        idx = [slice(None)] * dat.ndim
        idx[td] = slice(iv.lo + h, iv.hi + h)
        dat.data[tuple(idx)] = values

    @staticmethod
    def _slot_slice(arr, lo: int, hi: int, td: int):
        idx = [slice(None)] * arr.ndim
        idx[td] = slice(lo, hi)
        return tuple(idx)

    def _nbytes(self, dat, iv: Interval) -> int:
        other = 1
        for d, s in enumerate(dat.padded_shape):
            if d != self.cfg.tiled_dim:
                other *= s
        return iv.length * other * dat.dtype.itemsize

    # -- planning ---------------------------------------------------------------
    def plan_chain(self, loops: Sequence[ParallelLoop]) -> ChainPlan:
        """Analysis + tile scheduling + engine, memoised on the replay-safe
        ``plan_signature`` (structure, dataset identity, kernel fingerprints)
        plus the planning-relevant config knobs.  Raises ``MemoryError``
        (uncached) when no tile count fits, so ``run_chain`` can split."""
        cfg = self.cfg
        key = (plan_signature(loops, cfg.tiled_dim), cfg.num_tiles,
               cfg.num_slots, float(cfg.capacity),
               tuple(sorted(cfg.pinned)))
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.plan_hits += 1
            return plan
        if key in self._no_fit:   # negative cache: skip the doomed analysis
            raise MemoryError("chain cannot fit (cached verdict); splitting")
        t0 = time.perf_counter()
        try:
            info = analyze_chain(loops, tiled_dim=cfg.tiled_dim)
            pinned_names = self.residency.pinned & frozenset(info.datasets)
            n_tiles = cfg.num_tiles or choose_num_tiles(
                info, int(cfg.capacity), num_slots=cfg.num_slots
            )
            sched = make_tile_schedule(info, n_tiles)
            slot_bytes = sched.slot_bytes(exclude=pinned_names)
            pinned_bytes = sum(info.datasets[n].nbytes for n in pinned_names)
            # Single capacity oracle: the same accounting the real path uses
            # decides whether run_chain must split (raises MemoryError).
            self.residency.check_fit(slot_bytes, pinned_bytes)
        except MemoryError:
            if len(self._no_fit) >= 8 * self._max_plans:
                self._no_fit.clear()
            self._no_fit.add(key)
            raise
        # The engine (and its jit cache) is owned by the plan: sharing engines
        # across chains whose kernels differ only in captured constants would
        # replay stale closures — the fingerprint in ``key`` prevents exactly
        # that, so the plan's engine is always consistent with its kernels.
        plan = ChainPlan(
            key=key, info=info, sched=sched, engine=TileEngine(info),
            slot_bytes=slot_bytes, sig=chain_signature(info),
            plan_s=time.perf_counter() - t0,
            pinned_names=pinned_names, pinned_bytes=pinned_bytes,
        )
        self._plans[key] = plan
        if len(self._plans) > self._max_plans:
            self._plans.popitem(last=False)
        self.plan_misses += 1
        self.plan_time_s += plan.plan_s
        return plan

    @property
    def plan_hit_rate(self) -> float:
        tot = self.plan_hits + self.plan_misses
        return self.plan_hits / tot if tot else 0.0

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        """Stop the transfer engine's worker threads.  Optional (they are
        daemons), but long-lived processes creating many executors should
        call it — or rely on this running at garbage collection."""
        self.transfer.close()

    def __del__(self):  # pragma: no cover - interpreter-shutdown timing
        try:
            self.close()
        except Exception:
            pass

    # -- main entry ------------------------------------------------------------
    def run_chain(self, loops: Sequence[ParallelLoop],
                  keep_live: frozenset = frozenset()) -> Dict[str, np.ndarray]:
        """Run one chain; if no tile count makes its slots fit fast memory
        (skew span exceeding the grid — long chains on small problems), split
        the chain and run the halves sequentially.  This is the runtime
        equivalent of OPS bounding the number of loops tiled across.

        Splitting breaks the §4.1 Cyclic contract: a write-first dat of the
        first half is no longer a dead temporary if the second half reads it,
        so its download cannot be elided — ``keep_live`` carries the dats the
        remainder of the original chain still consumes."""
        try:
            return self._run_chain_tiled(loops, keep_live)
        except MemoryError:
            if len(loops) <= 1:
                raise
            mid = len(loops) // 2
            head, tail = loops[:mid], loops[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            out = self.run_chain(head, keep_live | tail_reads)
            # Both halves may contribute to the same reduction: combine, not
            # overwrite.
            specs = {r.name: r for lp in loops for r in lp.reductions}
            for name, val in self.run_chain(tail, keep_live).items():
                out[name] = (np.asarray(specs[name].combine(out[name], val))
                             if name in out else val)
            return out

    def _run_chain_tiled(self, loops: Sequence[ParallelLoop],
                         keep_live: frozenset = frozenset()) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        td = cfg.tiled_dim
        t_wall = time.perf_counter()
        n_cached = self.plan_hits
        plan = self.plan_chain(loops)
        cache_hit = self.plan_hits > n_cached
        # On a cache hit the recorded loops are interchangeable with the
        # plan's (equal structure, dataset objects, kernel fingerprints);
        # executing the plan's loops keeps the engine's jit cache valid.
        info, sched, engine = plan.info, plan.sched, plan.engine
        slot_bytes = plan.slot_bytes
        sig = plan.sig
        sim = cfg.simulate_only
        tx = self.transfer
        rm = self.residency
        pinned_names = plan.pinned_names
        codecs = resolve_codecs(cfg.codec, tuple(info.datasets))
        tx_before = tx.snapshot()

        def nominal_wire(name: str, nbytes: int) -> int:
            """Modelled post-codec bytes for simulate_only (no data to encode)."""
            if not nbytes:
                return 0
            ratio = codecs[name].nominal_ratio(info.datasets[name].dtype)
            return max(1, int(nbytes / ratio))

        ledger = TransferLedger(cfg.hw)
        # Transfer events are recorded with raw sizes up front (dependency
        # wiring needs the event ids in submission order) and patched with the
        # achieved post-codec wire bytes after the engine drains.
        patches: List[Tuple[int, object, str]] = []

        # ---- pinned datasets: whole-array device residency, cached across
        # chains while the home copy's version is unchanged --------------------
        pinned_arrays: Dict[str, object] = {}
        pinned_origins: Dict[str, int] = {}
        pinned_written: Set[str] = set()
        pin_up_raw = pin_up_wire = 0
        last_upload_eid: Optional[int] = None
        for name in sorted(pinned_names):
            dat = info.datasets[name]
            origin = -dat.halo[td][0]
            hit = rm.pinned_lookup(dat)
            if hit is not None:
                arr, origin = hit
            elif sim:
                arr = _SimArray(dat.nbytes)
                rm.pinned_store(dat, arr, origin)
                pin_up_raw += dat.nbytes
                pin_up_wire += nominal_wire(name, dat.nbytes)
            else:
                dec, raw, wire = codecs[name].roundtrip(dat.data)
                arr = jnp.asarray(np.asarray(dec, dtype=dat.dtype))
                rm.pinned_store(dat, arr, origin)
                pin_up_raw += raw
                pin_up_wire += wire
            pinned_arrays[name] = arr
            pinned_origins[name] = origin
        if pin_up_wire:
            last_upload_eid = ledger.add(
                1, "upload", pin_up_wire, ledger.t_up(pin_up_wire), ())

        # ---- slot pool: LRU-tracked by the residency manager -----------------
        slots = rm.begin_chain(cfg.num_slots)
        if not sim:
            for slot in slots:
                arrays = {}
                for name, ln in sched.max_fp_len.items():
                    if name in pinned_names:
                        continue
                    dat = info.datasets[name]
                    shape = list(dat.padded_shape)
                    shape[td] = ln
                    arrays[name] = jnp.zeros(tuple(shape), dtype=dat.dtype)
                slot.arrays = arrays

        reductions: Dict[str, np.ndarray] = {}
        red_specs = {}
        for lp in info.loops:
            for r in lp.reductions:
                red_specs[r.name] = r

        uploaded = pin_up_raw
        uploaded_wire = pin_up_wire
        downloaded = downloaded_wire = edge_bytes = 0
        prefetch_hits = 0
        num_tiles = sched.num_tiles
        # event ids for stream dependency wiring
        last_compute_eid: Optional[int] = None
        last_download_eid: Dict[int, Optional[int]] = {}  # slot index -> eid
        compute_eids: List[Optional[int]] = [None] * num_tiles
        tile_up_eid: List[Optional[int]] = [None] * num_tiles
        tile_slot: List = [None] * num_tiles
        tile_org: List = [None] * num_tiles
        up_handles: List = [None] * num_tiles

        spec_valid = (
            cfg.prefetch
            and self._spec_sig is not None
            and self._spec_sig == sig
            and bool(self._spec_uploaded)
        )
        # Pipelined submission (tile t+1's upload issued during tile t) needs
        # a second slot to stage into; a 1-slot pool runs strictly in order.
        early_submit = cfg.num_slots >= 2

        def spec_lookup(name, iv):
            """Resolve a speculative-prefetch hit for upload piece ``iv``.

            Returns ``(miss_part, restore)``: the sub-interval still needing a
            home upload, and — on real data-plane runs — the captured device
            array to copy into the slot for the hit part.  A capture whose
            dataset identity/version no longer matches home degrades to a
            full miss."""
            nonlocal prefetch_hits
            pre = self._spec_uploaded.get(name, ())
            for j, piv in enumerate(pre):
                hit = iv.intersect(piv)
                if hit.empty or hit.lo != iv.lo:
                    continue
                if sim:
                    prefetch_hits += 1
                    return Interval(hit.hi, iv.hi), None
                ents = self._spec_data.get(name, ())
                ent = ents[j] if j < len(ents) else None
                dat = info.datasets[name]
                if (ent is not None and ent[0] == piv and ent[2] == id(dat)
                        and ent[3] == dat.version):
                    prefetch_hits += 1
                    return Interval(hit.hi, iv.hi), (name, hit, ent[1], piv.lo)
                return iv, None  # stale capture: stage everything from home
            return iv, None

        def upload_plan(t):
            """Pieces tile t stages up (cold-clamped, prefetch-adjusted)."""
            tile = sched.tiles[t]
            org = {name: iv.lo for name, iv in tile.footprint.items()
                   if not iv.empty}
            items: List[Tuple[str, Interval]] = []
            restores: List[Tuple] = []
            raw = 0
            conflicts: List = []
            for name, pieces in tile.upload.items():
                if name in pinned_names:
                    continue    # whole-array resident: never staged per tile
                if name in info.write_first:
                    # §4.1: write-first data never uploads — except rows the
                    # chain reads before any write reaches them (halo skirts):
                    # those are genuinely consumed from home (cold reads).
                    cold = info.cold.get(name, [])
                    pieces = tuple(
                        p
                        for iv in pieces
                        for p in (iv.clamp(clo, chi) for clo, chi in cold)
                        if not p.empty
                    )
                for iv in pieces:
                    if iv.empty:
                        continue
                    use = iv
                    if spec_valid and t == 0:
                        use, restore = spec_lookup(name, iv)
                        if restore is not None:
                            restores.append(restore)
                    if use.empty:
                        continue
                    raw += self._nbytes(info.datasets[name], use)
                    items.append((name, use))
                    # Home rows a still-pending download is writing back must
                    # land before this staging read (cross-tile safety net;
                    # the footprint algebra keeps these disjoint in practice).
                    conflicts.extend(rm.home_conflicts(name, use.lo, use.hi))
            return org, items, restores, raw, conflicts

        def make_upload_task(slot, org, items, restores=()):
            def task():
                raw = wire = 0
                # Prefetch restores: device-resident captures from the last
                # chain's speculative upload — no link traffic (it was
                # charged as the prefetch event back then).
                for name, hit, arr, arr_lo in restores:
                    vals = arr[self._slot_slice(
                        arr, hit.lo - arr_lo, hit.hi - arr_lo, td)]
                    lo, hi = _region_to_slot(hit, org[name])
                    with slot.lock:
                        dst = slot.arrays[name]
                        slot.arrays[name] = dst.at[
                            self._slot_slice(dst, lo, hi, td)
                        ].set(vals)
                for name, use in items:
                    dat = info.datasets[name]
                    chunk = self._dat_np_region(dat, use)
                    dec, r, w = codecs[name].roundtrip(chunk)
                    raw += r
                    wire += w
                    vals = jnp.asarray(np.asarray(dec, dtype=dat.dtype))
                    lo, hi = _region_to_slot(use, org[name])
                    # Disjoint-region updates commute, but the functional
                    # read-modify-write of the slot's dict entry must be
                    # atomic against the main thread's edge copy.
                    with slot.lock:
                        arr = slot.arrays[name]
                        slot.arrays[name] = arr.at[
                            self._slot_slice(arr, lo, hi, td)
                        ].set(vals)
                return raw, wire
            return task

        def submit_upload(t):
            """Acquire tile t's slot and queue its staging task.

            Per-tile transfers COALESCE into one task/ledger event per
            direction (one staging pass per tile — at real scale per-dat
            latencies are noise; at scaled-down bench sizes they would
            dominate falsely)."""
            nonlocal last_upload_eid, uploaded, uploaded_wire
            slot = rm.acquire()
            org, items, restores, raw, conflicts = upload_plan(t)
            slot.origins = org
            tile_slot[t] = slot
            tile_org[t] = org
            if not raw and not restores:
                return
            up_deps = []
            if last_download_eid.get(slot.index) is not None:
                up_deps.append(last_download_eid[slot.index])  # slot reuse fence
            if last_upload_eid is not None:
                up_deps.append(last_upload_eid)                # stream-1 FIFO
            if sim:
                uploaded += raw
                wire = sum(
                    nominal_wire(name, self._nbytes(info.datasets[name], use))
                    for name, use in items)
                uploaded_wire += wire
                eid = ledger.add(1, "upload", wire, ledger.t_up(wire),
                                 tuple(up_deps))
            else:
                handle = tx.submit(UP,
                                   make_upload_task(slot, org, items, restores),
                                   deps=conflicts)
                up_handles[t] = handle
                for name, use in items:
                    rm.note_home_read(name, use.lo, use.hi, handle)
                if not raw:
                    # Pure prefetch restore: device-side only, no link event
                    # (the traffic was charged as last chain's prefetch).
                    return
                uploaded += raw
                eid = ledger.add(1, "upload", raw, ledger.t_up(raw),
                                 tuple(up_deps))
                patches.append((eid, handle, UP))
            tile_up_eid[t] = eid
            last_upload_eid = eid

        def make_download_task(arrays, org, items):
            def task():
                raw = wire = 0
                for name, iv in items:
                    dat = info.datasets[name]
                    lo, hi = _region_to_slot(iv, org[name])
                    arr = arrays[name]
                    vals = np.asarray(arr[self._slot_slice(arr, lo, hi, td)])
                    dec, r, w = codecs[name].roundtrip(vals)
                    raw += r
                    wire += w
                    self._write_np_region(dat, iv, np.asarray(dec, dat.dtype))
                return raw, wire
            return task

        submit_upload(0)
        for t, tile in enumerate(sched.tiles):
            slot = tile_slot[t]
            org = tile_org[t]

            # ---- preparation phase: tile t's staging must have landed -------
            if up_handles[t] is not None:
                up_handles[t].wait()
            # Algorithm 1: issue tile t+1's upload now, so in threaded mode it
            # genuinely overlaps this tile's compute (the ledger wires the
            # same overlap into the modelled timeline either way).
            if t + 1 < num_tiles and early_submit:
                submit_upload(t + 1)

            # ---- execution phase -------------------------------------------
            comp_deps = []
            if tile_up_eid[t] is not None:
                comp_deps.append(tile_up_eid[t])
            if last_compute_eid is not None:
                comp_deps.append(last_compute_eid)
            tile_bytes = 0
            tile_flops = 0
            for k, box in enumerate(tile.loop_ranges):
                if box is None:
                    continue
                npts = 1
                for a, b in box:
                    npts *= b - a
                lp = info.loops[k]
                full_pts = 1
                for a, b in lp.range_:
                    full_pts *= b - a
                frac = npts / full_pts
                tile_bytes += int(lp.bytes_moved() * frac)
                tile_flops += int(lp.flops(cfg.flops_per_point) * frac)
            if not sim:
                run_arrays = {**slot.arrays, **pinned_arrays}
                run_origins = {**org, **pinned_origins}
                new_arrays, tile_reds = engine.run_tile(tile, run_arrays, run_origins)
                for name in pinned_arrays:
                    pinned_arrays[name] = new_arrays[name]
                    rm.pinned_update(info.datasets[name], new_arrays[name])
                slot.arrays = {n: a for n, a in new_arrays.items()
                               if n not in pinned_arrays}
                for name, val in tile_reds.items():
                    spec = red_specs[name]
                    if name in reductions:
                        reductions[name] = np.asarray(
                            spec.combine(reductions[name], val))
                    else:
                        reductions[name] = np.asarray(val)
            last_compute_eid = ledger.add(
                0, "compute", tile_bytes, ledger.t_compute(tile_bytes, tile_flops),
                tuple(comp_deps),
            )
            compute_eids[t] = last_compute_eid
            # Residency bookkeeping: rows this tile wrote stay dirty until a
            # download, an edge carry, or a §4.1 elision retires them — the
            # manager refuses slot reuse (and chain end) while any survive.
            for k, box in enumerate(tile.loop_ranges):
                if box is None:
                    continue
                lo_w, hi_w = box[td]
                for arg in info.loops[k].args:
                    if not arg.mode.writes:
                        continue
                    if arg.dat.name in pinned_names:
                        pinned_written.add(arg.dat.name)
                    else:
                        rm.mark_dirty(slot, arg.dat.name, lo_w, hi_w)

            # ---- finishing phase --------------------------------------------
            def do_edge():
                """Edge copy: right edge of tile t -> slot of tile t+1."""
                nonlocal edge_bytes, last_compute_eid
                if t + 1 >= num_tiles:
                    return
                next_slot = tile_slot[t + 1]
                if next_slot is None:
                    # 1-slot pool (late submit): tile t+1 continues in this
                    # very slot — rebase from this tile's origins to the next
                    # tile's BEFORE its upload lands in the rebased positions.
                    next_slot = slot
                    next_org = {
                        name: iv.lo
                        for name, iv in sched.tiles[t + 1].footprint.items()
                        if not iv.empty
                    }
                else:
                    next_org = tile_org[t + 1]
                edge_deps = [last_compute_eid]
                if last_download_eid.get(next_slot.index) is not None:
                    edge_deps.append(last_download_eid[next_slot.index])
                tile_edge_bytes = 0
                for name, iv in tile.edge_to_next.items():
                    if iv.empty or name not in next_org or name in pinned_names:
                        continue
                    if not sim:
                        src_lo, src_hi = _region_to_slot(iv, org[name])
                        dst_lo, dst_hi = _region_to_slot(iv, next_org[name])
                        src = slot.arrays[name]
                        vals = src[self._slot_slice(src, src_lo, src_hi, td)]
                        with next_slot.lock:
                            dst = next_slot.arrays[name]
                            next_slot.arrays[name] = dst.at[
                                self._slot_slice(dst, dst_lo, dst_hi, td)
                            ].set(vals)
                    rm.carry(slot, next_slot, name, iv.lo, iv.hi)
                    tile_edge_bytes += self._nbytes(info.datasets[name], iv)
                if tile_edge_bytes:
                    edge_bytes += tile_edge_bytes
                    last_compute_eid = ledger.add(
                        0, "edge", tile_edge_bytes,
                        ledger.t_dd(2 * tile_edge_bytes), tuple(edge_deps))

            def do_downloads():
                """Download the left footprint of modified datasets."""
                nonlocal downloaded, downloaded_wire
                dn_deps = [compute_eids[t]]
                items: List[Tuple[str, Interval]] = []
                raw = 0
                for name, pieces in tile.download.items():
                    if name in pinned_names or name in info.read_only:
                        continue  # never written / flushed once at chain end
                    if (cfg.cyclic and name in info.write_first
                            and name not in keep_live):
                        # §4.1 Cyclic: temporaries stay on device — no
                        # traffic, but the residency books must balance.
                        for iv in pieces:
                            if not iv.empty:
                                rm.elide(slot, name, iv.lo, iv.hi)
                        continue
                    for iv in pieces:
                        if iv.empty:
                            continue
                        raw += self._nbytes(info.datasets[name], iv)
                        items.append((name, iv))
                if not raw:
                    return
                downloaded += raw
                if sim:
                    wire = sum(
                        nominal_wire(name, self._nbytes(info.datasets[name], iv))
                        for name, iv in items)
                    downloaded_wire += wire
                    eid = ledger.add(2, "download", wire, ledger.t_down(wire),
                                     tuple(dn_deps))
                    for name, iv in items:
                        rm.writeback(slot, name, iv.lo, iv.hi)
                else:
                    # Snapshot the arrays: a later tile's upload functionally
                    # replaces dict entries, never the captured values.  The
                    # home write must also wait for earlier-queued uploads
                    # still reading overlapping home rows (tile t+1's upload
                    # is submitted before tile t's download).
                    read_deps = [
                        h for name, iv in items
                        for h in rm.home_read_conflicts(name, iv.lo, iv.hi)]
                    handle = tx.submit(
                        DOWN, make_download_task(dict(slot.arrays), org, items),
                        deps=read_deps)
                    eid = ledger.add(2, "download", raw, ledger.t_down(raw),
                                     tuple(dn_deps))
                    patches.append((eid, handle, DOWN))
                    for name, iv in items:
                        rm.writeback(slot, name, iv.lo, iv.hi, handle)
                last_download_eid[slot.index] = eid

            if early_submit:
                do_edge()
                do_downloads()
            else:
                # 1-slot pool: retire this tile before staging the next one
                # into the same (continuing) slot.
                do_downloads()
                do_edge()
                if t + 1 < num_tiles:
                    submit_upload(t + 1)

            # Speculative prefetch (§4.1): during the last tile, upload the
            # next chain's assumed first tile (assume it mirrors this chain).
            if cfg.prefetch and t == num_tiles - 1:
                first = sched.tiles[0]
                nb_total = 0
                self._spec_uploaded = {}
                for name, pieces in first.upload.items():
                    if name in info.write_first or name in pinned_names:
                        continue
                    live = tuple(iv for iv in pieces if not iv.empty)
                    if not live:
                        continue
                    self._spec_uploaded[name] = live
                    # Charge at nominal post-codec size so prefetch traffic
                    # is priced consistently with the uploads it replaces.
                    nb_total += sum(
                        nominal_wire(name, self._nbytes(info.datasets[name], iv))
                        for iv in live)
                if nb_total:
                    # Overlaps the last compute on stream 1.
                    ledger.add(1, "prefetch", nb_total, ledger.t_up(nb_total),
                               (last_upload_eid,) if last_upload_eid is not None else ())
                self._spec_sig = sig

        tx.drain()
        # Patch transfer events with the achieved wire bytes (codec output is
        # data-dependent, so threaded tasks only report it after the fact).
        # ``ledger.totals`` accumulated the raw estimate at submission and
        # must shift by the same delta to stay consistent with the events.
        for eid, handle, direction in patches:
            _, wire = handle.result
            ev = ledger.events[eid]
            ledger.totals[ev.kind] = ledger.totals.get(ev.kind, 0) + wire - ev.nbytes
            ev.nbytes = wire
            ev.duration = (ledger.t_up(wire) if direction == UP
                           else ledger.t_down(wire))
            if direction == UP:
                uploaded_wire += wire
            else:
                downloaded_wire += wire

        # Speculative-prefetch data capture (real data plane): home is stable
        # now that downloads have drained, so snapshot the regions the next
        # chain's first tile is assumed to upload.  ``jnp.array`` copies —
        # the capture must not alias home rows a later chain will overwrite.
        if cfg.prefetch and not sim:
            self._spec_data = {}
            for name, ivs in self._spec_uploaded.items():
                dat = info.datasets.get(name)
                if dat is None:
                    continue
                self._spec_data[name] = [
                    (iv, jnp.array(self._dat_np_region(dat, iv)), id(dat),
                     dat.version)
                    for iv in ivs]

        # Pinned flush: written pinned datasets ship home once per chain.
        pin_dn_raw = pin_dn_wire = 0
        for name in sorted(pinned_written):
            dat = info.datasets[name]
            rows = info.written.get(name, [])
            if sim:
                nb = sum(self._nbytes(dat, Interval(lo, hi)) for lo, hi in rows)
                pin_dn_raw += nb
                pin_dn_wire += nominal_wire(name, nb)
            else:
                arr = pinned_arrays[name]
                origin = pinned_origins[name]
                for lo, hi in rows:
                    vals = np.asarray(arr[self._slot_slice(
                        arr, lo - origin, hi - origin, td)])
                    dec, r, w = codecs[name].roundtrip(vals)
                    pin_dn_raw += r
                    pin_dn_wire += w
                    self._write_np_region(dat, Interval(lo, hi),
                                          np.asarray(dec, dat.dtype))
            rm.pinned_mark_flushed(dat)
        if pin_dn_wire:
            downloaded += pin_dn_raw
            downloaded_wire += pin_dn_wire
            ledger.add(2, "download", pin_dn_wire, ledger.t_down(pin_dn_wire),
                       (last_compute_eid,) if last_compute_eid is not None else ())
        rm.end_chain()

        makespan = ledger.simulate()
        wall = time.perf_counter() - t_wall
        loop_bytes = info.loop_bytes()
        tx_delta = tx.delta(tx.snapshot(), tx_before)
        raw_total = uploaded + downloaded
        wire_total = uploaded_wire + downloaded_wire
        self.history.append(
            ChainStats(
                num_tiles=sched.num_tiles,
                loop_bytes=loop_bytes,
                uploaded=uploaded,
                downloaded=downloaded,
                edge_bytes=edge_bytes,
                prefetch_hits=prefetch_hits,
                wall_s=wall,
                modelled_s=makespan,
                achieved_bw_model=loop_bytes / makespan if makespan else 0.0,
                slot_bytes=slot_bytes,
                plan_cache_hit=cache_hit,
                plan_s=0.0 if cache_hit else plan.plan_s,
                uploaded_wire=uploaded_wire,
                downloaded_wire=downloaded_wire,
                compression_ratio=raw_total / wire_total if wire_total else 1.0,
                queue_wait_s=tx_delta.get("queue_wait_s", 0.0),
                transfer_mode=tx.mode,
            )
        )
        return reductions

    # -- aggregate metrics -----------------------------------------------------
    def average_bandwidth_model(self) -> float:
        """The paper's 'Average Bandwidth' over everything run so far."""
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0

    def transfer_stats(self) -> Dict[str, float]:
        """Transfer-subsystem totals over everything run so far: raw vs wire
        bytes each direction, the achieved compression ratio, and queue-wait
        (submit-to-start latency; real queueing in threaded mode, a few
        microseconds of inline dispatch overhead per task in sync mode)."""
        up_raw = sum(c.uploaded for c in self.history)
        dn_raw = sum(c.downloaded for c in self.history)
        up_wire = sum(c.uploaded_wire for c in self.history)
        dn_wire = sum(c.downloaded_wire for c in self.history)
        wire = up_wire + dn_wire
        rs = self.residency.stats
        return {
            "mode": self.transfer.mode,
            "bytes_up_raw": up_raw,
            "bytes_down_raw": dn_raw,
            "bytes_up_wire": up_wire,
            "bytes_down_wire": dn_wire,
            "bytes_moved_wire": wire,
            "compression_ratio": (up_raw + dn_raw) / wire if wire else 1.0,
            "queue_wait_s": sum(c.queue_wait_s for c in self.history),
            "elided_rows": rs["elided_rows"],
            "evictions": rs["evictions"],
            "pinned_hits": rs["pinned_hits"],
        }


class ResidentExecutor:
    """Paper baseline: all datasets live in fast memory for the whole run.

    Implemented as the 1-tile schedule with an up-front capacity check; the
    ledger charges one initial upload per dataset (amortised across chains:
    subsequent chains reuse resident data, as in the paper's setup) and no
    per-chain traffic.
    """

    def __init__(self, hw: HardwareModel = TPU_V5E, capacity_bytes: Optional[float] = None):
        self.hw = hw
        self.capacity = capacity_bytes if capacity_bytes is not None else hw.fast_capacity
        self._resident: Set[str] = set()
        self._resident_bytes = 0
        self._inner = OutOfCoreExecutor(
            OOCConfig(hw=hw, capacity_bytes=float("inf"), num_tiles=1, num_slots=1)
        )
        self.history = self._inner.history

    def run_chain(self, loops: Sequence[ParallelLoop]) -> Dict[str, np.ndarray]:
        # Capacity check needs only the touched-dataset set — enumerating
        # args directly keeps the inner planner's cache stats honest (one
        # plan per chain, not a self-inflicted hit per run).
        for lp in loops:
            for arg in lp.args:
                if arg.dat.name not in self._resident:
                    self._resident.add(arg.dat.name)
                    self._resident_bytes += arg.dat.nbytes
        if self._resident_bytes > self.capacity:
            raise MemoryError(
                f"resident set {self._resident_bytes}B exceeds fast memory "
                f"{self.capacity}B — the paper's segfault, reproduced politely"
            )
        reds = self._inner.run_chain(loops)
        # Resident baseline: per-chain link traffic doesn't apply; replace the
        # modelled time with pure compute time.
        last = self.history[-1]
        ledger = TransferLedger(self.hw)
        t = ledger.t_compute(last.loop_bytes, 0)
        last.modelled_s = max(t, 1e-30)
        last.achieved_bw_model = last.loop_bytes / last.modelled_s
        return reds

    # plan-cache stats proxy to the inner executor (shared planner)
    @property
    def plan_hits(self) -> int:
        return self._inner.plan_hits

    @property
    def plan_misses(self) -> int:
        return self._inner.plan_misses

    @property
    def plan_time_s(self) -> float:
        return self._inner.plan_time_s

    @property
    def plan_hit_rate(self) -> float:
        return self._inner.plan_hit_rate

    def transfer_stats(self) -> Dict[str, float]:
        return self._inner.transfer_stats()

    def average_bandwidth_model(self) -> float:
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0
