"""Out-of-core executors (the paper's §4, Algorithm 1).

``OutOfCoreExecutor`` — explicit memory management with three slots:
while tile *t* executes (stream 0), tile *t+1*'s right footprint uploads
(stream 1) and tile *t−1*'s left footprint downloads (stream 2); after each
tile the right edge is copied device-side into the next slot.  Transfer
elision per §4.1: read-only datasets never download, write-first datasets
never upload, Cyclic additionally skips the download of write-first
temporaries, and speculative prefetch uploads the *next* chain's first tile
during the current chain's last tile.

Since the Plan-IR redesign the executor is a thin planner/interpreter pair:

* :meth:`plan_chain` lowers a chain to an explicit, typed instruction
  stream (:class:`~repro.core.plan.Plan`) via dependency analysis + skewed
  tile scheduling + :func:`~repro.core.plan.build_plan`, memoised on the
  replay-safe ``plan_signature`` plus every planning-relevant config knob.
* :meth:`run_chain` hands that stream to one of the two interpreters in
  :mod:`repro.core.interp`: the ledger interpreter (``simulate_only`` —
  modelled timeline, no data) or the data-plane interpreter (real slot
  arrays, transfer-engine staging, codecs, compiled tiles).  Both execute
  the *same* ops, so simulated and real runs cannot drift apart.

``ResidentExecutor`` — the paper's baseline: everything resident in fast
memory for the whole run (raises, like the paper's segfault, if it can't fit).

Data plane: home copies are NumPy (slow memory); slots are JAX device arrays;
uploads/downloads go through ``jnp.asarray``/``np.asarray`` so the data path
is real on every backend, while *timings* for the paper's platforms come from
the calibrated :class:`~repro.core.memory.HardwareModel` ledger.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from .dependency import (ChainInfo, analyze_chain, chain_signature,
                         plan_signature, shared_plan_signature)
from .engine import TileEngine
from .interp import DataPlaneInterpreter, LedgerInterpreter, SpecState
from .loop import ParallelLoop
from .memory import HardwareModel, TPU_V5E, TransferLedger
from .plan import Plan, build_plan
from .tiling import TileSchedule, choose_num_tiles, make_tile_schedule
from .transfer import ResidencyManager, TransferEngine, resolve_codecs
from ..obs.tracer import AnyTracer, as_tracer


@dataclass
class OOCConfig:
    hw: HardwareModel = TPU_V5E
    capacity_bytes: Optional[float] = None   # default: hw.fast_capacity
    num_slots: int = 3
    num_tiles: Optional[int] = None          # default: smallest that fits
    tiled_dim: int = 0
    cyclic: bool = False                     # §4.1 unsafe temporaries opt
    prefetch: bool = False                   # §4.1 speculative prefetch
    flops_per_point: Optional[int] = None    # compute model override
    # Ledger interpreter only — no data plane.  For modelled benchmarks at
    # scaled-down sizes (correctness is covered by the executing tests).
    simulate_only: bool = False
    # -- transfer subsystem knobs --------------------------------------------
    transfer: str = "sync"                   # "sync" | "threaded"
    codec: Union[str, Dict[str, str]] = "identity"   # name or {dat: name, "*": ...}
    pinned: Tuple[str, ...] = ()             # datasets kept device-resident
    # -- host tier (repro.core.store) ----------------------------------------
    # Host-RAM budget for dataset home copies; chains whose working set
    # exceeds it get FetchHome/SpillHome ops against the disk-backed stores.
    host_capacity: Optional[float] = None    # default: hw.host_capacity
    # Statically verify every plan before interpreting it
    # (repro.core.verify); error-severity diagnostics raise
    # PlanVerificationError instead of executing a corrupting stream.
    debug: bool = False
    # -- observability (repro.obs) -------------------------------------------
    # True mints a fresh span Tracer; an existing Tracer shares one spine
    # across executors (the sharded mesh and serve lanes do this).  Off by
    # default: the hot path then pays one attribute check per chain/op.
    trace: object = None                     # None/False | True | obs.Tracer

    @property
    def capacity(self) -> float:
        return self.capacity_bytes if self.capacity_bytes is not None else self.hw.fast_capacity

    @property
    def host_budget(self) -> float:
        return (self.host_capacity if self.host_capacity is not None
                else self.hw.host_capacity)

    def codec_key(self) -> Tuple:
        """Hashable form of the codec spec (plan wire bytes depend on it)."""
        if isinstance(self.codec, dict):
            return tuple(sorted(self.codec.items()))
        return (self.codec,)


@dataclass
class ChainStats:
    num_tiles: int
    loop_bytes: int            # the paper's 'useful bytes' for avg-BW metric
    uploaded: int              # raw (uncompressed) bytes staged up
    downloaded: int            # raw (uncompressed) bytes staged down
    edge_bytes: int
    prefetch_hits: int
    wall_s: float
    modelled_s: float
    achieved_bw_model: float   # loop_bytes / modelled makespan
    slot_bytes: int
    plan_cache_hit: bool = False   # chain plan replayed from cache
    plan_s: float = 0.0            # analysis + scheduling time (0 on hits)
    # -- transfer subsystem --------------------------------------------------
    uploaded_wire: int = 0         # post-codec bytes the link carried up
    downloaded_wire: int = 0       # post-codec bytes the link carried down
    compression_ratio: float = 1.0  # raw / wire over both directions
    queue_wait_s: float = 0.0      # submit-to-start latency summed over tasks
    transfer_mode: str = "sync"
    # -- plan IR -------------------------------------------------------------
    # Per-kind op counts straight from the chain's instruction stream
    # (uploads/downloads/carries/elisions/evictions/...), so benchmarks
    # report plan structure without re-deriving it from ledger events.
    op_counts: Dict[str, int] = field(default_factory=dict)
    # -- disk tier (repro.core.store) ----------------------------------------
    # Bytes that crossed the disk boundary this chain: the backing stores'
    # achieved counters on data-plane runs (all traffic, including lazy
    # chunk-cache misses), the FetchHome/SpillHome modelled bytes in sim mode.
    disk_read: int = 0
    disk_written: int = 0
    # -- device mesh (repro.core.sharded) ------------------------------------
    # Halo-exchange traffic this chain's plan carried (messages/bytes landing
    # in this device's skirts; aggregated over devices by the sharded
    # executor).  Zero for unsharded chains.
    halo_messages: int = 0
    halo_bytes: int = 0


@dataclass
class ChainPlan:
    """The memoised product of dependency analysis + tile scheduling + the
    compiled tile engine + the lowered instruction stream for one chain
    signature.  Cyclic loop chains (CloverLeaf/OpenSBLI timesteps) are
    structurally identical across steps, so every flush after the first
    replays one of these instead of paying ``analyze_chain`` +
    ``make_tile_schedule`` + ``build_plan`` + jit-cache lookup again."""

    key: Tuple
    info: ChainInfo
    sched: TileSchedule
    engine: TileEngine
    slot_bytes: int     # per-slot bytes, pinned datasets excluded
    sig: Tuple          # structural chain_signature (prefetch guessing)
    plan_s: float       # construction cost (what cache hits save)
    ir: Plan = None                         # the typed instruction stream
    pinned_names: frozenset = frozenset()   # pinned datasets this chain touches
    pinned_bytes: int = 0                   # their whole-array residency cost


class OutOfCoreExecutor:
    """Explicitly-managed 3-slot streaming executor (Algorithm 1)."""

    def __init__(self, config: OOCConfig = None, *, shared_plans=None):
        self.cfg = config or OOCConfig()
        # LRU-bounded: kernels capturing a per-step constant (a real dt
        # changing every step) legitimately produce a new plan per flush —
        # without a bound a long run would accumulate engines/ChainInfos
        # (and their jit caches) without limit.
        self._plans: "OrderedDict[Tuple, ChainPlan]" = OrderedDict()
        self._max_plans = 32
        self._no_fit: set = set()   # keys known to raise MemoryError
        self.plan_hits = 0
        self.plan_misses = 0
        self.plan_time_s = 0.0
        # Optional cross-executor plan cache (repro.serve.SharedPlanCache):
        # consulted on a local miss under the tenant-neutral signature, fed
        # on every build.  ``tenant`` attributes lookups for the serving
        # layer's cross-tenant hit counters; executors outside a server
        # leave both None.
        self.shared_plans = shared_plans
        self.tenant: Optional[str] = None
        # The transfer subsystem: engine (worker threads or sync fallback)
        # and residency manager (slot pool, dirty tracking, pinned cache,
        # capacity accounting) are executor-lifetime so pinned device arrays
        # and transfer statistics persist across chains.
        self.transfer = TransferEngine(mode=self.cfg.transfer)
        self.residency = ResidencyManager(
            capacity_bytes=self.cfg.capacity, num_slots=self.cfg.num_slots,
            pinned=frozenset(self.cfg.pinned))
        # Cross-chain speculative-prefetch state (shared by both interpreters).
        self._spec = SpecState()
        # Collective halo-exchange hook: a mesh-owning parent executor
        # (repro.core.sharded) installs a callable here so this executor's
        # data-plane interpreter can run HaloExchange ops for real.
        self.halo_runtime = None
        self.history: List[ChainStats] = []
        # Observability spine (repro.obs): a mesh/serve parent may overwrite
        # both to share one tracer and prefix this executor's tracks.
        self.tracer: AnyTracer = as_tracer(self.cfg.trace)
        self.trace_tag: str = ""
        # Per-chain ledgers, retained only while tracing — the drift audit
        # needs each chain's modelled timeline next to its achieved spans.
        self.ledgers: List[TransferLedger] = []

    # -- planning ---------------------------------------------------------------
    def plan_chain(self, loops: Sequence[ParallelLoop],
                   keep_live: frozenset = frozenset(),
                   halo=None, *, warm: frozenset = frozenset()) -> ChainPlan:
        """Analysis + tile scheduling + engine + the lowered Plan IR,
        memoised on the replay-safe ``plan_signature`` (structure, dataset
        identity, kernel fingerprints) plus the planning-relevant config
        knobs.  ``keep_live`` names datasets a split chain's remainder still
        reads (they may not be elided), and is part of the cache key because
        the §4.1 elision decisions are baked into the instruction stream.
        ``halo`` (a :class:`~repro.core.mesh.HaloSpec`, sharded execution)
        stamps the plan with its device-mesh position and places the
        once-per-chain halo exchange at the head of the op stream.  ``warm``
        names write-first dats that must stage anyway — a segmented chain's
        earlier segment landed real home data the §4.1 upload elision would
        let this segment's download clobber.
        Raises ``MemoryError`` (uncached) when no tile count fits, so
        ``run_chain`` can split."""
        cfg = self.cfg
        key = (plan_signature(loops, cfg.tiled_dim), cfg.num_tiles,
               cfg.num_slots, float(cfg.capacity), float(cfg.host_budget),
               tuple(sorted(cfg.pinned)), bool(cfg.cyclic),
               bool(cfg.prefetch), cfg.codec_key(), cfg.flops_per_point,
               tuple(sorted(keep_live)), halo, tuple(sorted(warm)))
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.plan_hits += 1
            return plan
        if key in self._no_fit:   # negative cache: skip the doomed analysis
            raise MemoryError("chain cannot fit (cached verdict); splitting")
        shared_key = None
        if self.shared_plans is not None:
            # Same config knobs, tenant-neutral dataset identity: a plan
            # another executor (or tenant) built for an isomorphic chain
            # replays here once its ChainInfo is rebound to our datasets.
            shared_key = (shared_plan_signature(loops, cfg.tiled_dim),) + key[1:]
            cached = self.shared_plans.lookup(shared_key, self.tenant)
            if cached is not None:
                adopted = self._adopt_shared(cached, loops, key)
                if adopted is not None:
                    self._plans[key] = adopted
                    if len(self._plans) > self._max_plans:
                        self._plans.popitem(last=False)
                    self.plan_hits += 1
                    return adopted
        t0 = time.perf_counter()
        try:
            info = analyze_chain(loops, tiled_dim=cfg.tiled_dim)
            pinned_names = self.residency.pinned & frozenset(info.datasets)
            n_tiles = cfg.num_tiles or choose_num_tiles(
                info, cfg.capacity, num_slots=cfg.num_slots
            )
            sched = make_tile_schedule(info, n_tiles)
            slot_bytes = sched.slot_bytes(exclude=pinned_names)
            pinned_bytes = sum(info.datasets[n].nbytes for n in pinned_names)
            # Single capacity oracle for BOTH tiers: fast-memory overflow
            # raises (run_chain answers by splitting); host-RAM overflow is
            # a planning verdict — the chain's home working set spills to
            # the disk tier via FetchHome/SpillHome ops instead of dying.
            home_bytes = sum(d.nbytes for d in info.datasets.values())
            self.residency.check_fit(slot_bytes, pinned_bytes)
            spill_home = self.residency.host_overflow(home_bytes,
                                                      cfg.host_budget)
        except MemoryError:
            if len(self._no_fit) >= 8 * self._max_plans:
                self._no_fit.clear()
            self._no_fit.add(key)
            raise
        ir = build_plan(
            info, sched, num_slots=cfg.num_slots, cyclic=cfg.cyclic,
            prefetch=cfg.prefetch, spill_home=spill_home,
            keep_live=frozenset(keep_live), warm=frozenset(warm),
            pinned_names=pinned_names, codec_spec=cfg.codec,
            flops_per_point=cfg.flops_per_point, slot_bytes=slot_bytes,
            pinned_bytes=pinned_bytes, halo=halo,
        )
        # The engine (and its jit cache) is owned by the plan: sharing engines
        # across chains whose kernels differ only in captured constants would
        # replay stale closures — the fingerprint in ``key`` prevents exactly
        # that, so the plan's engine is always consistent with its kernels.
        plan = ChainPlan(
            key=key, info=info, sched=sched, engine=TileEngine(info),
            slot_bytes=slot_bytes, sig=chain_signature(info),
            plan_s=time.perf_counter() - t0, ir=ir,
            pinned_names=pinned_names, pinned_bytes=pinned_bytes,
        )
        self._plans[key] = plan
        if len(self._plans) > self._max_plans:
            self._plans.popitem(last=False)
        self.plan_misses += 1
        self.plan_time_s += plan.plan_s
        if shared_key is not None:
            self.shared_plans.insert(shared_key, plan, self.tenant)
        return plan

    def _adopt_shared(self, cp: ChainPlan, loops: Sequence[ParallelLoop],
                      key: Tuple) -> Optional[ChainPlan]:
        """Rebind a shared-cache ChainPlan to this chain's datasets.

        The Plan IR, tile schedule and engine reference datasets by *name*
        (the engine additionally closes over the donor chain's kernels, which
        the shared signature guarantees are value-identical to ours), so a
        shallow copy with ``info.datasets`` swapped to our Dataset objects is
        a complete rebind.  Sharing the engine is the point: the adopter
        reuses the donor's jit cache.  Returns None if the dataset name sets
        somehow disagree (signature collision paranoia — build fresh)."""
        dats = {}
        for lp in loops:
            for a in lp.args:
                dats.setdefault(a.dat.name, a.dat)
        if set(dats) != set(cp.info.datasets):
            return None
        if all(dats[n] is d for n, d in cp.info.datasets.items()):
            info = cp.info            # same tenant, different executor/lane
        else:
            info = replace(cp.info, datasets=dats)
        return ChainPlan(
            key=key, info=info, sched=cp.sched, engine=cp.engine,
            slot_bytes=cp.slot_bytes, sig=cp.sig, plan_s=0.0, ir=cp.ir,
            pinned_names=cp.pinned_names, pinned_bytes=cp.pinned_bytes,
        )

    @property
    def plan_hit_rate(self) -> float:
        tot = self.plan_hits + self.plan_misses
        return self.plan_hits / tot if tot else 0.0

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        """Stop the transfer engine's worker threads.  Optional (they are
        daemons), but long-lived processes creating many executors should
        call it — or rely on this running at garbage collection."""
        self.transfer.close()

    def reset_data_caches(self) -> None:
        """Forget device-side cached *data* (pinned arrays, speculative
        prefetch captures) after home copies changed underneath the executor
        — ``Session.restore`` calls this so a resumed run cannot replay
        device state from before the checkpoint.  Plan caches survive: plans
        are data-independent."""
        self.residency._pinned_cache.clear()
        self._spec = SpecState()

    def __del__(self):  # pragma: no cover - interpreter-shutdown timing
        try:
            self.close()
        except Exception:
            pass

    # -- main entry ------------------------------------------------------------
    def run_chain(self, loops: Sequence[ParallelLoop],
                  keep_live: frozenset = frozenset(), *,
                  plan: Optional[Plan] = None,
                  halo=None,
                  warm: frozenset = frozenset()) -> Dict[str, np.ndarray]:
        """Plan one chain and interpret its instruction stream; if no tile
        count makes its slots fit fast memory (skew span exceeding the grid —
        long chains on small problems), split the chain and run the halves
        sequentially.  This is the runtime equivalent of OPS bounding the
        number of loops tiled across.

        ``plan`` replays an explicit (e.g. JSON-imported) instruction stream
        instead of the freshly-planned one; its signature hash must match
        the chain's.

        Splitting breaks the §4.1 Cyclic contract: a write-first dat of the
        first half is no longer a dead temporary if the second half reads it,
        so its download cannot be elided — ``keep_live`` carries the dats the
        remainder of the original chain still consumes."""
        try:
            return self._interpret_chain(loops, keep_live, plan, halo, warm)
        except MemoryError:
            if len(loops) <= 1 or plan is not None:
                raise
            mid = len(loops) // 2
            head, tail = loops[:mid], loops[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            # The halo exchange happens once at chain start: the head keeps
            # it; the tail re-reads rows the head already refreshed.  The
            # tail must also warm-stage anything the head wrote — the head's
            # downloads landed real data its write-first elision would let
            # the tail clobber.  This split policy is mirrored in
            # Session._plan_split and ShardedOutOfCoreExecutor._plan_local;
            # the three must stay in lock-step.
            head_writes = frozenset(
                a.dat.name for lp in head for a in lp.args if a.mode.writes)
            out = self.run_chain(head, keep_live | tail_reads, halo=halo,
                                 warm=warm)
            # Both halves may contribute to the same reduction: combine, not
            # overwrite.
            specs = {r.name: r for lp in loops for r in lp.reductions}
            for name, val in self.run_chain(tail, keep_live,
                                            warm=warm | head_writes).items():
                out[name] = (np.asarray(specs[name].combine(out[name], val))
                             if name in out else val)
            return out

    def _interpret_chain(self, loops: Sequence[ParallelLoop],
                         keep_live: frozenset,
                         ir: Optional[Plan] = None,
                         halo=None,
                         warm: frozenset = frozenset()
                         ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        t_wall = time.perf_counter()
        tr = self.tracer
        chain_index = len(self.history)
        t_tr0 = tr.clock() if tr.enabled else 0.0
        n_cached = self.plan_hits
        cp = self.plan_chain(loops, keep_live, halo, warm=warm)
        cache_hit = self.plan_hits > n_cached
        if ir is None:
            ir = cp.ir
        elif ir.sig_hash != cp.ir.sig_hash:
            raise ValueError(
                "imported plan does not match this chain (signature hash "
                f"{ir.sig_hash[:12]} != {cp.ir.sig_hash[:12]})")
        elif (ir.num_tiles, ir.num_slots, ir.tiled_dim) != (
                cp.ir.num_tiles, cp.ir.num_slots, cp.ir.tiled_dim):
            # Same chain, different geometry: the imported op stream would be
            # bound to this config's tile schedule and fail far away inside
            # the transfer engine — reject it here with the real reason.
            raise ValueError(
                "imported plan does not match this config's tile geometry "
                f"(plan {ir.num_tiles} tiles x {ir.num_slots} slots, dim "
                f"{ir.tiled_dim}; config {cp.ir.num_tiles} x "
                f"{cp.ir.num_slots}, dim {cp.ir.tiled_dim})")
        if cfg.debug:
            from .verify import verify_plan  # function-level: avoids a cycle

            verify_plan(ir).raise_for_errors(
                f"chain {ir.sig_hash[:12]} (debug mode)")
        tx = self.transfer
        tx_before = tx.snapshot()
        # Disk-tier accounting: on data-plane runs the backing stores count
        # every byte that actually crossed the disk boundary (FetchHome /
        # SpillHome traffic AND lazy chunk-cache misses inside staging tasks).
        stores = {id(d.store): d.store for d in cp.info.datasets.values()}
        disk_before = {
            k: (s.stats["disk_bytes_read"], s.stats["disk_bytes_written"])
            for k, s in stores.items()}
        if cfg.simulate_only:
            interp = LedgerInterpreter(
                ir, cfg.hw, rm=self.residency, spec=self._spec,
                datasets=cp.info.datasets,
                tracer=tr, trace_tag=self.trace_tag,
                chain_index=chain_index)
        else:
            interp = DataPlaneInterpreter(
                ir, cfg.hw, rm=self.residency, spec=self._spec, cp=cp, tx=tx,
                codecs=resolve_codecs(cfg.codec, tuple(cp.info.datasets)),
                halo_runtime=self.halo_runtime,
                tracer=tr, trace_tag=self.trace_tag,
                chain_index=chain_index)
        res = interp.run()
        if tr.enabled:
            self.ledgers.append(res.ledger)
            tr.emit("chain", cat="chain", track=self.trace_tag + "chain",
                    t_start=t_tr0, t_end=tr.clock(),
                    args={"chain": chain_index, "sig": ir.sig_hash[:12],
                          "tiles": ir.num_tiles, "cache_hit": cache_hit,
                          "mode": "sim" if cfg.simulate_only else "data"})
        tx_delta = tx.delta(tx.snapshot(), tx_before)
        raw_total = res.uploaded + res.downloaded
        wire_total = res.uploaded_wire + res.downloaded_wire
        if cfg.simulate_only:
            disk_read, disk_written = res.disk_read, res.disk_written
        else:
            disk_read = sum(
                s.stats["disk_bytes_read"] - disk_before[k][0]
                for k, s in stores.items())
            disk_written = sum(
                s.stats["disk_bytes_written"] - disk_before[k][1]
                for k, s in stores.items())
        self.history.append(
            ChainStats(
                num_tiles=ir.num_tiles,
                loop_bytes=ir.loop_bytes,
                uploaded=res.uploaded,
                downloaded=res.downloaded,
                edge_bytes=res.edge_bytes,
                prefetch_hits=res.prefetch_hits,
                wall_s=time.perf_counter() - t_wall,
                modelled_s=res.makespan,
                achieved_bw_model=(ir.loop_bytes / res.makespan
                                   if res.makespan else 0.0),
                slot_bytes=cp.slot_bytes,
                plan_cache_hit=cache_hit,
                plan_s=0.0 if cache_hit else cp.plan_s,
                uploaded_wire=res.uploaded_wire,
                downloaded_wire=res.downloaded_wire,
                compression_ratio=(raw_total / wire_total
                                   if wire_total else 1.0),
                queue_wait_s=tx_delta.get("queue_wait_s", 0.0),
                transfer_mode=tx.mode,
                op_counts=ir.counts(),
                disk_read=disk_read,
                disk_written=disk_written,
                halo_messages=res.halo_messages,
                halo_bytes=res.halo_bytes,
            )
        )
        return res.reductions

    # -- aggregate metrics -----------------------------------------------------
    def average_bandwidth_model(self) -> float:
        """The paper's 'Average Bandwidth' over everything run so far."""
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0

    def transfer_stats(self) -> Dict[str, float]:
        """Transfer-subsystem totals over everything run so far: raw vs wire
        bytes each direction, the achieved compression ratio, and queue-wait
        (submit-to-start latency; real queueing in threaded mode, a few
        microseconds of inline dispatch overhead per task in sync mode)."""
        up_raw = sum(c.uploaded for c in self.history)
        dn_raw = sum(c.downloaded for c in self.history)
        up_wire = sum(c.uploaded_wire for c in self.history)
        dn_wire = sum(c.downloaded_wire for c in self.history)
        wire = up_wire + dn_wire
        rs = self.residency.stats
        return {
            "mode": self.transfer.mode,
            "bytes_up_raw": up_raw,
            "bytes_down_raw": dn_raw,
            "bytes_up_wire": up_wire,
            "bytes_down_wire": dn_wire,
            "bytes_moved_wire": wire,
            "compression_ratio": (up_raw + dn_raw) / wire if wire else 1.0,
            "queue_wait_s": sum(c.queue_wait_s for c in self.history),
            "elided_rows": rs["elided_rows"],
            "evictions": rs["evictions"],
            "pinned_hits": rs["pinned_hits"],
            # disk tier (repro.core.store): bytes across the disk boundary
            "bytes_disk_read": sum(c.disk_read for c in self.history),
            "bytes_disk_written": sum(c.disk_written for c in self.history),
            # device mesh (repro.core.sharded): halo-exchange traffic
            "halo_messages": sum(c.halo_messages for c in self.history),
            "halo_bytes": sum(c.halo_bytes for c in self.history),
            # per-lane queue-wait / service-time histograms straight from the
            # TransferHandle timestamps ({lane: {"queue_wait": snap, ...}})
            "lanes": self.transfer.lane_stats(),
        }


class ResidentExecutor:
    """Paper baseline: all datasets live in fast memory for the whole run.

    Implemented as the 1-tile schedule with an up-front capacity check; the
    ledger charges one initial upload per dataset (amortised across chains:
    subsequent chains reuse resident data, as in the paper's setup) and no
    per-chain traffic.
    """

    def __init__(self, hw: HardwareModel = TPU_V5E, capacity_bytes: Optional[float] = None):
        self.hw = hw
        self.capacity = capacity_bytes if capacity_bytes is not None else hw.fast_capacity
        self._resident: Set[str] = set()
        self._resident_bytes = 0
        self._inner = OutOfCoreExecutor(
            OOCConfig(hw=hw, capacity_bytes=float("inf"), num_tiles=1, num_slots=1)
        )
        self.history = self._inner.history

    def run_chain(self, loops: Sequence[ParallelLoop]) -> Dict[str, np.ndarray]:
        # Capacity check needs only the touched-dataset set — enumerating
        # args directly keeps the inner planner's cache stats honest (one
        # plan per chain, not a self-inflicted hit per run).
        for lp in loops:
            for arg in lp.args:
                if arg.dat.name not in self._resident:
                    self._resident.add(arg.dat.name)
                    self._resident_bytes += arg.dat.nbytes
        if self._resident_bytes > self.capacity:
            raise MemoryError(
                f"resident set {self._resident_bytes}B exceeds fast memory "
                f"{self.capacity}B — the paper's segfault, reproduced politely"
            )
        reds = self._inner.run_chain(loops)
        # Resident baseline: per-chain link traffic doesn't apply; replace the
        # modelled time with pure compute time.
        last = self.history[-1]
        ledger = TransferLedger(self.hw)
        t = ledger.t_compute(last.loop_bytes, 0)
        last.modelled_s = max(t, 1e-30)
        last.achieved_bw_model = last.loop_bytes / last.modelled_s
        return reds

    # plan-cache stats proxy to the inner executor (shared planner)
    @property
    def tracer(self) -> AnyTracer:
        return self._inner.tracer

    @property
    def ledgers(self) -> List[TransferLedger]:
        return self._inner.ledgers

    @property
    def plan_hits(self) -> int:
        return self._inner.plan_hits

    @property
    def plan_misses(self) -> int:
        return self._inner.plan_misses

    @property
    def plan_time_s(self) -> float:
        return self._inner.plan_time_s

    @property
    def plan_hit_rate(self) -> float:
        return self._inner.plan_hit_rate

    def transfer_stats(self) -> Dict[str, float]:
        return self._inner.transfer_stats()

    def average_bandwidth_model(self) -> float:
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0
