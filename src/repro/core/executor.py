"""Out-of-core executors (the paper's §4, Algorithm 1).

``OutOfCoreExecutor`` — explicit memory management with three slots:
while tile *t* executes (stream 0), tile *t+1*'s right footprint uploads
(stream 1) and tile *t−1*'s left footprint downloads (stream 2); after each
tile the right edge is copied device-side into the next slot.  Transfer
elision per §4.1: read-only datasets never download, write-first datasets
never upload, Cyclic additionally skips the download of write-first
temporaries, and speculative prefetch uploads the *next* chain's first tile
during the current chain's last tile.

``ResidentExecutor`` — the paper's baseline: everything resident in fast
memory for the whole run (raises, like the paper's segfault, if it can't fit).

Data plane: home copies are NumPy (slow memory); slots are JAX device arrays;
uploads/downloads go through ``jnp.asarray``/``np.asarray`` so the data path
is real on every backend, while *timings* for the paper's platforms come from
the calibrated :class:`~repro.core.memory.HardwareModel` ledger.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .dependency import ChainInfo, analyze_chain, chain_signature, plan_signature
from .engine import TileEngine
from .loop import ParallelLoop
from .memory import HardwareModel, TPU_V5E, TransferLedger
from .tiling import (
    Interval,
    TileSchedule,
    choose_num_tiles,
    make_tile_schedule,
)


@dataclass
class OOCConfig:
    hw: HardwareModel = TPU_V5E
    capacity_bytes: Optional[float] = None   # default: hw.fast_capacity
    num_slots: int = 3
    num_tiles: Optional[int] = None          # default: smallest that fits
    tiled_dim: int = 0
    cyclic: bool = False                     # §4.1 unsafe temporaries opt
    prefetch: bool = False                   # §4.1 speculative prefetch
    flops_per_point: Optional[int] = None    # compute model override
    # Schedule/ledger only — no data plane.  For modelled benchmarks at
    # scaled-down sizes (correctness is covered by the executing tests).
    simulate_only: bool = False

    @property
    def capacity(self) -> float:
        return self.capacity_bytes if self.capacity_bytes is not None else self.hw.fast_capacity


@dataclass
class ChainStats:
    num_tiles: int
    loop_bytes: int            # the paper's 'useful bytes' for avg-BW metric
    uploaded: int
    downloaded: int
    edge_bytes: int
    prefetch_hits: int
    wall_s: float
    modelled_s: float
    achieved_bw_model: float   # loop_bytes / modelled makespan
    slot_bytes: int
    plan_cache_hit: bool = False   # chain plan replayed from cache
    plan_s: float = 0.0            # analysis + scheduling time (0 on hits)


@dataclass
class ChainPlan:
    """The memoised product of dependency analysis + tile scheduling + the
    compiled tile engine for one chain signature.  Cyclic loop chains
    (CloverLeaf/OpenSBLI timesteps) are structurally identical across steps,
    so every flush after the first replays one of these instead of paying
    ``analyze_chain`` + ``make_tile_schedule`` + jit-cache lookup again."""

    key: Tuple
    info: ChainInfo
    sched: TileSchedule
    engine: TileEngine
    slot_bytes: int
    sig: Tuple          # structural chain_signature (prefetch guessing)
    plan_s: float       # construction cost (what cache hits save)


def _region_to_slot(iv: Interval, origin: int) -> Tuple[int, int]:
    return iv.lo - origin, iv.hi - origin


class OutOfCoreExecutor:
    """Explicitly-managed 3-slot streaming executor (Algorithm 1)."""

    def __init__(self, config: OOCConfig = None):
        self.cfg = config or OOCConfig()
        # LRU-bounded: kernels capturing a per-step constant (a real dt
        # changing every step) legitimately produce a new plan per flush —
        # without a bound a long run would accumulate engines/ChainInfos
        # (and their jit caches) without limit.
        self._plans: "OrderedDict[Tuple, ChainPlan]" = OrderedDict()
        self._max_plans = 32
        self._no_fit: set = set()   # keys known to raise MemoryError
        self.plan_hits = 0
        self.plan_misses = 0
        self.plan_time_s = 0.0
        # Speculative prefetch state: what we uploaded ahead for the next
        # chain: {dat_name: Interval} plus the signature we guessed from.
        self._spec_uploaded: Dict[str, Interval] = {}
        self._spec_sig = None
        self.history: List[ChainStats] = []

    # -- helpers -------------------------------------------------------------
    def _dat_np_region(self, dat, iv: Interval) -> np.ndarray:
        td = self.cfg.tiled_dim
        h = dat.halo[td][0]
        idx = [slice(None)] * dat.ndim
        idx[td] = slice(iv.lo + h, iv.hi + h)
        return dat.data[tuple(idx)]

    def _write_np_region(self, dat, iv: Interval, values: np.ndarray) -> None:
        td = self.cfg.tiled_dim
        h = dat.halo[td][0]
        idx = [slice(None)] * dat.ndim
        idx[td] = slice(iv.lo + h, iv.hi + h)
        dat.data[tuple(idx)] = values

    @staticmethod
    def _slot_slice(arr, lo: int, hi: int, td: int):
        idx = [slice(None)] * arr.ndim
        idx[td] = slice(lo, hi)
        return tuple(idx)

    def _nbytes(self, dat, iv: Interval) -> int:
        other = 1
        for d, s in enumerate(dat.padded_shape):
            if d != self.cfg.tiled_dim:
                other *= s
        return iv.length * other * dat.dtype.itemsize

    # -- planning ---------------------------------------------------------------
    def plan_chain(self, loops: Sequence[ParallelLoop]) -> ChainPlan:
        """Analysis + tile scheduling + engine, memoised on the replay-safe
        ``plan_signature`` (structure, dataset identity, kernel fingerprints)
        plus the planning-relevant config knobs.  Raises ``MemoryError``
        (uncached) when no tile count fits, so ``run_chain`` can split."""
        cfg = self.cfg
        key = (plan_signature(loops, cfg.tiled_dim), cfg.num_tiles,
               cfg.num_slots, float(cfg.capacity))
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.plan_hits += 1
            return plan
        if key in self._no_fit:   # negative cache: skip the doomed analysis
            raise MemoryError("chain cannot fit (cached verdict); splitting")
        t0 = time.perf_counter()
        try:
            info = analyze_chain(loops, tiled_dim=cfg.tiled_dim)
            n_tiles = cfg.num_tiles or choose_num_tiles(
                info, int(cfg.capacity), num_slots=cfg.num_slots
            )
            sched = make_tile_schedule(info, n_tiles)
            slot_bytes = sched.slot_bytes()
            if cfg.num_slots * slot_bytes > cfg.capacity:
                raise MemoryError(
                    f"{cfg.num_slots} slots x {slot_bytes}B exceed fast "
                    f"capacity {cfg.capacity}B; increase num_tiles"
                )
        except MemoryError:
            if len(self._no_fit) >= 8 * self._max_plans:
                self._no_fit.clear()
            self._no_fit.add(key)
            raise
        # The engine (and its jit cache) is owned by the plan: sharing engines
        # across chains whose kernels differ only in captured constants would
        # replay stale closures — the fingerprint in ``key`` prevents exactly
        # that, so the plan's engine is always consistent with its kernels.
        plan = ChainPlan(
            key=key, info=info, sched=sched, engine=TileEngine(info),
            slot_bytes=slot_bytes, sig=chain_signature(info),
            plan_s=time.perf_counter() - t0,
        )
        self._plans[key] = plan
        if len(self._plans) > self._max_plans:
            self._plans.popitem(last=False)
        self.plan_misses += 1
        self.plan_time_s += plan.plan_s
        return plan

    @property
    def plan_hit_rate(self) -> float:
        tot = self.plan_hits + self.plan_misses
        return self.plan_hits / tot if tot else 0.0

    # -- main entry ------------------------------------------------------------
    def run_chain(self, loops: Sequence[ParallelLoop],
                  keep_live: frozenset = frozenset()) -> Dict[str, np.ndarray]:
        """Run one chain; if no tile count makes its slots fit fast memory
        (skew span exceeding the grid — long chains on small problems), split
        the chain and run the halves sequentially.  This is the runtime
        equivalent of OPS bounding the number of loops tiled across.

        Splitting breaks the §4.1 Cyclic contract: a write-first dat of the
        first half is no longer a dead temporary if the second half reads it,
        so its download cannot be elided — ``keep_live`` carries the dats the
        remainder of the original chain still consumes."""
        try:
            return self._run_chain_tiled(loops, keep_live)
        except MemoryError:
            if len(loops) <= 1:
                raise
            mid = len(loops) // 2
            head, tail = loops[:mid], loops[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            out = self.run_chain(head, keep_live | tail_reads)
            # Both halves may contribute to the same reduction: combine, not
            # overwrite.
            specs = {r.name: r for lp in loops for r in lp.reductions}
            for name, val in self.run_chain(tail, keep_live).items():
                out[name] = (np.asarray(specs[name].combine(out[name], val))
                             if name in out else val)
            return out

    def _run_chain_tiled(self, loops: Sequence[ParallelLoop],
                         keep_live: frozenset = frozenset()) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        td = cfg.tiled_dim
        t_wall = time.perf_counter()
        n_cached = self.plan_hits
        plan = self.plan_chain(loops)
        cache_hit = self.plan_hits > n_cached
        # On a cache hit the recorded loops are interchangeable with the
        # plan's (equal structure, dataset objects, kernel fingerprints);
        # executing the plan's loops keeps the engine's jit cache valid.
        info, sched, engine = plan.info, plan.sched, plan.engine
        slot_bytes = plan.slot_bytes
        sig = plan.sig

        ledger = TransferLedger(cfg.hw)
        # Slot allocation: uniform arrays, max footprint length per dat.
        def fresh_slot():
            slot = {}
            for name, ln in sched.max_fp_len.items():
                dat = info.datasets[name]
                shape = list(dat.padded_shape)
                shape[td] = ln
                slot[name] = jnp.zeros(tuple(shape), dtype=dat.dtype)
            return slot

        sim = cfg.simulate_only
        slots = [({} if sim else fresh_slot()) for _ in range(cfg.num_slots)]
        origins = [dict() for _ in range(cfg.num_slots)]  # per-slot dat origins

        reductions: Dict[str, np.ndarray] = {}
        red_specs = {}
        for lp in info.loops:
            for r in lp.reductions:
                red_specs[r.name] = r

        uploaded = downloaded = edge_bytes = 0
        prefetch_hits = 0
        # event ids for stream dependency wiring
        last_compute_eid: Optional[int] = None
        last_upload_eid: Optional[int] = None
        last_download_eid: Dict[int, Optional[int]] = {}  # slot -> eid
        compute_eids: List[Optional[int]] = [None] * sched.num_tiles

        spec_valid = (
            cfg.prefetch
            and self._spec_sig is not None
            and self._spec_sig == sig
            and bool(self._spec_uploaded)
        )

        for t, tile in enumerate(sched.tiles):
            s = t % cfg.num_slots
            slot = slots[s]
            org = {name: iv.lo for name, iv in tile.footprint.items() if not iv.empty}
            origins[s] = org

            # ---- preparation phase: upload this tile's new data ------------
            # (Algorithm 1 issues tile t+1's upload during tile t; the ledger
            # wires that overlap; data-plane order here is sequential & safe.)
            # Per-tile transfers COALESCE into one ledger event per direction
            # (one staging copy per tile — at real scale per-dat latencies are
            # noise; at scaled-down bench sizes they would dominate falsely).
            up_deps = []
            if last_download_eid.get(s) is not None:
                up_deps.append(last_download_eid[s])   # slot reuse fence
            if last_upload_eid is not None:
                up_deps.append(last_upload_eid)        # stream-1 FIFO
            tile_up_bytes = 0
            for name, pieces in tile.upload.items():
                if name in info.write_first:
                    # §4.1: write-first data never uploads — except rows the
                    # chain reads before any write reaches them (halo skirts):
                    # those are genuinely consumed from home (cold reads).
                    cold = info.cold.get(name, [])
                    pieces = tuple(
                        p
                        for iv in pieces
                        for p in (iv.clamp(clo, chi) for clo, chi in cold)
                        if not p.empty
                    )
                for iv in pieces:
                    if iv.empty:
                        continue
                    use = iv
                    if spec_valid and t == 0:
                        pre = self._spec_uploaded.get(name, ())
                        for piv in pre:
                            hit = iv.intersect(piv)
                            if not hit.empty and hit.lo == iv.lo:
                                prefetch_hits += 1
                                use = Interval(hit.hi, iv.hi)  # only the miss part
                                break
                    if use.empty:
                        continue
                    if not sim:
                        chunk = self._dat_np_region(info.datasets[name], use)
                        lo, hi = _region_to_slot(use, org[name])
                        slot[name] = slot[name].at[
                            self._slot_slice(slot[name], lo, hi, td)
                        ].set(jnp.asarray(chunk))
                    tile_up_bytes += self._nbytes(info.datasets[name], use)
            if tile_up_bytes:
                uploaded += tile_up_bytes
                last_upload_eid = ledger.add(
                    1, "upload", tile_up_bytes, ledger.t_up(tile_up_bytes),
                    tuple(up_deps))

            # ---- execution phase -------------------------------------------
            comp_deps = []
            if last_upload_eid is not None:
                comp_deps.append(last_upload_eid)
            if last_compute_eid is not None:
                comp_deps.append(last_compute_eid)
            tile_bytes = 0
            tile_flops = 0
            for k, box in enumerate(tile.loop_ranges):
                if box is None:
                    continue
                npts = 1
                for a, b in box:
                    npts *= b - a
                lp = info.loops[k]
                full_pts = 1
                for a, b in lp.range_:
                    full_pts *= b - a
                frac = npts / full_pts
                tile_bytes += int(lp.bytes_moved() * frac)
                tile_flops += int(lp.flops(cfg.flops_per_point) * frac)
            if not sim:
                new_slot, tile_reds = engine.run_tile(tile, slot, org)
                slots[s] = new_slot
                slot = new_slot
                for name, val in tile_reds.items():
                    spec = red_specs[name]
                    if name in reductions:
                        reductions[name] = np.asarray(
                            spec.combine(reductions[name], val))
                    else:
                        reductions[name] = np.asarray(val)
            last_compute_eid = ledger.add(
                0, "compute", tile_bytes, ledger.t_compute(tile_bytes, tile_flops),
                tuple(comp_deps),
            )
            compute_eids[t] = last_compute_eid

            # ---- finishing phase --------------------------------------------
            # Edge copy: right edge of tile t -> left edge region of slot t+1.
            if t + 1 < sched.num_tiles:
                nslot_i = (t + 1) % cfg.num_slots
                next_tile = sched.tiles[t + 1]
                next_org = {
                    name: iv.lo
                    for name, iv in next_tile.footprint.items()
                    if not iv.empty
                }
                edge_deps = [last_compute_eid]
                if last_download_eid.get(nslot_i) is not None:
                    edge_deps.append(last_download_eid[nslot_i])
                tile_edge_bytes = 0
                for name, iv in tile.edge_to_next.items():
                    if iv.empty or name not in next_org:
                        continue
                    if not sim:
                        src_lo, src_hi = _region_to_slot(iv, org[name])
                        dst_lo, dst_hi = _region_to_slot(iv, next_org[name])
                        src = slots[s][name]
                        dst = slots[nslot_i][name]
                        vals = src[self._slot_slice(src, src_lo, src_hi, td)]
                        slots[nslot_i][name] = dst.at[
                            self._slot_slice(dst, dst_lo, dst_hi, td)
                        ].set(vals)
                    tile_edge_bytes += self._nbytes(info.datasets[name], iv)
                if tile_edge_bytes:
                    edge_bytes += tile_edge_bytes
                    last_compute_eid = ledger.add(
                        0, "edge", tile_edge_bytes,
                        ledger.t_dd(2 * tile_edge_bytes), tuple(edge_deps))

            # Download left footprint of modified datasets.
            dn_deps = [compute_eids[t]]
            tile_dn_bytes = 0
            for name, pieces in tile.download.items():
                if name in info.read_only:
                    continue  # never written -> never download
                if (cfg.cyclic and name in info.write_first
                        and name not in keep_live):
                    continue  # §4.1 Cyclic: temporaries stay on device
                for iv in pieces:
                    if iv.empty:
                        continue
                    if not sim:
                        lo, hi = _region_to_slot(iv, org[name])
                        arr = slots[s][name]
                        vals = np.asarray(arr[self._slot_slice(arr, lo, hi, td)])
                        self._write_np_region(info.datasets[name], iv, vals)
                    tile_dn_bytes += self._nbytes(info.datasets[name], iv)
            if tile_dn_bytes:
                downloaded += tile_dn_bytes
                eid = ledger.add(2, "download", tile_dn_bytes,
                                 ledger.t_down(tile_dn_bytes), tuple(dn_deps))
                last_download_eid[s] = eid

            # Speculative prefetch (§4.1): during the last tile, upload the
            # next chain's assumed first tile (assume it mirrors this chain).
            if cfg.prefetch and t == sched.num_tiles - 1:
                first = sched.tiles[0]
                nb_total = 0
                self._spec_uploaded = {}
                for name, pieces in first.upload.items():
                    if name in info.write_first:
                        continue
                    live = tuple(iv for iv in pieces if not iv.empty)
                    if not live:
                        continue
                    self._spec_uploaded[name] = live
                    nb_total += sum(self._nbytes(info.datasets[name], iv) for iv in live)
                if nb_total:
                    # Overlaps the last compute on stream 1.
                    ledger.add(1, "prefetch", nb_total, ledger.t_up(nb_total),
                               (last_upload_eid,) if last_upload_eid else ())
                self._spec_sig = sig

        makespan = ledger.simulate()
        wall = time.perf_counter() - t_wall
        loop_bytes = info.loop_bytes()
        self.history.append(
            ChainStats(
                num_tiles=sched.num_tiles,
                loop_bytes=loop_bytes,
                uploaded=uploaded,
                downloaded=downloaded,
                edge_bytes=edge_bytes,
                prefetch_hits=prefetch_hits,
                wall_s=wall,
                modelled_s=makespan,
                achieved_bw_model=loop_bytes / makespan if makespan else 0.0,
                slot_bytes=slot_bytes,
                plan_cache_hit=cache_hit,
                plan_s=0.0 if cache_hit else plan.plan_s,
            )
        )
        return reductions

    # -- aggregate metrics -----------------------------------------------------
    def average_bandwidth_model(self) -> float:
        """The paper's 'Average Bandwidth' over everything run so far."""
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0


class ResidentExecutor:
    """Paper baseline: all datasets live in fast memory for the whole run.

    Implemented as the 1-tile schedule with an up-front capacity check; the
    ledger charges one initial upload per dataset (amortised across chains:
    subsequent chains reuse resident data, as in the paper's setup) and no
    per-chain traffic.
    """

    def __init__(self, hw: HardwareModel = TPU_V5E, capacity_bytes: Optional[float] = None):
        self.hw = hw
        self.capacity = capacity_bytes if capacity_bytes is not None else hw.fast_capacity
        self._resident: Set[str] = set()
        self._resident_bytes = 0
        self._inner = OutOfCoreExecutor(
            OOCConfig(hw=hw, capacity_bytes=float("inf"), num_tiles=1, num_slots=1)
        )
        self.history = self._inner.history

    def run_chain(self, loops: Sequence[ParallelLoop]) -> Dict[str, np.ndarray]:
        # Capacity check needs only the touched-dataset set — enumerating
        # args directly keeps the inner planner's cache stats honest (one
        # plan per chain, not a self-inflicted hit per run).
        for lp in loops:
            for arg in lp.args:
                if arg.dat.name not in self._resident:
                    self._resident.add(arg.dat.name)
                    self._resident_bytes += arg.dat.nbytes
        if self._resident_bytes > self.capacity:
            raise MemoryError(
                f"resident set {self._resident_bytes}B exceeds fast memory "
                f"{self.capacity}B — the paper's segfault, reproduced politely"
            )
        reds = self._inner.run_chain(loops)
        # Resident baseline: per-chain link traffic doesn't apply; replace the
        # modelled time with pure compute time.
        last = self.history[-1]
        ledger = TransferLedger(self.hw)
        t = ledger.t_compute(last.loop_bytes, 0)
        last.modelled_s = max(t, 1e-30)
        last.achieved_bw_model = last.loop_bytes / last.modelled_s
        return reds

    # plan-cache stats proxy to the inner executor (shared planner)
    @property
    def plan_hits(self) -> int:
        return self._inner.plan_hits

    @property
    def plan_misses(self) -> int:
        return self._inner.plan_misses

    @property
    def plan_time_s(self) -> float:
        return self._inner.plan_time_s

    @property
    def plan_hit_rate(self) -> float:
        return self._inner.plan_hit_rate

    def average_bandwidth_model(self) -> float:
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0
