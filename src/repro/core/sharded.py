"""Sharded out-of-core execution: the device mesh composed with tiling.

This is the execution model of the paper's §5.2 evaluation, made a
first-class backend: the grid is decomposed along ``shard_dim`` (default 1,
the *non*-tiled dimension) over a :class:`~repro.core.mesh.DeviceMesh`;
every shard runs the ordinary out-of-core machinery — dependency analysis,
skewed tiles along dim 0, the typed Plan IR, the shared interpreters —
over its *extended region* (owned interval + redundant-compute skirt), and
the shards exchange one **accumulated-depth** halo per chain instead of one
per loop (the §5.2 message-aggregation trade-off).

Mechanics:

* Each shard owns a contiguous interval of the shard dimension plus a
  ``skirt`` of redundant rows toward interior neighbours
  (:func:`~repro.core.mesh.shard_geometries`).  Loops are *localised* per
  shard: ranges clipped to the extended region, datasets swapped for
  shard-local homes, kernel ``coords()`` offset back to global coordinates
  so position-dependent kernels stay exact.  Reduction loops are clipped to
  the owned interval so global reductions are combined, not double-counted.
* A chain whose accumulated halo depth (sum of per-loop read extents along
  ``shard_dim``) exceeds the skirt is split into *segments* that fit, with
  one exchange per segment — the runtime equivalent of OPS bounding the
  number of loops tiled across (see PAPERS.md).
* The exchange itself is lowered into the Plan IR
  (``HaloPack``/``HaloExchange``/``HaloUnpack``,
  :func:`~repro.core.plan.build_plan` with a
  :class:`~repro.core.mesh.HaloSpec`), costed on the ledger's network
  stream per device, and executed by the per-device
  :class:`~repro.core.interp.DataPlaneInterpreter` through the collective
  runtime installed here — host-side copies on a ``sim:N`` virtual mesh,
  the :func:`~repro.core.distributed.exchange_halos` ``ppermute`` path
  under ``shard_map`` on a ``jax:N`` mesh of real devices.

Every shard gets its own :class:`~repro.core.executor.OutOfCoreExecutor`
(per-device plan caches, residency, transfer engine, ledger), so
``Session.explain()`` reports genuinely per-device makespans and
``Session.tune()`` can enumerate shard counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .block import Block
from .dataset import Dataset
from .dependency import loop_kernel_fingerprint
from .distributed import HaloExchangeStats
from .executor import ChainStats, OOCConfig, OutOfCoreExecutor
from .loop import Accessor, Arg, ParallelLoop
from .mesh import DeviceMesh, HaloSpec, MeshError, ShardGeometry, shard_geometries
from ..obs.metrics import merge_histogram_snapshots
from ..obs.tracer import as_tracer

# Cap on the auto-sized redundant-compute skirt (rows per interior side).
# The skirt targets the deepest chain's accumulated halo depth (CloverLeaf's
# 51-loop timestep accumulates ~40 rows) so one exchange covers the whole
# chain; this cap bounds the redundant memory/compute on very long chains,
# and ``min_width - halo`` clamps it on narrow shards.  Override with
# ``halo_depth=``.
DEFAULT_MAX_SKIRT = 64


class ShardingError(MeshError):
    """The chain cannot be decomposed over the requested mesh."""


def loop_halo_extent(lp: ParallelLoop, dim: int) -> int:
    """Max |read offset| of one loop along ``dim`` — its halo-depth cost."""
    e = 0
    for arg in lp.args:
        if arg.mode.reads:
            e = max(e, arg.stencil.max_abs_extent(dim))
    return e


def split_segments(loops: Sequence[ParallelLoop], dim: int,
                   budget: int) -> List[List[ParallelLoop]]:
    """Split a chain into segments whose accumulated halo depth (sum of
    per-loop read extents along ``dim``) fits ``budget`` — one exchange per
    segment keeps every shard's owned interval valid.

    A loop that both writes datasets *and* carries reductions ends its
    segment: its writes are clipped to the owned interval (reduction
    correctness), so later loops may only read them after an exchange."""
    segs: List[List[ParallelLoop]] = []
    cur: List[ParallelLoop] = []
    acc = 0
    for lp in loops:
        e = loop_halo_extent(lp, dim)
        if e > budget:
            raise ShardingError(
                f"loop {lp.name!r} reads {e} rows along shard dim {dim} but "
                f"the redundant-compute skirt is only {budget} rows — use "
                f"fewer devices or a larger halo_depth")
        if cur and acc + e > budget:
            segs.append(cur)
            cur, acc = [], 0
        cur.append(lp)
        acc += e
        if lp.reductions and any(a.mode.writes for a in lp.args):
            segs.append(cur)
            cur, acc = [], 0
    if cur:
        segs.append(cur)
    return segs


# -- kernel re-basing --------------------------------------------------------------


class _OffsetAccessor(Accessor):
    """Proxy accessor adding a constant offset to ``coords()`` so kernels of
    a localised loop still see *global* grid coordinates (position-dependent
    kernels — initialisation fields, coordinate-based forcing — stay exact
    under decomposition)."""

    def __init__(self, inner: Accessor, offsets: Tuple[int, ...]):
        self._inner = inner
        self._offsets = offsets

    @property
    def shape(self):
        return self._inner.shape

    def coords(self):
        return tuple(c + o if o else c
                     for c, o in zip(self._inner.coords(), self._offsets))

    def __call__(self, name, offset=None):
        return self._inner(name, offset)


def shift_kernel(kernel, offsets: Tuple[int, ...]):
    """Wrap ``kernel`` so its accessor reports global coordinates."""

    def sharded_kernel(acc):
        return kernel(_OffsetAccessor(acc, offsets))

    return sharded_kernel


# -- per-block shard state ---------------------------------------------------------


class _ShardState:
    """Everything one global block's decomposition owns: per-shard local
    blocks and datasets (created once, so per-shard plan caches hit across
    timesteps), plus home-copy version tracking for scatter/gather."""

    def __init__(self, block: Block, mesh: DeviceMesh, shard_dim: int,
                 skirt: int):
        self.block = block
        self.mesh = mesh
        self.shard_dim = shard_dim
        self.skirt = skirt
        self.geos: List[ShardGeometry] = shard_geometries(
            block.size[shard_dim], mesh.num_devices, skirt)
        self.blocks: List[Block] = []
        for geo in self.geos:
            size = list(block.size)
            size[shard_dim] = geo.ext_size
            self.blocks.append(
                Block(f"{block.name}@{mesh.spec}/{geo.index}", tuple(size)))
        self.globals: Dict[str, Dataset] = {}       # name -> global dataset
        self.locals: Dict[str, List[Dataset]] = {}  # name -> per-shard homes
        self.versions: Dict[str, int] = {}          # global version at sync
        self.min_width = min(g.width for g in self.geos)
        # ppermute collectives need uniform per-device blocks; uneven shard
        # widths fall back to host copies for THIS block only.
        self.uniform = len({g.width for g in self.geos}) == 1
        # jitted collective cache: (names, depths) -> compiled shard_map fn
        # (re-tracing per exchange would dominate a multi-step run).
        self._collectives: Dict[Tuple, object] = {}

    def ensure_local(self, gdat: Dataset) -> List[Dataset]:
        name = gdat.name
        if self.globals.get(name) is not gdat:
            # New (or replaced) global dataset: rebuild the local homes.
            self.globals[name] = gdat
            self.locals.pop(name, None)
            self.versions.pop(name, None)
        if name in self.locals:
            return self.locals[name]
        sd = self.shard_dim
        h_lo, h_hi = gdat.halo[sd]
        if self.skirt + max(h_lo, h_hi) > self.min_width:
            raise ShardingError(
                f"dataset {name!r}: skirt {self.skirt} + halo "
                f"{max(h_lo, h_hi)} exceeds the narrowest shard width "
                f"{self.min_width} — use fewer devices or a smaller "
                f"halo_depth")
        self.locals[name] = [
            Dataset(block=self.blocks[s], name=name, dtype=gdat.dtype,
                    halo=gdat.halo)
            for s in range(len(self.geos))
        ]
        return self.locals[name]

    def row_bytes(self, name: str) -> int:
        """Bytes per shard-dim row of a local home (identical across shards:
        only the shard dimension is decomposed)."""
        dat = self.locals[name][0]
        other = 1
        for d, s in enumerate(dat.padded_shape):
            if d != self.shard_dim:
                other *= s
        return other * dat.dtype.itemsize

    def transfers(self, name: str):
        """Directed boundary copies one exchange performs for ``name``:
        ``(src_shard, dst_shard, global_lo, global_hi)`` — each interior
        boundary refreshes the downstream shard's full stale region (skirt +
        dataset halo) from the upstream shard's *owned* rows."""
        sd = self.shard_dim
        h_lo, h_hi = self.globals[name].halo[sd]
        out = []
        for s in range(len(self.geos) - 1):
            b = self.geos[s].hi  # == geos[s+1].lo
            out.append((s, s + 1, b - self.skirt - h_lo, b))
            out.append((s + 1, s, b, b + self.skirt + h_hi))
        return out


# -- the sharded executor ----------------------------------------------------------


@dataclass
class ShardedChainPlan:
    """Per-device Plan IRs for one chain (segments x shards, stream order).
    ``Session.plan()`` flattens ``ir`` so every device's instruction stream
    is inspectable/exportable individually."""

    ir: Tuple


class ShardedOutOfCoreExecutor:
    """One executor per mesh device, one accumulated-depth exchange per
    chain segment; a drop-in ``run_chain`` backend."""

    def __init__(self, config: OOCConfig = None, *,
                 mesh: DeviceMesh = None, shard_dim: int = 1,
                 halo_depth: Optional[int] = None):
        self.cfg = config or OOCConfig()
        self.mesh = mesh or DeviceMesh.sim(1)
        self.shard_dim = shard_dim
        self.halo_depth = halo_depth
        # The inner executors share THIS config object (the Session's cyclic
        # toggle and tuner overrides reach every device).
        self.inner: List[OutOfCoreExecutor] = [
            OutOfCoreExecutor(self.cfg)
            for _ in range(self.mesh.num_devices)
        ]
        # One tracing spine for the whole mesh: each device's executor emits
        # onto the shared tracer under a ``devN/`` track prefix (so Perfetto
        # shows per-device compute/upload/download swim-lanes), and the mesh
        # itself gets scatter/gather/exchange spans on a ``mesh`` track.
        self.tracer = as_tracer(self.cfg.trace)
        self.trace_tag = ""
        for i, ex in enumerate(self.inner):
            ex.tracer = self.tracer
            ex.trace_tag = f"dev{i}/"
        self.history: List[ChainStats] = []
        # Achieved (data-plane) exchange traffic, counted by the collective
        # runtime; the modelled counterpart is summed over ChainStats.
        self.halo_stats = HaloExchangeStats()
        self.exchange_path = ("ppermute" if self.mesh.kind == "jax"
                              else "host")
        self._states: Dict[int, _ShardState] = {}

    # -- plumbing shared with the plain executor ------------------------------
    @property
    def plan_hits(self) -> int:
        return sum(ex.plan_hits for ex in self.inner)

    @property
    def plan_misses(self) -> int:
        return sum(ex.plan_misses for ex in self.inner)

    @property
    def plan_time_s(self) -> float:
        return sum(ex.plan_time_s for ex in self.inner)

    @property
    def plan_hit_rate(self) -> float:
        tot = self.plan_hits + self.plan_misses
        return self.plan_hits / tot if tot else 0.0

    def close(self) -> None:
        for ex in self.inner:
            ex.close()

    def reset_data_caches(self) -> None:
        for ex in self.inner:
            ex.reset_data_caches()
        # Home copies changed underneath us (Session.restore): re-scatter.
        for state in self._states.values():
            state.versions.clear()

    def transfer_stats(self) -> Dict[str, float]:
        stats = [ex.transfer_stats() for ex in self.inner]
        out: Dict[str, float] = {"mode": self.inner[0].transfer.mode}
        for key in stats[0]:
            if key in ("mode", "compression_ratio", "lanes"):
                continue
            out[key] = sum(s[key] for s in stats)
        wire = out.get("bytes_moved_wire", 0)
        raw = out.get("bytes_up_raw", 0) + out.get("bytes_down_raw", 0)
        out["compression_ratio"] = raw / wire if wire else 1.0
        # Per-lane histograms fold across devices (fixed bucket bounds make
        # the snapshots mergeable) instead of summing like the scalars.
        lanes: Dict[str, Dict[str, dict]] = {}
        for s in stats:
            for lane, hists in s.get("lanes", {}).items():
                dst = lanes.setdefault(lane, {})
                for k, snap in hists.items():
                    dst[k] = merge_histogram_snapshots(dst.get(k, {}), snap)
        out["lanes"] = lanes
        return out

    def average_bandwidth_model(self) -> float:
        tot_b = sum(c.loop_bytes for c in self.history)
        tot_t = sum(c.modelled_s for c in self.history)
        return tot_b / tot_t if tot_t else 0.0

    def __del__(self):  # pragma: no cover - interpreter-shutdown timing
        try:
            self.close()
        except Exception:
            pass

    # -- decomposition ---------------------------------------------------------
    def _state_for(self, loops: Sequence[ParallelLoop]) -> _ShardState:
        """The block's decomposition, with the skirt auto-sized to the
        deepest chain seen so far: ideally the whole chain's accumulated
        halo depth fits one exchange (segmentation re-stages every segment's
        read footprint, which costs far more than skirt compute), clamped by
        the narrowest shard and ``DEFAULT_MAX_SKIRT``.  A deeper chain
        rebuilds the decomposition once (the global homes are authoritative
        between chains, so a rebuild is just a re-scatter)."""
        block = loops[0].block
        sd = self.shard_dim
        if sd >= block.ndim:
            raise ShardingError(
                f"shard_dim {sd} out of range for {block.ndim}-D block "
                f"{block.name!r}")
        h_max = max((max(a.dat.halo[sd]) for lp in loops
                     for a in lp.args), default=0)
        min_width = block.size[sd] // self.mesh.num_devices
        if min_width < 1:
            raise ShardingError(
                f"cannot shard extent {block.size[sd]} over "
                f"{self.mesh.num_devices} devices")
        if self.halo_depth is not None:
            skirt = self.halo_depth
        else:
            needed = sum(loop_halo_extent(lp, sd) for lp in loops)
            skirt = max(0, min(min_width - h_max, needed,
                               DEFAULT_MAX_SKIRT))
        state = self._states.get(id(block))
        if (state is not None and self.halo_depth is None
                and skirt > state.skirt):
            state = None      # deeper chain arrived: rebuild decomposition
        if state is None:
            state = _ShardState(block, self.mesh, sd, skirt)
            self._states[id(block)] = state
        for lp in loops:
            for a in lp.args:
                state.ensure_local(a.dat)
        return state

    def _localize(self, state: _ShardState, lp: ParallelLoop,
                  s: int) -> Optional[ParallelLoop]:
        """One shard's version of one loop: range clipped to the extended
        region (owned only, for reduction loops), shifted to local
        coordinates; args re-bound to the shard-local datasets; the kernel
        wrapped so coords() stays global.  None when the clip is empty."""
        geo = state.geos[s]
        sd = state.shard_dim
        n = state.mesh.num_devices
        a, b = lp.range_[sd]
        if lp.reductions:
            lo = max(a, geo.lo) if s > 0 else a
            hi = min(b, geo.hi) if s < n - 1 else b
        else:
            lo = max(a, geo.ext_lo) if s > 0 else a
            hi = min(b, geo.ext_hi) if s < n - 1 else b
        if hi <= lo:
            return None
        off = geo.ext_lo
        range_ = list(lp.range_)
        range_[sd] = (lo - off, hi - off)
        args = tuple(
            Arg(state.locals[arg.dat.name][s], arg.stencil, arg.mode)
            for arg in lp.args)
        kernel = lp.kernel if off == 0 else shift_kernel(
            lp.kernel, tuple(off if d == sd else 0
                             for d in range(lp.block.ndim)))
        local = ParallelLoop(
            name=lp.name, block=state.blocks[s], range_=tuple(range_),
            args=args, kernel=kernel, reductions=lp.reductions)
        # Plan-cache key stability: derive the local kernel fingerprint from
        # the (memoised) global one instead of re-walking the wrapper.
        local.__dict__["_kernel_fp"] = (
            "shard", off, sd, loop_kernel_fingerprint(lp))
        return local

    # -- scatter / exchange / gather -------------------------------------------
    def _scatter(self, state: _ShardState, names) -> None:
        """Global home -> shard-local homes (full extended region + halos)
        for datasets whose global copy changed since the last sync."""
        tr = self.tracer
        t_tr0 = tr.clock() if tr.enabled else 0.0
        moved = 0
        sd = state.shard_dim
        for name in names:
            gdat = state.globals[name]
            if state.versions.get(name) == gdat.version:
                continue
            h_lo, h_hi = gdat.halo[sd]
            for s, ldat in enumerate(state.locals[name]):
                geo = state.geos[s]
                vals = gdat.read_rows(sd, geo.ext_lo - h_lo,
                                      geo.ext_hi + h_hi)
                ldat.write_rows(sd, -h_lo, geo.ext_size + h_hi, vals)
                moved += vals.nbytes
            state.versions[name] = gdat.version
        if tr.enabled and moved:
            tr.emit("scatter", cat="mesh", track=self.trace_tag + "mesh",
                    t_start=t_tr0, t_end=tr.clock(), args={"bytes": moved})

    def _gather(self, state: _ShardState, names) -> None:
        """Shard-local owned rows -> global home.  Edge shards also own the
        global halo rows (their halo-mirror loops wrote them)."""
        tr = self.tracer
        t_tr0 = tr.clock() if tr.enabled else 0.0
        moved = 0
        sd = state.shard_dim
        n = state.mesh.num_devices
        extent = state.block.size[sd]
        for name in names:
            gdat = state.globals[name]
            h_lo, h_hi = gdat.halo[sd]
            for s, ldat in enumerate(state.locals[name]):
                geo = state.geos[s]
                lo = geo.lo if s > 0 else -h_lo
                hi = geo.hi if s < n - 1 else extent + h_hi
                vals = ldat.read_rows(sd, lo - geo.ext_lo, hi - geo.ext_lo)
                gdat.write_rows(sd, lo, hi, vals)
                moved += vals.nbytes
            state.versions[name] = gdat.version
        if tr.enabled and moved:
            tr.emit("gather", cat="mesh", track=self.trace_tag + "mesh",
                    t_start=t_tr0, t_end=tr.clock(), args={"bytes": moved})

    def _halo_spec(self, state: _ShardState, s: int,
                   names: Tuple[str, ...]) -> HaloSpec:
        """This device's plan-level exchange annotation (``names`` = the
        read set of ITS local segment); summing the per-device
        messages/bytes over the mesh reproduces the runtime totals exactly,
        because the collective refreshes precisely these per-device sets."""
        n = state.mesh.num_devices
        sd = state.shard_dim
        msgs = nbytes = 0
        h_max = 0
        for name in names:
            h_lo, h_hi = state.globals[name].halo[sd]
            h_max = max(h_max, h_lo, h_hi)
            rb = state.row_bytes(name)
            if s > 0:
                msgs += 1
                nbytes += (state.skirt + h_lo) * rb
            if s < n - 1:
                msgs += 1
                nbytes += (state.skirt + h_hi) * rb
        return HaloSpec(device=s, num_devices=n, shard_dim=sd,
                        depth=state.skirt + h_max, messages=msgs,
                        nbytes=nbytes, names=names)

    def _exchange(self, state: _ShardState,
                  names_by_shard: List[Tuple[str, ...]]) -> None:
        """The collective: refresh each participating shard's stale
        (non-owned) region of the datasets ITS segment reads from its
        neighbours' owned rows, counting achieved messages/bytes.
        Host-side copies on a virtual mesh; the ``exchange_halos`` ppermute
        path under ``shard_map`` on a real one."""
        if self.mesh.num_devices <= 1:
            return
        union = tuple(sorted({n for names in names_by_shard for n in names}))
        if not union:
            return
        tr = self.tracer
        t_tr0 = tr.clock() if tr.enabled else 0.0
        msgs0, bytes0 = self.halo_stats.messages, self.halo_stats.bytes
        exchanged = None
        if self.exchange_path == "ppermute" and state.uniform:
            exchanged = self._exchange_ppermute(state, union, names_by_shard)
        sd = state.shard_dim
        for name in union:
            locs = state.locals[name]
            rb = state.row_bytes(name)
            for src, dst, glo, ghi in state.transfers(name):
                if name not in names_by_shard[dst]:
                    continue  # that shard's segment never reads it
                if exchanged is None:  # ppermute path already landed them
                    vals = locs[src].read_rows(
                        sd, glo - state.geos[src].ext_lo,
                        ghi - state.geos[src].ext_lo)
                    locs[dst].write_rows(
                        sd, glo - state.geos[dst].ext_lo,
                        ghi - state.geos[dst].ext_lo, vals)
                self.halo_stats.messages += 1
                self.halo_stats.bytes += (ghi - glo) * rb
        if tr.enabled:
            tr.emit("halo-exchange", cat="mesh",
                    track=self.trace_tag + "mesh",
                    t_start=t_tr0, t_end=tr.clock(),
                    args={"path": self.exchange_path if exchanged is not None
                          else "host",
                          "messages": self.halo_stats.messages - msgs0,
                          "bytes": self.halo_stats.bytes - bytes0})

    def _exchange_ppermute(self, state: _ShardState, names,
                           names_by_shard) -> Dict:
        """Run the real collective for a ``jax:N`` mesh: per-shard blocks of
        uniform width stacked along the shard dim, one
        ``exchange_halos(periodic=False)`` under ``shard_map`` for all
        datasets at once, received halo regions written back into the
        shard-local homes.  The jitted collective is cached per (names,
        depths) on the shard state, so repeated exchanges replay a compiled
        executable instead of re-tracing."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        sd = state.shard_dim
        geos = state.geos
        n = state.mesh.num_devices
        w = state.min_width
        mesh = self.mesh.jax_mesh()
        axis = self.mesh.axis_name
        stacked = {}
        depths = {}
        for name in names:
            gdat = state.globals[name]
            h_lo, h_hi = gdat.halo[sd]
            depth = state.skirt + max(h_lo, h_hi)
            depths[name] = depth
            shape = list(state.locals[name][0].padded_shape)
            shape[sd] = n * (w + 2 * depth)
            buf = np.zeros(tuple(shape), dtype=gdat.dtype)
            for s, geo in enumerate(geos):
                # Owned rows into the block centre; the margins are what the
                # collective fills (or leaves, at the global edges).
                vals = state.locals[name][s].read_rows(
                    sd, geo.lo - geo.ext_lo, geo.hi - geo.ext_lo)
                idx = [slice(None)] * len(shape)
                base = s * (w + 2 * depth)
                idx[sd] = slice(base + depth, base + depth + w)
                buf[tuple(idx)] = vals
            stacked[name] = buf

        spec = P(*[axis if d == sd else None
                   for d in range(len(state.block.size))])
        fn = self._collective_fn(state, mesh, spec, names,
                                 tuple(depths[n_] for n_ in names))
        placed = {nm: jax.device_put(arr, NamedSharding(mesh, spec))
                  for nm, arr in stacked.items()}
        result = {nm: np.asarray(arr) for nm, arr in fn(placed).items()}
        # Land the received regions into the shard-local homes (exactly the
        # host path's refresh regions, so accounting is path-independent).
        for name in names:
            depth = depths[name]
            for src, dst, glo, ghi in state.transfers(name):
                if name not in names_by_shard[dst]:
                    continue
                base = dst * (w + 2 * depth)
                # Buffer row j of block dst holds global row ext-region row:
                # block centre starts at geos[dst].lo <-> base + depth.
                blo = base + depth + (glo - geos[dst].lo)
                idx = [slice(None)] * result[name].ndim
                idx[sd] = slice(blo, blo + (ghi - glo))
                state.locals[name][dst].write_rows(
                    sd, glo - geos[dst].ext_lo, ghi - geos[dst].ext_lo,
                    result[name][tuple(idx)])
        return result

    def _collective_fn(self, state: _ShardState, mesh, spec,
                       names: Tuple[str, ...], depths: Tuple[int, ...]):
        """The jitted shard_map'd exchange for one (names, depths) shape,
        memoised on the shard state."""
        key = (names, depths)
        fn = state._collectives.get(key)
        if fn is None:
            import jax

            from ..compat import shard_map
            from .distributed import exchange_halos

            sd = state.shard_dim
            axis = self.mesh.axis_name
            by_name = dict(zip(names, depths))

            def collective(arrays):
                out = {}
                for nm, arr in arrays.items():
                    got = exchange_halos({nm: arr}, by_name[nm], axis,
                                         dim=sd, periodic=False)
                    out[nm] = got[nm]
                return out

            fn = jax.jit(shard_map(collective, mesh=mesh, in_specs=spec,
                                   out_specs=spec, check_vma=False))
            state._collectives[key] = fn
        return fn

    # -- main entry ------------------------------------------------------------
    def run_chain(self, loops: Sequence[ParallelLoop],
                  keep_live: frozenset = frozenset()):
        if self.mesh.num_devices == 1:
            # Degenerate mesh: exactly the unsharded executor (bit-identical
            # to the ``ooc`` backend by construction).
            before = len(self.inner[0].history)
            out = self.inner[0].run_chain(loops, keep_live)
            self.history.extend(self.inner[0].history[before:])
            return out
        state = self._state_for(loops)
        segments = split_segments(loops, self.shard_dim, state.skirt)
        sim = self.cfg.simulate_only
        if self.cfg.debug:
            # Per-plan verification happens inside each inner executor; this
            # adds the cross-device pass (exchange depth/message consistency
            # over every per-device plan of every segment).
            from .verify import verify_plans  # function-level: avoids a cycle

            verify_plans(self.plan_chain(loops, keep_live).ir
                         ).raise_for_errors("sharded chain (debug mode)")
        if not sim:
            self._scatter(state, sorted(
                {a.dat.name for lp in loops for a in lp.args}))
        specs = {r.name: r for lp in loops for r in lp.reductions}
        reductions: Dict[str, np.ndarray] = {}
        modified: Set[str] = set()
        accessed: Set[str] = set()
        not_elidable = self._chain_live_set(loops)
        for i, seg in enumerate(segments):
            tail_reads = frozenset(
                a.dat.name for later in segments[i + 1:] for lp in later
                for a in lp.args if a.mode.reads)
            self._run_segment(state, seg,
                              keep_live | tail_reads | not_elidable,
                              reductions, specs, sim, accessed)
            modified.update(a.dat.name for lp in seg for a in lp.args
                            if a.mode.writes)
            accessed.update(a.dat.name for lp in seg for a in lp.args)
        if not sim:
            self._gather(state, sorted(modified))
        return reductions

    def _localize_segment(self, state, seg):
        """Per-shard local loop lists and their read sets (what the exchange
        refreshes and the per-device plans annotate)."""
        locals_by_shard = []
        names_by_shard: List[Tuple[str, ...]] = []
        for s in range(self.mesh.num_devices):
            local = [loc for lp in seg
                     if (loc := self._localize(state, lp, s)) is not None]
            locals_by_shard.append(local)
            names_by_shard.append(tuple(sorted(
                {a.dat.name for lp in local for a in lp.args
                 if a.mode.reads})))
        return locals_by_shard, names_by_shard

    @staticmethod
    def _chain_live_set(loops: Sequence[ParallelLoop]) -> frozenset:
        """Datasets the §4.1 cyclic elision may NOT touch at segment level:
        everything that is not write-first over the *whole* chain.  A
        segment's local classification can turn a chain-read-first dataset
        (``reset_field`` writing ``xvel0`` in the last segment) into a
        segment-write-first one — eliding its download would leave the home
        rows stale for the next chain's halo exchange, which ``ooc-cyclic``
        on the unsegmented chain would never do."""
        first: Dict[str, bool] = {}
        for lp in loops:
            for a in lp.args:
                if a.dat.name not in first:
                    first[a.dat.name] = not a.mode.reads
        return frozenset(n for n, wf in first.items() if not wf)

    @staticmethod
    def _warm_set(local_seg, accessed_earlier: Set[str]) -> frozenset:
        """Write-first dats of this shard's segment whose home copies hold
        earlier-segment results: the §4.1 write-first upload elision would
        let this segment's full-width download clobber them (e.g. halo
        columns a clipped-out mirror loop wrote on another shard), so they
        stage like read-first data instead."""
        first: Dict[str, bool] = {}
        for lp in local_seg:
            for a in lp.args:
                if a.dat.name not in first:
                    first[a.dat.name] = not a.mode.reads  # pure WRITE first
        return frozenset(n for n, wf in first.items()
                         if wf and n in accessed_earlier)

    def _run_segment(self, state, seg, keep_live, reductions, specs,
                     sim, accessed_earlier: Set[str]) -> None:
        locals_by_shard, names_by_shard = self._localize_segment(state, seg)
        done = [False]

        def runtime(op=None):
            # One collective per segment epoch.  Interpreters executing
            # their HaloExchange ops route here; the pre-fire below already
            # ran it, so they see it done.
            if not done[0]:
                done[0] = True
                self._exchange(state, names_by_shard)

        # Pre-fire the collective at segment start: shards run sequentially,
        # so a shard whose local segment has no reads (hence no halo op)
        # must not mutate its owned rows before a later shard's exchange
        # sources them.
        if not sim and any(names_by_shard):
            runtime()
        seg_stats: List[List[ChainStats]] = []
        for s in range(self.mesh.num_devices):
            local = locals_by_shard[s]
            if not local:
                seg_stats.append([])
                continue
            halo = self._halo_spec(state, s, names_by_shard[s])
            warm = self._warm_set(local, accessed_earlier)
            ex = self.inner[s]
            before = len(ex.history)
            ex.halo_runtime = runtime
            try:
                reds = ex.run_chain(local, keep_live, halo=halo, warm=warm)
            finally:
                ex.halo_runtime = None
            seg_stats.append(ex.history[before:])
            for name, val in reds.items():
                if name in reductions:
                    reductions[name] = np.asarray(
                        specs[name].combine(reductions[name], val))
                else:
                    reductions[name] = np.asarray(val)
        self.history.append(self._aggregate(seg_stats))

    def _aggregate(self, per_shard: List[List[ChainStats]]) -> ChainStats:
        """One mesh-level ChainStats per segment: traffic sums over devices,
        modelled time = the slowest device (they run concurrently)."""
        flat = [c for stats in per_shard for c in stats]
        modelled = max((sum(c.modelled_s for c in stats)
                        for stats in per_shard if stats), default=0.0)
        loop_bytes = sum(c.loop_bytes for c in flat)
        op_counts: Dict[str, int] = {}
        for c in flat:
            for k, v in c.op_counts.items():
                op_counts[k] = op_counts.get(k, 0) + v
        raw = sum(c.uploaded + c.downloaded for c in flat)
        wire = sum(c.uploaded_wire + c.downloaded_wire for c in flat)
        return ChainStats(
            num_tiles=max((c.num_tiles for c in flat), default=0),
            loop_bytes=loop_bytes,
            uploaded=sum(c.uploaded for c in flat),
            downloaded=sum(c.downloaded for c in flat),
            edge_bytes=sum(c.edge_bytes for c in flat),
            prefetch_hits=sum(c.prefetch_hits for c in flat),
            wall_s=sum(c.wall_s for c in flat),
            modelled_s=modelled,
            achieved_bw_model=loop_bytes / modelled if modelled else 0.0,
            slot_bytes=max((c.slot_bytes for c in flat), default=0),
            plan_cache_hit=all(c.plan_cache_hit for c in flat) if flat
            else False,
            plan_s=sum(c.plan_s for c in flat),
            uploaded_wire=sum(c.uploaded_wire for c in flat),
            downloaded_wire=sum(c.downloaded_wire for c in flat),
            compression_ratio=raw / wire if wire else 1.0,
            queue_wait_s=sum(c.queue_wait_s for c in flat),
            transfer_mode=flat[0].transfer_mode if flat else "sync",
            op_counts=op_counts,
            disk_read=sum(c.disk_read for c in flat),
            disk_written=sum(c.disk_written for c in flat),
            halo_messages=sum(c.halo_messages for c in flat),
            halo_bytes=sum(c.halo_bytes for c in flat),
        )

    # -- planning (Session.plan / explain / tune) ------------------------------
    def plan_chain(self, loops: Sequence[ParallelLoop],
                   keep_live: frozenset = frozenset(), *,
                   warm: frozenset = frozenset()):
        """Per-device Plan IRs (segments x shards) without executing or
        moving any data — what ``Session.plan()``/``explain()`` flatten into
        device-annotated instruction streams."""
        if self.mesh.num_devices == 1:
            return self.inner[0].plan_chain(loops, keep_live, warm=warm)
        state = self._state_for(loops)
        segments = split_segments(loops, self.shard_dim, state.skirt)
        plans = []
        accessed: Set[str] = set(warm)
        not_elidable = self._chain_live_set(loops)
        for i, seg in enumerate(segments):
            tail_reads = frozenset(
                a.dat.name for later in segments[i + 1:] for lp in later
                for a in lp.args if a.mode.reads)
            locals_by_shard, names_by_shard = self._localize_segment(
                state, seg)
            for s in range(self.mesh.num_devices):
                if not locals_by_shard[s]:
                    continue
                halo = self._halo_spec(state, s, names_by_shard[s])
                seg_warm = self._warm_set(locals_by_shard[s], accessed)
                plans.extend(self._plan_local(
                    self.inner[s], locals_by_shard[s],
                    keep_live | tail_reads | not_elidable,
                    halo, seg_warm))
            accessed.update(a.dat.name for lp in seg for a in lp.args)
        return ShardedChainPlan(ir=tuple(plans))

    def _plan_local(self, ex: OutOfCoreExecutor, local, keep_live, halo,
                    warm) -> List:
        """Plan one shard's local segment, mirroring ``run_chain``'s
        MemoryError split exactly (halo stays with the head; the tail
        warm-stages what the head wrote) — so ``Session.plan()``/
        ``explain()`` show the instruction streams execution will replay,
        and the plan cache is primed with the same keys.

        NOTE: this split policy (midpoint, tail_reads -> keep_live,
        head_writes -> warm, halo with the head) is implemented in three
        places that must stay in lock-step: ``OutOfCoreExecutor.run_chain``,
        ``Session._plan_split`` and here."""
        try:
            return [ex.plan_chain(local, keep_live, halo=halo,
                                  warm=warm).ir]
        except MemoryError:
            if len(local) <= 1:
                raise
            mid = len(local) // 2
            head, tail = local[:mid], local[mid:]
            tail_reads = frozenset(
                a.dat.name for lp in tail for a in lp.args if a.mode.reads)
            head_writes = frozenset(
                a.dat.name for lp in head for a in lp.args
                if a.mode.writes)
            return (self._plan_local(ex, head, keep_live | tail_reads,
                                     halo, warm)
                    + self._plan_local(ex, tail, keep_live, None,
                                       warm | head_writes))
