"""Stencils — the access patterns parallel loops use to read/write datasets.

Mirrors ``ops_stencil``: a set of relative offsets.  The *extent* of a stencil
per dimension drives both the skewed-tiling slopes (:mod:`repro.core.tiling`)
and footprint computation for out-of-core transfers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class Stencil:
    """A named set of relative index offsets.

    Attributes:
      name: identifier (for diagnostics).
      points: tuple of offset tuples, e.g. ``((0, 0), (1, 0), (-1, 0))``.
    """

    name: str
    points: Tuple[Tuple[int, ...], ...]

    def __post_init__(self) -> None:
        if not self.points:
            raise ValueError(f"stencil {self.name!r}: empty")
        nd = len(self.points[0])
        if any(len(p) != nd for p in self.points):
            raise ValueError(f"stencil {self.name!r}: inconsistent arity")

    @property
    def ndim(self) -> int:
        return len(self.points[0])

    def extent(self, dim: int) -> Tuple[int, int]:
        """(min_offset, max_offset) along ``dim``."""
        offs = [p[dim] for p in self.points]
        return min(offs), max(offs)

    def max_abs_extent(self, dim: int) -> int:
        lo, hi = self.extent(dim)
        return max(abs(lo), abs(hi))

    def is_zero(self) -> bool:
        return all(all(o == 0 for o in p) for p in self.points)


def point_stencil(ndim: int) -> Stencil:
    """The 0-offset stencil (the only one legal for WRITE/RW/INC access)."""
    return Stencil(f"S{ndim}D_000", (tuple(0 for _ in range(ndim)),))


def star_stencil(ndim: int, radius: int = 1) -> Stencil:
    """Von-Neumann (star) stencil: centre plus ±r along each axis."""
    pts = [tuple(0 for _ in range(ndim))]
    for d in range(ndim):
        for r in range(1, radius + 1):
            for sgn in (-1, 1):
                p = [0] * ndim
                p[d] = sgn * r
                pts.append(tuple(p))
    return Stencil(f"S{ndim}D_star{radius}", tuple(pts))


def box_stencil(ndim: int, radius: int = 1) -> Stencil:
    """Moore (box) stencil: all offsets with |o_d| <= radius."""
    import itertools

    rng = range(-radius, radius + 1)
    pts = tuple(itertools.product(rng, repeat=ndim))
    return Stencil(f"S{ndim}D_box{radius}", pts)


def offset_stencil(*offsets: Tuple[int, ...]) -> Stencil:
    """Ad-hoc stencil from explicit offsets."""
    name = "S_" + "_".join("m".join(str(o).replace("-", "n") for o in p) for p in offsets)
    return Stencil(name[:64], tuple(tuple(p) for p in offsets))
