"""Device meshes: the decomposition axis of sharded out-of-core execution.

The paper's evaluation (§5.2) runs tiled chains across 4 KNL processes,
decomposing the grid along the *non*-tiled dimension so out-of-core slab
tiling (dim 0) composes with MPI-style decomposition (dim 1).  This module
makes that device dimension a first-class API object:

* :class:`DeviceMesh` — ``sim:N`` *virtual* devices (the decomposition is
  exact, exchanges are host-side copies, any N works on a 1-device machine)
  or ``jax:N`` *real* JAX devices (halo exchanges run through the
  ``ppermute`` path of :func:`repro.core.distributed.exchange_halos` under
  ``shard_map``).
* :class:`ShardGeometry` — one device's slice of the global grid: the owned
  interval along the shard dimension plus the redundant-compute *skirt*
  (accumulated halo depth) on each interior side.
* :class:`HaloSpec` — the per-device annotation :func:`repro.core.plan.build_plan`
  lowers into ``HaloPack``/``HaloExchange``/``HaloUnpack`` ops: exchange
  depth, message count and byte totals, so the ledger model and the real
  runtime account halo traffic identically.

``ExecutionConfig(mesh=...)`` accepts a :class:`DeviceMesh`, an int
(``sim`` mesh of that size) or a string spec (``"sim:4"``, ``"jax:2"``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple, Union


class MeshError(ValueError):
    """Bad mesh spec, or a grid that cannot be decomposed as requested."""


@dataclass(frozen=True)
class DeviceMesh:
    """A 1-D mesh of execution devices for grid decomposition.

    ``kind="sim"`` — virtual devices: shards execute sequentially in this
    process (each through its own out-of-core interpreter) and halo
    exchanges are host-side copies between shard home arrays.  Correctness
    and cost modelling are exact on any machine, including 1-device CI.

    ``kind="jax"`` — real JAX devices: halo exchanges additionally run the
    ``ppermute`` collective under ``shard_map`` across the first
    ``num_devices`` entries of ``jax.devices()`` (forced host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` count).
    """

    num_devices: int
    kind: str = "sim"
    axis_name: str = "shard"

    def __post_init__(self) -> None:
        if self.num_devices < 1:
            raise MeshError(f"mesh needs >= 1 device, got {self.num_devices}")
        if self.kind not in ("sim", "jax"):
            raise MeshError(f"unknown mesh kind {self.kind!r} "
                            f"(expected 'sim' or 'jax')")

    @classmethod
    def sim(cls, n: int, axis_name: str = "shard") -> "DeviceMesh":
        return cls(num_devices=n, kind="sim", axis_name=axis_name)

    @classmethod
    def devices(cls, n: Optional[int] = None,
                axis_name: str = "shard") -> "DeviceMesh":
        """A mesh over real JAX devices (all of them if ``n`` is None)."""
        if n is None:
            import jax

            n = len(jax.devices())
        return cls(num_devices=n, kind="jax", axis_name=axis_name)

    @property
    def spec(self) -> str:
        return f"{self.kind}:{self.num_devices}"

    def jax_mesh(self):
        """The concrete ``jax.sharding.Mesh`` over the first ``num_devices``
        devices (``kind="jax"`` only)."""
        if self.kind != "jax":
            raise MeshError(f"{self.spec!r} is a virtual mesh; only "
                            f"kind='jax' meshes materialise jax.sharding.Mesh")
        import jax
        import numpy as np
        from jax.sharding import Mesh

        devs = jax.devices()
        if len(devs) < self.num_devices:
            raise MeshError(
                f"mesh {self.spec!r} needs {self.num_devices} JAX devices, "
                f"only {len(devs)} available (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N for CPU testing)")
        return Mesh(np.asarray(devs[: self.num_devices]), (self.axis_name,))


def parse_mesh(spec: Union[None, int, str, DeviceMesh]) -> Optional[DeviceMesh]:
    """Normalise a user-facing mesh spec: None, int (=> sim:N), "sim:N" /
    "jax:N", or a ready :class:`DeviceMesh`."""
    if spec is None or isinstance(spec, DeviceMesh):
        return spec
    if isinstance(spec, int):
        return DeviceMesh.sim(spec)
    if isinstance(spec, str):
        kind, _, n = spec.partition(":")
        if not n and kind.isdigit():
            return DeviceMesh.sim(int(kind))
        if kind in ("sim", "jax") and n.isdigit():
            return DeviceMesh(num_devices=int(n), kind=kind)
        raise MeshError(f"bad mesh spec {spec!r} (expected 'sim:N' or 'jax:N')")
    raise MeshError(f"bad mesh spec {spec!r} of type {type(spec).__name__}")


# -- per-shard geometry -----------------------------------------------------------


@dataclass(frozen=True)
class ShardGeometry:
    """One device's slice of the global extent along the shard dimension.

    ``[lo, hi)`` is the *owned* interval (the owned intervals partition the
    global interior exactly — reductions and gathers use them).
    ``skirt_lo``/``skirt_hi`` are the redundant-compute skirts toward
    interior neighbours (0 at the global edges): after one accumulated-depth
    halo exchange the shard runs the whole (sub-)chain over
    ``[lo - skirt_lo, hi + skirt_hi)`` and only the owned interior is
    guaranteed — exactly the paper's §5.2 halo-deep compute."""

    index: int
    lo: int
    hi: int
    skirt_lo: int
    skirt_hi: int

    @property
    def width(self) -> int:
        return self.hi - self.lo

    @property
    def ext_lo(self) -> int:
        """Global coordinate of the shard's extended-region start."""
        return self.lo - self.skirt_lo

    @property
    def ext_hi(self) -> int:
        return self.hi + self.skirt_hi

    @property
    def ext_size(self) -> int:
        return self.ext_hi - self.ext_lo

    def to_local(self, g: int) -> int:
        """Global grid coordinate -> this shard's local grid coordinate."""
        return g - self.ext_lo


def shard_geometries(extent: int, num_devices: int,
                     skirt: int) -> List[ShardGeometry]:
    """Contiguous partition of ``[0, extent)`` over ``num_devices`` shards
    (remainder spread over the first shards), with ``skirt`` redundant rows
    on every *interior* side."""
    n = num_devices
    if extent < n:
        raise MeshError(f"cannot shard extent {extent} over {n} devices")
    base, rem = divmod(extent, n)
    geos: List[ShardGeometry] = []
    lo = 0
    for s in range(n):
        hi = lo + base + (1 if s < rem else 0)
        geos.append(ShardGeometry(
            index=s, lo=lo, hi=hi,
            skirt_lo=skirt if s > 0 else 0,
            skirt_hi=skirt if s < n - 1 else 0))
        lo = hi
    return geos


# -- plan-level halo annotation ---------------------------------------------------


@dataclass(frozen=True)
class HaloSpec:
    """What one device's chain plan needs to know about its halo exchange:
    lowered by ``build_plan`` into ``HaloPack``/``HaloExchange``/
    ``HaloUnpack`` ops.  Hashable (part of the executor's plan-cache key).

    ``depth`` is the exchange depth in rows per interior side (skirt +
    dataset halo); ``messages``/``nbytes`` count what *this* device receives
    (so summing over devices gives the mesh-global totals); ``names`` are
    the datasets exchanged (the segment's read set)."""

    device: int
    num_devices: int
    shard_dim: int
    depth: int
    messages: int
    nbytes: int
    names: Tuple[str, ...]
