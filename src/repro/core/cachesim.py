"""Cache-mode / unified-memory execution model (paper §5.2, §5.4).

There is no MCDRAM-as-cache or CUDA page migration on this container, so the
paper's *implicit* memory-management configurations are reproduced with an
explicit page-granular LRU model driven by the exact access streams the
runtime schedules (untiled loop-by-loop, or the skewed tile schedule).

Modes:
  * ``flat_fast``  — everything in fast memory (errors if it can't fit).
  * ``flat_slow``  — everything in slow memory (DDR4-only configuration).
  * ``cache``      — fast memory is an LRU page cache over slow memory (KNL
    cache mode; miss service at slow_bw, hardware-prefetch-friendly).
  * ``um``         — GPU unified memory: page faults serviced one-by-one at
    ``page_fault_latency`` + page/upload-bw (latency-bound, matching §5.4's
    observation that UM throughput is the same on PCIe and NVLink).
  * ``um_prefetch``— UM + bulk ``cudaMemPrefetchAsync``-style moves: misses
    of a loop are batched and moved at link bandwidth with one latency.

Because regions are slabs (dim-0 intervals × full rows), page ranges are
contiguous and the model is exact, not sampled.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .dependency import ChainInfo, analyze_chain
from .loop import ParallelLoop
from .memory import HardwareModel
from .tiling import make_tile_schedule


@dataclass
class CacheStats:
    mode: str
    time_s: float = 0.0
    useful_bytes: int = 0
    hit_bytes: int = 0
    miss_bytes: int = 0
    writeback_bytes: int = 0
    faults: int = 0

    @property
    def achieved_bw(self) -> float:
        return self.useful_bytes / self.time_s if self.time_s else 0.0

    @property
    def hit_rate(self) -> float:
        tot = self.hit_bytes + self.miss_bytes
        return self.hit_bytes / tot if tot else 1.0


class _LRU:
    """Page cache: key -> dirty flag."""

    def __init__(self, capacity_pages: int):
        self.cap = capacity_pages
        self.pages: "OrderedDict[Tuple[str,int], bool]" = OrderedDict()

    def touch(self, key, dirty: bool) -> Tuple[bool, int]:
        """Returns (hit, evicted_dirty_count_from_insert)."""
        if key in self.pages:
            self.pages[key] = self.pages[key] or dirty
            self.pages.move_to_end(key)
            return True, 0
        evict_dirty = 0
        while len(self.pages) >= self.cap:
            _, was_dirty = self.pages.popitem(last=False)
            evict_dirty += int(was_dirty)
        self.pages[key] = dirty
        return False, evict_dirty


def _access_items(
    loops: Sequence[ParallelLoop], tiled: bool, num_tiles: int, tiled_dim: int = 0
) -> Iterable[Tuple[ParallelLoop, Tuple[Tuple[int, int], ...]]]:
    if not tiled:
        for lp in loops:
            yield lp, lp.range_
        return
    info = analyze_chain(loops, tiled_dim=tiled_dim)
    sched = make_tile_schedule(info, num_tiles)
    for tile in sched.tiles:
        for k, box in enumerate(tile.loop_ranges):
            if box is not None:
                yield info.loops[k], box


def simulate_chain(
    loops: Sequence[ParallelLoop],
    hw: HardwareModel,
    mode: str = "cache",
    tiled: bool = False,
    num_tiles: int = 1,
    tiled_dim: int = 0,
    warmup: bool = True,
) -> CacheStats:
    """Model one chain's steady-state execution time under the given mode.

    ``warmup=True`` (default) replays the access stream once before
    measuring, so cold-start compulsory misses don't pollute the steady-state
    bandwidth (the paper measures many timesteps of a warm working set)."""
    stats = CacheStats(mode=mode)
    total_bytes = sum(d.nbytes for d in analyze_chain(loops).datasets.values())

    if mode == "flat_fast":
        if total_bytes > hw.fast_capacity:
            raise MemoryError(
                f"flat_fast: {total_bytes}B > {hw.fast_capacity}B fast memory "
                "(the paper's segfault)"
            )
        for lp, box in _access_items(loops, tiled, num_tiles, tiled_dim):
            nb = _box_bytes(lp, box)
            stats.useful_bytes += nb
            stats.time_s += nb / hw.dd_bw  # flat MCDRAM/HBM bandwidth
        return stats
    if mode == "flat_slow":
        for lp, box in _access_items(loops, tiled, num_tiles, tiled_dim):
            nb = _box_bytes(lp, box)
            stats.useful_bytes += nb
            stats.time_s += nb / hw.slow_bw
        return stats

    lru = _LRU(max(1, int(hw.fast_capacity // hw.page_bytes)))
    if warmup and mode in ("cache", "um", "um_prefetch"):
        for lp, box in _access_items(loops, tiled, num_tiles, tiled_dim):
            for arg in lp.args:
                lo, hi = _slab_interval(lp, box, arg)
                dat = arg.dat
                row_bytes = dat.nbytes // dat.padded_shape[0]
                b0 = (lo + dat.halo[0][0]) * row_bytes
                b1 = (hi + dat.halo[0][0]) * row_bytes
                p0, p1 = b0 // hw.page_bytes, (max(b1 - 1, b0)) // hw.page_bytes
                for p in range(p0, p1 + 1):
                    lru.touch((dat.name, p), arg.mode.writes)
    for lp, box in _access_items(loops, tiled, num_tiles, tiled_dim):
        nb = _box_bytes(lp, box)
        stats.useful_bytes += nb
        miss_pages = 0
        hit_pages = 0
        wb_pages = 0
        for arg in lp.args:
            lo, hi = _slab_interval(lp, box, arg)
            dat = arg.dat
            row_bytes = dat.nbytes // dat.padded_shape[0]
            b0 = (lo + dat.halo[0][0]) * row_bytes
            b1 = (hi + dat.halo[0][0]) * row_bytes
            p0, p1 = b0 // hw.page_bytes, (max(b1 - 1, b0)) // hw.page_bytes
            for p in range(p0, p1 + 1):
                hit, evicted = lru.touch((dat.name, p), arg.mode.writes)
                wb_pages += evicted
                if hit:
                    hit_pages += 1
                else:
                    miss_pages += 1
        hit_b = hit_pages * hw.page_bytes
        miss_b = miss_pages * hw.page_bytes
        wb_b = wb_pages * hw.page_bytes
        stats.hit_bytes += hit_b
        stats.miss_bytes += miss_b
        stats.writeback_bytes += wb_b
        stats.faults += miss_pages
        if mode == "cache":
            t = nb / hw.fast_bw + (miss_b + wb_b) / hw.slow_bw
        elif mode == "um":
            t = nb / hw.fast_bw + miss_pages * hw.page_fault_latency \
                + (miss_b + wb_b) / hw.up_bw
        elif mode == "um_prefetch":
            # one bulk prefetch per loop; driver CPU overhead per call, and
            # (paper §5.4) prefetch throughput degrades when oversubscribed.
            oversub = total_bytes > hw.fast_capacity
            eff_bw = hw.up_bw * (0.6 if oversub else 1.0)
            t = nb / hw.fast_bw + (hw.page_fault_latency if miss_pages else 0.0) \
                + (miss_b + wb_b) / eff_bw
        else:
            raise ValueError(f"unknown mode {mode!r}")
        stats.time_s += t
    return stats


def _box_bytes(lp: ParallelLoop, box) -> int:
    npts = 1
    for a, b in box:
        npts *= b - a
    full = 1
    for a, b in lp.range_:
        full *= b - a
    return int(lp.bytes_moved() * (npts / full)) if full else 0


def _slab_interval(lp: ParallelLoop, box, arg) -> Tuple[int, int]:
    lo, hi = box[0]
    if arg.mode.reads:
        mn, mx = arg.stencil.extent(0)
        lo, hi = lo + mn, hi + mx
    blo, bhi = arg.dat.bounds(0)
    return max(lo, blo), min(hi, bhi)
