"""Reference executor: direct, eager, NumPy, no tiling, no staging.

The oracle every other execution strategy is validated against (unit,
integration and hypothesis property tests): loops run in program order,
reads/writes hit the home arrays directly.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from .loop import AccessMode, Accessor, ParallelLoop


class _NumpyAccessor(Accessor):
    def __init__(self, loop: ParallelLoop):
        self._loop = loop
        self._dats = {a.dat.name: a.dat for a in loop.args}
        self.shape = tuple(b - a for a, b in loop.range_)

    def coords(self):
        lp = self._loop
        nd = lp.block.ndim
        out = []
        for d in range(nd):
            ar = np.arange(lp.range_[d][0], lp.range_[d][1], dtype=np.int32)
            shape = [1] * nd
            shape[d] = ar.size
            out.append(np.broadcast_to(ar.reshape(shape), self.shape))
        return tuple(out)

    def __call__(self, name: str, offset: Tuple[int, ...] = None):
        lp = self._loop
        nd = lp.block.ndim
        if offset is None:
            offset = (0,) * nd
        dat = self._dats[name]
        idx = tuple(
            slice(lp.range_[d][0] + offset[d] + dat.halo[d][0],
                  lp.range_[d][1] + offset[d] + dat.halo[d][0])
            for d in range(nd)
        )
        # Store-routed so the oracle also runs over mmap/chunked homes.
        return dat.read_region(idx)


def run_loop_reference(lp: ParallelLoop) -> Dict[str, np.ndarray]:
    """Execute one loop eagerly; returns reduction results (if any)."""
    acc = _NumpyAccessor(lp)
    out = lp.kernel(acc)
    writes = {}
    for arg in lp.args:
        if not arg.mode.writes:
            continue
        # Copy: kernels may return views of the very arrays we are about to
        # mutate (e.g. pure copy loops) — overlapping-view assignment corrupts.
        vals = np.array(out[arg.dat.name], dtype=arg.dat.dtype, copy=True)
        writes[arg.dat.name] = (arg, vals)
    # Two-phase commit so RW loops read pre-loop values (parallel semantics).
    for name, (arg, vals) in writes.items():
        dat = arg.dat
        idx = tuple(
            slice(lp.range_[d][0] + dat.halo[d][0], lp.range_[d][1] + dat.halo[d][0])
            for d in range(lp.block.ndim)
        )
        if arg.mode is AccessMode.INC:
            dat.write_region(idx, dat.read_region(idx) + vals)
        else:
            dat.write_region(idx, vals)
    reds = {}
    for rspec in lp.reductions:
        reds[rspec.name] = np.asarray(out[rspec.name])
    return reds


def merge_loop_reductions(
    merged: Dict[str, np.ndarray], lp: ParallelLoop, reds: Dict[str, np.ndarray]
) -> None:
    """Fold one loop's reduction results into ``merged`` via each spec's op."""
    for name, val in reds.items():
        spec = next(r for r in lp.reductions if r.name == name)
        if name in merged:
            merged[name] = np.asarray(spec.combine(merged[name], val))
        else:
            merged[name] = val


def run_chain_reference(loops: Sequence[ParallelLoop]) -> Dict[str, np.ndarray]:
    """Execute a chain eagerly in program order; merge reductions."""
    merged: Dict[str, np.ndarray] = {}
    for lp in loops:
        merge_loop_reductions(merged, lp, run_loop_reference(lp))
    return merged
