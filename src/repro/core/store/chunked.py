"""``chunked`` backing store: compressed fixed-size chunks on disk + an LRU
decompressed-chunk cache with a byte budget in RAM.

This is the tier that makes problems *larger than host RAM* representable
(Shen et al. 2022's compressed out-of-core design applied one level down):
the array is split into slabs of whole rows along axis 0, each slab lives on
disk as one codec-compressed payload, and only the chunks the executor is
currently staging are held decompressed in RAM.  The cache budget is the
host-RAM working-set bound — touch more rows than fit and the LRU end is
compressed back out (dirty chunks only; clean ones are simply dropped).

Compression uses the :mod:`repro.core.transfer.codecs` registry.  The default
is the lossless ``shuffle-rle``; a lossy codec (``fp16``/``bf16``) degrades
the *home copy itself* on every evict/reload cycle, not just the wire — the
README's safety note applies doubly here.

Chunk files are written atomically (write-to-temp + ``os.replace``) so a
killed run never leaves a torn chunk behind; together with
``Session.checkpoint``'s atomic manifest this is what makes multi-hour
out-of-core runs restartable.

Thread safety: one re-entrant lock serialises all public operations — the
transfer engine's upload, download and disk-fetch workers share a store.
"""
from __future__ import annotations

import json
import os
import threading
from collections import OrderedDict
from typing import Set, Tuple

import numpy as np

from .base import BackingStore, Index, StoreConfig, StoreError, register_store


def _get_codec(name: str):
    # Function-level: the transfer package reaches back into dataset.py via
    # the residency/dependency modules, so importing it at module scope would
    # close an import cycle (store <- dataset <- dependency <- transfer).
    from ..transfer.codecs import get_codec

    return get_codec(name)


class ChunkedStore(BackingStore):
    kind = "chunked"

    def __init__(self, directory: str, shape: Tuple[int, ...], dtype, *,
                 chunk_bytes: int = 1 << 20, cache_bytes: int = 64 << 20,
                 codec: str = "shuffle-rle"):
        super().__init__(shape, dtype)
        if not shape:
            raise StoreError("chunked store needs at least one dimension")
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.codec = _get_codec(codec) if isinstance(codec, str) else codec
        row_nbytes = self.dtype.itemsize
        for s in shape[1:]:
            row_nbytes *= s
        self.chunk_rows = max(1, int(chunk_bytes) // max(1, row_nbytes))
        self.num_chunks = -(-self.shape[0] // self.chunk_rows)
        self.cache_bytes = int(cache_bytes)
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._cached_bytes = 0
        self._dirty: Set[int] = set()
        self._on_disk: Set[int] = {
            i for i in range(self.num_chunks)
            if os.path.exists(self._chunk_path(i))
        }
        self._lock = threading.RLock()

    # -- chunk geometry -------------------------------------------------------
    def _chunk_path(self, i: int) -> str:
        return os.path.join(self.directory, f"chunk_{i:06d}.npz")

    def _chunk_shape(self, i: int) -> Tuple[int, ...]:
        rows = min(self.chunk_rows, self.shape[0] - i * self.chunk_rows)
        return (rows,) + self.shape[1:]

    def _norm(self, index: Index) -> Tuple[slice, ...]:
        index = tuple(index)
        if len(index) > self.ndim:
            raise StoreError(f"index arity {len(index)} > ndim {self.ndim}")
        index = index + tuple(slice(None) for _ in range(self.ndim - len(index)))
        out = []
        for d, sl in enumerate(index):
            if not isinstance(sl, slice):
                raise StoreError("chunked stores accept slice indices only")
            lo, hi, step = sl.indices(self.shape[d])
            if step != 1:
                raise StoreError("chunked stores accept unit-step slices only")
            out.append(slice(lo, hi))
        return tuple(out)

    # -- disk round-trip ------------------------------------------------------
    def _load_chunk(self, i: int) -> np.ndarray:
        if i in self._on_disk:
            with open(self._chunk_path(i), "rb") as f:
                with np.load(f) as z:
                    meta = json.loads(bytes(z["meta"].tobytes()))
                    payload = z["payload"]
                    self.stats["disk_bytes_read"] += int(payload.nbytes)
            codec = _get_codec(meta.pop("codec"))
            # Fresh writable array: shuffle-rle decodes via frombuffer views.
            arr = np.array(codec.decode(payload, meta), dtype=self.dtype,
                           copy=True)
            # A reopened spill dir written under different geometry (other
            # chunk_bytes / array shape / dtype) must fail loudly, not feed
            # wrong-shaped slabs into read()'s concatenation.
            expect = self._chunk_shape(i)
            if arr.shape != expect or np.dtype(meta.get("dtype", self.dtype)) \
                    != self.dtype:
                raise StoreError(
                    f"chunk {i} in {self.directory!r} is {arr.shape} "
                    f"{meta.get('dtype')}, store geometry expects {expect} "
                    f"{self.dtype.str} — was this directory written with "
                    f"different chunk_bytes/shape/dtype?")
            return arr
        return np.zeros(self._chunk_shape(i), dtype=self.dtype)

    def _store_chunk(self, i: int, arr: np.ndarray) -> int:
        payload, meta = self.codec.encode(arr)
        payload = np.asarray(payload)
        meta = {**meta, "codec": self.codec.name,
                "dtype": self.dtype.str, "shape": list(arr.shape)}
        meta_u8 = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
        path = self._chunk_path(i)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, payload=payload, meta=meta_u8)
        os.replace(tmp, path)
        self._on_disk.add(i)
        written = int(payload.nbytes)
        self.stats["disk_bytes_written"] += written
        return written

    # -- the LRU cache --------------------------------------------------------
    def _get(self, i: int) -> np.ndarray:
        arr = self._cache.get(i)
        if arr is not None:
            self._cache.move_to_end(i)
            self.stats["cache_hits"] += 1
            return arr
        self.stats["cache_misses"] += 1
        arr = self._load_chunk(i)
        self._cache[i] = arr
        self._cached_bytes += arr.nbytes
        self._shrink(keep=i)
        return arr

    def _shrink(self, keep: int) -> None:
        """Evict LRU chunks until the cache fits its byte budget (the chunk
        just touched is never evicted, so a budget smaller than one chunk
        degrades to exactly-one-resident rather than thrashing forever)."""
        while self._cached_bytes > self.cache_bytes and len(self._cache) > 1:
            i, arr = next(iter(self._cache.items()))
            if i == keep:
                self._cache.move_to_end(i)
                continue
            self._evict(i)

    def _evict(self, i: int) -> int:
        arr = self._cache.pop(i)
        self._cached_bytes -= arr.nbytes
        self.stats["chunk_evictions"] += 1
        if i in self._dirty:
            self._dirty.discard(i)
            return self._store_chunk(i, arr)
        return 0

    def _overlapping(self, lo: int, hi: int) -> range:
        if hi <= lo:
            return range(0)
        return range(lo // self.chunk_rows, (hi - 1) // self.chunk_rows + 1)

    # -- data access ----------------------------------------------------------
    def read(self, index: Index) -> np.ndarray:
        index = self._norm(index)
        lo, hi = index[0].start, index[0].stop
        rest = index[1:]
        out_shape = (max(0, hi - lo),) + tuple(s.stop - s.start for s in rest)
        if out_shape[0] <= 0:
            return np.empty(out_shape, dtype=self.dtype)
        with self._lock:
            chunks = self._overlapping(lo, hi)
            if len(chunks) == 1:
                i = chunks[0]
                base = i * self.chunk_rows
                return np.array(self._get(i)[(slice(lo - base, hi - base),)
                                             + rest], copy=True)
            # Preallocate and fill chunk-by-chunk: a full-array read (e.g.
            # materialize() for a checkpoint) then peaks at one uncompressed
            # copy plus the cache budget, not two copies — and the LRU keeps
            # shrinking behind the scan instead of pinning every chunk in a
            # parts list.
            out = np.empty(out_shape, dtype=self.dtype)
            for i in chunks:
                base = i * self.chunk_rows
                rows = self._chunk_shape(i)[0]
                clo, chi = max(lo, base), min(hi, base + rows)
                out[clo - lo:chi - lo] = \
                    self._get(i)[(slice(clo - base, chi - base),) + rest]
            return out

    def write(self, index: Index, values) -> None:
        index = self._norm(index)
        lo, hi = index[0].start, index[0].stop
        rest = index[1:]
        tshape = (max(0, hi - lo),) + tuple(s.stop - s.start for s in rest)
        if tshape[0] <= 0:
            return
        vals = np.broadcast_to(np.asarray(values, dtype=self.dtype), tshape)
        with self._lock:
            for i in self._overlapping(lo, hi):
                base = i * self.chunk_rows
                rows = self._chunk_shape(i)[0]
                clo, chi = max(lo, base), min(hi, base + rows)
                arr = self._get(i)
                arr[(slice(clo - base, chi - base),) + rest] = \
                    vals[clo - lo:chi - lo]
                self._dirty.add(i)
                self._cache.move_to_end(i)
            self._shrink(keep=(hi - 1) // self.chunk_rows)

    # -- disk-tier hooks ------------------------------------------------------
    def prefetch(self, index: Index) -> int:
        """Decompress the indexed rows' chunks into the cache ahead of the
        staging read; returns disk bytes actually read (0 on full cache hit)."""
        index = self._norm(index)
        lo, hi = index[0].start, index[0].stop
        with self._lock:
            before = self.stats["disk_bytes_read"]
            for i in self._overlapping(lo, hi):
                self._get(i)
            return self.stats["disk_bytes_read"] - before

    def spill(self, index: Index) -> int:
        """Retire the indexed rows to disk: dirty overlapping chunks are
        compressed out; chunks *fully* covered by the row range are also
        dropped from the cache (their rows are done for this chain), which is
        what keeps the resident set inside the budget on oversubscribed
        runs.  Returns disk bytes written."""
        index = self._norm(index)
        lo, hi = index[0].start, index[0].stop
        written = 0
        with self._lock:
            for i in self._overlapping(lo, hi):
                base = i * self.chunk_rows
                rows = self._chunk_shape(i)[0]
                fully = lo <= base and base + rows <= hi
                if i in self._cache and fully:
                    written += self._evict(i)
                elif i in self._dirty:
                    written += self._store_chunk(i, self._cache[i])
                    self._dirty.discard(i)
        return written

    def flush(self) -> int:
        with self._lock:
            written = 0
            for i in sorted(self._dirty):
                written += self._store_chunk(i, self._cache[i])
            self._dirty.clear()
            return written

    def close(self) -> None:
        with self._lock:
            self.flush()
            self._cache.clear()
            self._cached_bytes = 0

    # -- introspection --------------------------------------------------------
    def cache_keys(self) -> Tuple[int, ...]:
        """Resident chunk ids, LRU-first (tests assert eviction ordering)."""
        with self._lock:
            return tuple(self._cache)

    def cache_resident_bytes(self) -> int:
        with self._lock:
            return self._cached_bytes


@register_store("chunked")
def _chunked(config: StoreConfig, name: str, shape, dtype,
             data=None) -> ChunkedStore:
    directory = os.path.join(config.resolved_directory("chunked"), name)
    store = ChunkedStore(directory, shape, dtype,
                         chunk_bytes=config.chunk_bytes,
                         cache_bytes=config.cache_bytes, codec=config.codec)
    if data is not None:
        store.write(tuple(slice(None) for _ in shape), data)
    return store
