"""Backing stores: where a dataset's slow-memory *home copy* actually lives.

The paper breaks the fast-memory wall (device HBM); this subsystem breaks the
next one.  A :class:`~repro.core.dataset.Dataset`'s home used to be a plain
in-RAM NumPy array, so the runtime's memory hierarchy stopped one level short:
problems larger than *host* RAM simply could not be represented.  Following
the OPS run-time tiling line of work (host as just another cache level) and
Shen et al.'s compression-based out-of-core GPU stencils (a compressed disk
tier plus overlapped I/O keeps such runs transfer-bound rather than
capacity-bound), a home copy is now an object behind one interface:

==============  ===============================================================
``ram``         the previous behaviour — a NumPy array, zero overhead (default)
``mmap``        ``np.memmap`` over a file in a spill directory; tile rows are
                read/written in place, the OS page cache is the host tier
``chunked``     fixed-size row chunks compressed with the PR 2 codec registry
                on disk, an LRU *decompressed-chunk* cache with a byte budget
                in RAM, per-chunk dirty tracking
==============  ===============================================================

The store works in *array index* space (padded-array indices); grid-coordinate
translation stays in :class:`~repro.core.dataset.Dataset`.  All stores are
thread-safe where it matters: the transfer engine's upload, download and disk
workers may touch one store concurrently.

``stats`` counts disk traffic (``disk_bytes_read`` / ``disk_bytes_written``
are the payload bytes that crossed the disk boundary — for ``mmap``, the
bytes moved through the API, since the page cache makes true device I/O
unobservable) plus chunk-cache behaviour for ``chunked``.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np

Index = Tuple[slice, ...]


class StoreError(RuntimeError):
    """A backing-store operation is invalid (wrong shape, closed store, or an
    operation the store kind cannot support, like ``.data`` on ``chunked``)."""


class BackingStore:
    """One dataset home copy: an n-d array of ``shape``/``dtype`` somewhere.

    ``read`` may return a view (``ram``/``mmap``) or a fresh array
    (``chunked``); callers must not rely on mutating the result.  ``write``
    broadcasts ``values`` over the indexed region.  ``prefetch``/``spill``
    are the disk-tier hooks the executor's FetchHome/SpillHome ops drive:
    no-ops for RAM-resident stores, real traffic for ``chunked``.
    """

    kind: str = "?"

    def __init__(self, shape: Tuple[int, ...], dtype):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.stats: Dict[str, int] = {
            "disk_bytes_read": 0, "disk_bytes_written": 0,
            "cache_hits": 0, "cache_misses": 0, "chunk_evictions": 0,
        }

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        """Logical (uncompressed) size of the stored array."""
        n = self.dtype.itemsize
        for s in self.shape:
            n *= s
        return int(n)

    def _full_index(self) -> Index:
        return tuple(slice(0, s) for s in self.shape)

    # -- data access ----------------------------------------------------------
    def read(self, index: Index) -> np.ndarray:
        raise NotImplementedError

    def write(self, index: Index, values) -> None:
        raise NotImplementedError

    def as_array(self) -> np.ndarray:
        """The live backing array, for stores that have one (``ram``/``mmap``).

        Raises :class:`StoreError` otherwise — code that must work with every
        store kind uses ``read``/``write``/``materialize`` instead."""
        raise StoreError(
            f"{self.kind!r} store has no single in-RAM backing array; "
            f"use read()/write()/materialize()")

    def materialize(self) -> np.ndarray:
        """The whole array (a view for RAM-resident stores, assembled fresh
        for ``chunked``) — what checkpointing and ``fetch_raw`` consume."""
        return np.asarray(self.read(self._full_index()))

    # -- disk-tier hooks ------------------------------------------------------
    def prefetch(self, index: Index) -> int:
        """Make the indexed region RAM-resident; returns disk bytes read."""
        return 0

    def spill(self, index: Index) -> int:
        """Push the indexed region's dirty state to disk (and release RAM
        where the store can); returns disk bytes written."""
        return 0

    def flush(self) -> int:
        """Persist everything dirty; returns disk bytes written."""
        return 0

    def close(self) -> None:
        """Flush and release resources; the store is unusable afterwards."""
        self.flush()


class RamStore(BackingStore):
    """Today's behaviour: the home copy is a plain NumPy array.

    Wraps the given array *without copying* so existing code holding the
    array (e.g. via ``Dataset.data``) keeps seeing every update."""

    kind = "ram"

    def __init__(self, array: np.ndarray):
        array = np.asarray(array)
        super().__init__(array.shape, array.dtype)
        self._arr = array

    def read(self, index: Index) -> np.ndarray:
        return self._arr[index]

    def write(self, index: Index, values) -> None:
        self._arr[index] = values

    def as_array(self) -> np.ndarray:
        return self._arr

    def materialize(self) -> np.ndarray:
        return self._arr


# -- configuration + registry -----------------------------------------------------


@dataclass(frozen=True)
class StoreConfig:
    """Declarative store selection for :func:`make_store` /
    ``make_dataset(store=...)``.

    ``directory`` is the spill directory for disk-backed kinds; when ``None``
    a fresh ``tempfile.mkdtemp`` directory is created per dataset (see the
    README's spill-dir hygiene notes — temp spill dirs are *not* auto-deleted
    so ``mmap`` homes survive reopen).  ``codec`` names a codec from the
    :mod:`repro.core.transfer.codecs` registry; the ``chunked`` default is the
    lossless ``shuffle-rle`` (lossy codecs silently degrade the *home copy*,
    not just the wire — opt in knowingly).  ``mode`` is ``"w+"`` (create) or
    ``"r+"`` (reopen existing ``mmap`` files in place).
    """

    kind: str = "ram"
    directory: Optional[str] = None
    chunk_bytes: int = 1 << 20          # chunked: target compressed-unit size
    cache_bytes: int = 64 << 20         # chunked: decompressed-cache budget
    codec: str = "shuffle-rle"          # chunked: at-rest compression
    mode: str = "w+"                    # mmap: "w+" create | "r+" reopen

    def resolved_directory(self, prefix: str) -> str:
        if self.directory is not None:
            os.makedirs(self.directory, exist_ok=True)
            return self.directory
        return tempfile.mkdtemp(prefix=f"repro-{prefix}-")


StoreSpec = Union[None, str, StoreConfig, BackingStore]

_STORES: Dict[str, Callable] = {}


def register_store(kind: str):
    """Decorator registering ``factory(config, name, shape, dtype,
    data=None) -> store`` under ``kind`` (mirrors the backend/codec
    registries).  ``data`` is the initial contents; a factory may adopt the
    array in place (``ram`` does, preserving aliasing) or copy it in."""
    def deco(factory):
        _STORES[kind] = factory
        return factory
    return deco


def available_stores() -> Tuple[str, ...]:
    return tuple(sorted(_STORES))


@register_store("ram")
def _ram(config: StoreConfig, name: str, shape, dtype, data=None) -> RamStore:
    # Wrap user data without copying: Dataset(data=arr) keeps aliasing arr.
    return RamStore(data if data is not None
                    else np.zeros(shape, dtype=dtype))


def make_store(spec: StoreSpec, *, name: str, shape: Tuple[int, ...], dtype,
               data: Optional[np.ndarray] = None) -> BackingStore:
    """Materialise a backing store from a spec.

    ``spec`` is ``None``/``"ram"`` (default), a kind name, a
    :class:`StoreConfig`, or a ready :class:`BackingStore` (shape/dtype
    checked).  ``data``, when given, becomes the initial contents.
    """
    if isinstance(spec, BackingStore):
        if spec.shape != tuple(shape) or spec.dtype != np.dtype(dtype):
            raise StoreError(
                f"store for {name!r} has shape {spec.shape}/{spec.dtype}, "
                f"dataset needs {tuple(shape)}/{np.dtype(dtype)}")
        if data is not None:
            spec.write(tuple(slice(None) for _ in shape), data)
        return spec
    if spec is None:
        spec = StoreConfig()
    elif isinstance(spec, str):
        spec = StoreConfig(kind=spec)
    factory = _STORES.get(spec.kind)
    if factory is None:
        raise StoreError(
            f"unknown store kind {spec.kind!r}; "
            f"available: {', '.join(available_stores())}")
    return factory(spec, name, tuple(shape), np.dtype(dtype), data=data)
