"""``mmap`` backing store: the home copy is an ``np.memmap`` over a file.

The OS page cache becomes the host tier: rows the executor stages are read
and written *in place* in the mapped file, cold pages fault in from disk,
and dirty pages drain back under kernel control (``flush`` forces it).
Datasets survive the process — :meth:`MmapStore.open` (or
``StoreConfig(kind="mmap", mode="r+")``) reattaches to an existing file,
which is what makes mmap homes restartable without a checkpoint.

``stats`` counts the bytes moved through the read/write API as disk traffic;
the page cache makes true device I/O unobservable from user space, so these
are upper bounds (a hot page costs no real I/O).
"""
from __future__ import annotations

import os
import threading
from typing import Tuple

import numpy as np

from .base import BackingStore, Index, StoreConfig, StoreError, register_store


class MmapStore(BackingStore):
    kind = "mmap"

    def __init__(self, path: str, shape: Tuple[int, ...], dtype,
                 mode: str = "w+"):
        super().__init__(shape, dtype)
        if mode not in ("w+", "r+"):
            raise StoreError(f"mmap store mode must be 'w+' or 'r+', got {mode!r}")
        self.path = path
        if mode == "r+":
            if not os.path.exists(path):
                raise StoreError(f"mmap reopen: {path!r} does not exist")
            actual = os.path.getsize(path)
            if actual != self.nbytes:
                raise StoreError(
                    f"mmap reopen: {path!r} is {actual}B, expected "
                    f"{self.nbytes}B for shape {self.shape} {self.dtype}")
        else:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
        # "w+" creates (zero-filled, sparse where the FS supports it).
        self._mm = np.memmap(path, dtype=self.dtype, mode=mode,
                             shape=self.shape)
        # The upload and download workers hit one store concurrently; the
        # memmap regions they touch are disjoint, but the stats counters are
        # shared read-modify-writes and would drop increments unlocked.
        self._stats_lock = threading.Lock()

    @classmethod
    def open(cls, path: str, shape: Tuple[int, ...], dtype) -> "MmapStore":
        """Reattach to an existing spill file (persistence across runs)."""
        return cls(path, shape, dtype, mode="r+")

    def read(self, index: Index) -> np.ndarray:
        region = self._mm[index]
        with self._stats_lock:
            self.stats["disk_bytes_read"] += int(region.nbytes)
        return region

    def write(self, index: Index, values) -> None:
        region = self._mm[index]
        region[...] = values
        with self._stats_lock:
            self.stats["disk_bytes_written"] += int(region.nbytes)

    def as_array(self) -> np.ndarray:
        return self._mm

    def materialize(self) -> np.ndarray:
        return self._mm

    def flush(self) -> int:
        self._mm.flush()
        return 0

    def close(self) -> None:
        self.flush()


@register_store("mmap")
def _mmap(config: StoreConfig, name: str, shape, dtype,
          data=None) -> MmapStore:
    directory = config.resolved_directory("mmap")
    store = MmapStore(os.path.join(directory, f"{name}.mmap"), shape, dtype,
                      mode=config.mode)
    if data is not None:
        store.write(tuple(slice(None) for _ in shape), data)
    return store
