"""repro.core.store — tiered host storage for dataset home copies: ``ram``
(NumPy, the default), ``mmap`` (np.memmap over a spill directory), and
``chunked`` (codec-compressed fixed-size chunks on disk behind an LRU
decompressed-chunk cache), plus atomic checkpoint save/restore."""
from .base import (
    BackingStore,
    RamStore,
    StoreConfig,
    StoreError,
    available_stores,
    make_store,
    register_store,
)
from .checkpoint import CHECKPOINT_FORMAT, load_checkpoint, save_checkpoint
from .chunked import ChunkedStore
from .mmapstore import MmapStore

__all__ = [
    "BackingStore", "RamStore", "MmapStore", "ChunkedStore",
    "StoreConfig", "StoreError",
    "make_store", "register_store", "available_stores",
    "save_checkpoint", "load_checkpoint", "CHECKPOINT_FORMAT",
]
