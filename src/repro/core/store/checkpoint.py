"""Checkpoint files: atomic save/restore of dataset home copies.

One checkpoint is a single ``.npz`` holding every dataset's *materialised*
padded array plus a JSON manifest (versions, dtypes, shapes, the session's
chain counter and the plan-cache signature hashes for provenance).  The file
is written to a temp path and ``os.replace``d into place, so a crash mid-save
leaves either the old checkpoint or the new one — never a torn file.  This is
what lets a multi-hour out-of-core run be killed and resumed bit-identically
(:meth:`Session.checkpoint` / :meth:`Session.restore` are thin wrappers).

RAM note: the npz format holds one dataset's *uncompressed* padded array in
memory while writing (chunked stores fill a preallocated buffer chunk by
chunk, so the peak is one array + the chunk-cache budget, not the whole
working set).  Checkpoint when the largest single dataset fits host RAM;
a per-chunk streaming format is the escape hatch if that ever stops holding.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List

import numpy as np

CHECKPOINT_FORMAT = 1


def save_checkpoint(path: str, datasets: Iterable, *,
                    chains_flushed: int = 0,
                    plan_signatures: Iterable[str] = ()) -> Dict:
    """Write ``datasets`` (any iterable of :class:`Dataset`) to ``path``
    atomically; returns the manifest that was embedded."""
    datasets = list(datasets)
    if not datasets:
        raise ValueError("nothing to checkpoint: no datasets given")
    names = [d.name for d in datasets]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate dataset names in checkpoint: {names}")
    manifest: Dict = {
        "format": CHECKPOINT_FORMAT,
        "chains_flushed": int(chains_flushed),
        "plan_signatures": sorted(set(plan_signatures)),
        "datasets": {},
    }
    arrays: Dict[str, np.ndarray] = {}
    for d in datasets:
        arrays[f"dat::{d.name}"] = np.asarray(d.materialize())
        manifest["datasets"][d.name] = {
            "version": int(d.version),
            "dtype": d.dtype.str,
            "shape": list(d.padded_shape),
        }
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return manifest


def load_checkpoint(path: str, datasets: Iterable) -> Dict:
    """Restore a checkpoint into ``datasets`` (matched by name; shapes and
    dtypes validated).  Every dataset recorded in the checkpoint must be
    present; extra live datasets are left untouched.  Returns the manifest."""
    by_name = {d.name: d for d in datasets}
    with np.load(path) as z:
        manifest = json.loads(bytes(np.asarray(z["manifest"]).tobytes()))
        if manifest.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"unsupported checkpoint format {manifest.get('format')!r} "
                f"(expected {CHECKPOINT_FORMAT})")
        missing: List[str] = [
            n for n in manifest["datasets"] if n not in by_name]
        if missing:
            raise KeyError(
                f"checkpoint has dataset(s) {missing} not present here; "
                f"pass matching datasets= to restore()")
        for name, meta in manifest["datasets"].items():
            d = by_name[name]
            arr = z[f"dat::{name}"]
            if tuple(arr.shape) != tuple(d.padded_shape) or \
                    np.dtype(meta["dtype"]) != d.dtype:
                raise ValueError(
                    f"checkpoint dataset {name!r} is {arr.shape} "
                    f"{meta['dtype']}, live dataset is {d.padded_shape} "
                    f"{d.dtype.str}")
            d.write_region(tuple(slice(None) for _ in range(d.ndim)), arr)
            d.version = int(meta["version"])
    return manifest
