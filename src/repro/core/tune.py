"""Sim-driven autotuning over the Plan IR.

The planner made execution configuration an explicit, costable object: every
candidate ``ExecutionConfig`` lowers to an instruction stream whose modelled
makespan the ledger interpreter computes without touching real data.  The
tuner enumerates candidates over ``num_tiles`` × ``tiled_dim`` ×
``num_slots`` × codec, costs each by interpreting the recorded chains in a
throwaway ``simulate_only`` executor (so pinned caching, prefetch guessing
and chain splitting all behave exactly as they would for real), and returns
the best config.  The base config is always a candidate, so the winner's
modelled makespan is never worse than the default's.

Lossy codecs (``fp16``/``bf16``) change results, not just traffic, so they
are only enumerated with ``allow_lossy=True``; the achieved ratio of the
lossless ``shuffle-rle`` codec is data-dependent (nominal 1.0), which the
byte-level model cannot see — pick it from a real :func:`transfer_bench`
measurement instead.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

from .loop import ParallelLoop

_SIM_EXCLUDED = {"reference", "pallas"}   # backends with no planner to tune


@dataclass
class TuneResult:
    """Outcome of one tuning sweep (``rows`` holds every candidate tried)."""

    best: "ExecutionConfig"              # noqa: F821 - see repro.core.program
    best_makespan: float                 # modelled seconds, all chains
    baseline_makespan: float             # the base config's modelled seconds
    rows: List[Dict]

    @property
    def speedup(self) -> float:
        """Modelled baseline/best ratio (1.0 = the default already wins)."""
        return (self.baseline_makespan / self.best_makespan
                if self.best_makespan else 1.0)

    def summary(self) -> str:
        b = self.best
        feas = sum(1 for r in self.rows if r["feasible"])
        mesh = getattr(b, "mesh", None)
        return (
            f"tune: {len(self.rows)} candidates ({feas} feasible); best "
            f"num_tiles={b.num_tiles} tiled_dim={b.tiled_dim} "
            f"num_slots={b.num_slots} codec={b.codec!r}"
            + (f" mesh={mesh.spec}" if mesh is not None else "") + ": "
            f"{self.best_makespan * 1e3:.3f} ms modelled vs baseline "
            f"{self.baseline_makespan * 1e3:.3f} ms ({self.speedup:.2f}x)")


def split_chains(loops: Sequence[ParallelLoop]) -> List[List[ParallelLoop]]:
    """Chain boundaries exactly as ``Session.flush`` draws them (per block)."""
    chains: List[List[ParallelLoop]] = []
    cur: List[ParallelLoop] = []
    for lp in loops:
        if cur and lp.block is not cur[0].block:
            chains.append(cur)
            cur = []
        cur.append(lp)
    if cur:
        chains.append(cur)
    return chains


def make_sim_executor(config, *, shared_plans=None):
    """A throwaway ledger-only executor for ``config`` — sharded when the
    config carries a multi-device mesh, so the tuner's shard-count
    candidates are costed with their per-device streams and halo ops.
    Delegates to the backend registry's builder so the tuner can never cost
    a different executor shape than ``make_backend`` would construct.
    ``shared_plans`` lets the serving layer's admission oracle plan through
    (and feed) the cross-tenant cache, so admission checks are cheap for
    chains the server has already planned."""
    from .backends import _ooc_executor

    return _ooc_executor(config, shared_plans=shared_plans,
                         simulate_only=True, transfer="sync")


def modelled_makespan(config, chains: Sequence[Sequence[ParallelLoop]],
                      repeats: int = 1) -> float:
    """Total modelled seconds for ``chains`` under ``config`` (sim only).

    ``repeats`` replays the chain sequence (cyclic apps): steady-state
    effects — pinned-cache hits, speculative-prefetch hits — only appear
    from the second pass on, so tuning for a long run should cost more than
    one.  Raises ``MemoryError`` only if a single loop cannot fit (the
    executor splits chains exactly as a real run would)."""
    ex = make_sim_executor(config)
    for _ in range(max(1, repeats)):
        for chain in chains:
            ex.run_chain(list(chain))
    return sum(c.modelled_s for c in ex.history)


def candidate_configs(
    base,
    ndim: int,
    num_tiles: Optional[Sequence[Optional[int]]] = None,
    num_slots: Optional[Sequence[int]] = None,
    tiled_dims: Optional[Sequence[int]] = None,
    codecs: Optional[Sequence] = None,
    allow_lossy: bool = False,
    meshes: Optional[Sequence] = None,
) -> List:
    """The candidate grid, base config first (ties resolve to the default).

    ``meshes`` (optional) enumerates device-mesh shard counts — entries are
    anything :func:`repro.core.mesh.parse_mesh` accepts (ints, "sim:N",
    DeviceMesh); the base config's mesh stays the first candidate."""
    from .mesh import parse_mesh

    if num_tiles is None:
        num_tiles = (None, 2, 4, 8, 16, 32)
    if num_slots is None:
        num_slots = (2, 3)
    if tiled_dims is None:
        tiled_dims = tuple(range(ndim))
    if codecs is None:
        codecs = ("identity",) + (("fp16", "bf16") if allow_lossy else ())
    nt = list(dict.fromkeys([base.num_tiles, *num_tiles]))
    ns = list(dict.fromkeys([base.num_slots, *num_slots]))
    td = [d for d in dict.fromkeys([base.tiled_dim, *tiled_dims])
          if 0 <= d < ndim]
    base_codec = base.codec if isinstance(base.codec, str) else None
    cs = list(dict.fromkeys(([base_codec] if base_codec else []) + list(codecs)))
    if not isinstance(base.codec, str):
        cs.insert(0, base.codec)   # per-dat dict spec: keep as-is candidate
    # A 1-device mesh builds the identical unsharded executor as mesh=None
    # (_ooc_executor only shards when num_devices > 1) — canonicalise so the
    # grid doesn't cost the same candidate twice.
    def canon(m):
        m = parse_mesh(m)
        return None if m is not None and m.num_devices == 1 else m

    ms = list(dict.fromkeys(
        [canon(getattr(base, "mesh", None))]
        + [canon(m) for m in (meshes or ())]))
    out = []
    seen = set()
    for t in nt:
        for s in ns:
            for d in td:
                for c in cs:
                    for m in ms:
                        key = (t, s, d, c if isinstance(c, str)
                               else tuple(sorted(c.items())), m)
                        if key in seen:
                            continue
                        seen.add(key)
                        out.append(replace(base, num_tiles=t, num_slots=s,
                                           tiled_dim=d, codec=c, mesh=m))
    return out


def tune_configs(
    loops: Sequence[ParallelLoop],
    base,
    *,
    num_tiles: Optional[Sequence[Optional[int]]] = None,
    num_slots: Optional[Sequence[int]] = None,
    tiled_dims: Optional[Sequence[int]] = None,
    codecs: Optional[Sequence] = None,
    allow_lossy: bool = False,
    meshes: Optional[Sequence] = None,
    repeats: int = 2,
) -> TuneResult:
    """Cost every candidate config on ``loops`` via the sim interpreter and
    return the best (modelled makespan, infeasible candidates excluded).
    ``meshes=[1, 2, 4]`` additionally enumerates device-mesh shard counts
    (costed per device, halo exchanges included)."""
    if not loops:
        raise ValueError("nothing to tune: record loops first")
    if base.backend in _SIM_EXCLUDED:
        raise ValueError(
            f"backend {base.backend!r} has no planner to tune; use an "
            f"ooc/ooc-async/sim session")
    chains = split_chains(loops)
    ndim = loops[0].block.ndim
    cands = candidate_configs(base, ndim, num_tiles, num_slots, tiled_dims,
                              codecs, allow_lossy, meshes)
    rows: List[Dict] = []
    best_cfg = None
    best_t = float("inf")
    baseline_t = float("inf")
    from .mesh import MeshError

    for i, cand in enumerate(cands):
        try:
            t = modelled_makespan(cand, chains, repeats=repeats)
            feasible = True
        except (MemoryError, MeshError):
            # MemoryError: no tile count fits fast memory.  MeshError: the
            # grid cannot be decomposed that way (too many devices, skirt
            # exceeding the shard width).
            t = float("inf")
            feasible = False
        rows.append({
            "num_tiles": cand.num_tiles, "num_slots": cand.num_slots,
            "tiled_dim": cand.tiled_dim,
            "codec": (cand.codec if isinstance(cand.codec, str)
                      else dict(cand.codec)),
            "mesh": cand.mesh.spec if getattr(cand, "mesh", None) else None,
            # None, not inf: rows land in JSON reports and bare Infinity
            # is not valid strict JSON.
            "modelled_s": t if feasible else None, "feasible": feasible,
        })
        if i == 0:
            baseline_t = t
        if feasible and t < best_t:
            best_cfg = cand
            best_t = t
    if best_cfg is None:
        raise MemoryError("no candidate configuration fits fast memory")
    return TuneResult(best=best_cfg, best_makespan=best_t,
                      baseline_makespan=baseline_t, rows=rows)
