"""The explicit Plan IR: a typed tile-instruction stream for one loop chain.

The paper's central artifact is a *tiling plan* — the runtime dependency
analysis produces a schedule of tile loads, skewed compute sweeps and stores
that is constructed once and replayed across timesteps.  This module makes
that plan first-class instead of implicit executor control flow:

* **Typed ops** — :class:`Upload`, :class:`Compute`, :class:`Download`,
  :class:`CarryEdge`, :class:`Elide`, :class:`Evict`, :class:`PinUpload`,
  :class:`WritebackPinned`, :class:`Prefetch` — each carrying the byte/flop
  annotations the cost model needs.  The op *order* is the submission order
  of Algorithm 1's three streams, so an interpreter walking the stream
  reconstructs the exact ledger dependency wiring the inline executor used.
* **A planner** — :func:`build_plan` absorbs the decide-side of the old
  ``OutOfCoreExecutor._run_chain_tiled`` monolith: footprint set algebra,
  §4.1 transfer elision, cold-read clamps, static LRU slot assignment,
  pinned-dataset residency and codec wire-byte modelling all happen here,
  once, with **no data plane**.
* **Interpreters** (:mod:`repro.core.interp`) consume the stream: the ledger
  interpreter costs it (``sim`` backend, :meth:`Session.explain`, the
  autotuner); the data-plane interpreter additionally moves real bytes
  through the :class:`~repro.core.transfer.TransferEngine`.  Both execute
  the *same* ops.
* **JSON export/import** — plans serialise losslessly
  (:meth:`Plan.to_json` / :meth:`Plan.from_json`) for offline analysis,
  diffing, or replay against a live chain with a matching signature.

Intervals are half-open ``[lo, hi)`` grid-row ranges along the tiled
dimension; byte math uses the per-dataset ``row_bytes`` table so any
sub-interval can be priced without the datasets themselves.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import MISSING, dataclass, fields
from typing import ClassVar, Dict, FrozenSet, List, Optional, Sequence, Tuple

from .dependency import ChainInfo, _merge, chain_signature
from .mesh import HaloSpec
from .tiling import TileSchedule
from .transfer import resolve_codecs

Item = Tuple[str, int, int]          # (dataset, lo, hi)
Rows = Tuple[Tuple[int, int], ...]   # merged half-open row intervals


class PlanError(ValueError):
    """A plan document is malformed: bad JSON, unsupported version, an
    unknown op kind, or an op/meta field mismatch.  The message names the
    offending op index and field so a truncated or version-skewed export
    is diagnosable without reading the raw JSON."""


# -- the instruction set ----------------------------------------------------------


@dataclass(frozen=True)
class PlanOp:
    """Base of every plan instruction (frozen: plans are immutable values)."""

    kind: ClassVar[str] = "?"


@dataclass(frozen=True)
class FetchHome(PlanOp):
    """Disk -> host-RAM fetch of tile ``tile``'s staging rows (stream 3).

    Emitted when the HostModel says the chain's home working set exceeds
    host RAM: the rows tile ``tile``'s upload will read must be RAM-resident
    (decompressed into the chunk cache / paged in) before the upload worker
    touches them.  Scheduled two tiles ahead by construction — the op sits in
    the stream where tile ``tile``'s staged upload is submitted, so on a
    ≥2-slot pool the disk lane runs ahead of the host->device lane exactly
    like the host->device lane runs ahead of compute."""

    kind: ClassVar[str] = "fetch-home"
    tile: int
    items: Tuple[Item, ...]
    raw: int


@dataclass(frozen=True)
class SpillHome(PlanOp):
    """Host-RAM -> disk retirement of tile ``tile``'s downloaded rows.

    The mirror of :class:`FetchHome`: once the download has landed the rows
    home, they are pushed out to the backing store (dirty chunks compressed
    and written, fully-retired chunks dropped from the cache) so the host
    working set stays inside the budget."""

    kind: ClassVar[str] = "spill-home"
    tile: int
    items: Tuple[Item, ...]
    raw: int


@dataclass(frozen=True)
class HaloPack(PlanOp):
    """Stage this device's boundary rows for its neighbours (host-side copy
    into send buffers).  ``nbytes`` counts the rows *sent*; ``names`` the
    datasets exchanged (the chain's read set)."""

    kind: ClassVar[str] = "halo-pack"
    names: Tuple[str, ...]
    nbytes: int


@dataclass(frozen=True)
class HaloExchange(PlanOp):
    """One accumulated-depth halo exchange per chain (§5.2): neighbours'
    interior rows land in this device's skirt.  ``depth`` is rows per
    interior side; ``messages``/``nbytes`` count what this device receives,
    so device sums reproduce the mesh-global exchange totals."""

    kind: ClassVar[str] = "halo-exchange"
    depth: int
    messages: int
    nbytes: int


@dataclass(frozen=True)
class HaloUnpack(PlanOp):
    """Land received halo rows into this device's home skirt; chain staging
    (the first ``Upload``) is gated on this — skirt rows must be current
    before they are staged toward fast memory."""

    kind: ClassVar[str] = "halo-unpack"
    names: Tuple[str, ...]
    nbytes: int


@dataclass(frozen=True)
class PinUpload(PlanOp):
    """Ensure pinned datasets are device-resident (upload on a cache miss).

    ``entries``: (name, whole-array raw bytes).  ``raw``/``wire`` are the
    cold-start totals; a cross-chain pinned-cache hit costs nothing."""

    kind: ClassVar[str] = "pin-upload"
    entries: Tuple[Tuple[str, int], ...]
    raw: int
    wire: int


@dataclass(frozen=True)
class Upload(PlanOp):
    """Acquire tile ``tile``'s slot and stage its right footprint up.

    Emitted for *every* tile (slot acquisition and origin binding happen
    here) even when ``items`` is empty.  Items exclude pinned datasets and
    are cold-clamped for write-first data; a speculative-prefetch hit may
    trim them further at interpretation time."""

    kind: ClassVar[str] = "upload"
    tile: int
    slot: int
    items: Tuple[Item, ...]
    raw: int
    wire: int


@dataclass(frozen=True)
class Compute(PlanOp):
    """Run the tile's skewed loop sub-ranges on stream 0.

    ``writes`` are the merged dirty-row marks per non-pinned dataset (the
    residency manager enforces their eventual writeback/carry/elision);
    ``pinned_writes`` name pinned datasets this tile modifies."""

    kind: ClassVar[str] = "compute"
    tile: int
    slot: int
    nbytes: int
    flops: int
    writes: Tuple[Tuple[str, Rows], ...]
    pinned_writes: Tuple[str, ...]


@dataclass(frozen=True)
class CarryEdge(PlanOp):
    """Device-side copy of tile ``tile``'s right edge into the next slot.

    Moves writeback responsibility for dirty rows with the data."""

    kind: ClassVar[str] = "carry-edge"
    tile: int
    slot: int
    dst_slot: int
    items: Tuple[Item, ...]
    nbytes: int


@dataclass(frozen=True)
class Elide(PlanOp):
    """§4.1 Cyclic: retire dirty rows of dead temporaries without traffic."""

    kind: ClassVar[str] = "elide"
    tile: int
    slot: int
    items: Tuple[Item, ...]
    rows: int


@dataclass(frozen=True)
class Download(PlanOp):
    """Ship tile ``tile``'s retired left footprint home (stream 2)."""

    kind: ClassVar[str] = "download"
    tile: int
    slot: int
    items: Tuple[Item, ...]
    raw: int
    wire: int


@dataclass(frozen=True)
class Evict(PlanOp):
    """Slot reuse: tile ``tile`` displaces the previous resident of its slot.

    Informational (the residency manager refuses the reuse if dirty rows
    survive); exists so plan-level op counts match residency statistics."""

    kind: ClassVar[str] = "evict"
    tile: int
    slot: int


@dataclass(frozen=True)
class Prefetch(PlanOp):
    """§4.1 speculative prefetch: upload the next chain's assumed first tile
    during this chain's last tile.  ``items``: (name, row intervals)."""

    kind: ClassVar[str] = "prefetch"
    items: Tuple[Tuple[str, Rows], ...]
    wire: int


@dataclass(frozen=True)
class WritebackPinned(PlanOp):
    """Chain-end flush of written pinned datasets (one download event).

    ``entries``: (name, written rows, raw bytes, nominal wire bytes)."""

    kind: ClassVar[str] = "writeback-pinned"
    entries: Tuple[Tuple[str, Rows, int, int], ...]
    raw: int
    wire: int


OP_TYPES: Dict[str, type] = {
    cls.kind: cls
    for cls in (PinUpload, Upload, Compute, CarryEdge, Elide, Download,
                Evict, Prefetch, WritebackPinned, FetchHome, SpillHome,
                HaloPack, HaloExchange, HaloUnpack)
}


# -- the plan ---------------------------------------------------------------------


# v2: + ``spill_home`` plan flag and the FetchHome/SpillHome disk-tier ops.
# v3: + device-mesh sharding — ``device``/``mesh_devices``/``shard_dim`` meta
#     and the HaloPack/HaloExchange/HaloUnpack network ops.
PLAN_JSON_VERSION = 3


@dataclass(frozen=True)
class Plan:
    """One chain's complete, immutable instruction stream plus the metadata
    interpreters need to bind it (slot geometry, per-row byte widths, codec
    ratios, per-tile slot origins).  Self-contained for cost modelling: a
    plan can be simulated — or exported, diffed and re-imported — without
    the datasets it was planned against."""

    num_tiles: int
    num_slots: int
    tiled_dim: int
    early_submit: bool
    cyclic: bool
    prefetch: bool
    spill_home: bool            # host tier oversubscribed: disk ops emitted
    slot_bytes: int
    pinned_bytes: int
    loop_bytes: int
    sig_hash: str                                   # structural chain identity
    row_bytes: Tuple[Tuple[str, int], ...]          # dataset -> bytes per row
    codec_names: Tuple[Tuple[str, str], ...]        # dataset -> codec name
    codec_ratios: Tuple[Tuple[str, float], ...]     # dataset -> nominal ratio
    keep_live: Tuple[str, ...]                      # split-chain liveness
    tile_origins: Tuple[Tuple[Tuple[str, int], ...], ...]
    ops: Tuple[PlanOp, ...]
    # -- device mesh (sharded execution): which device of how many this plan
    # drives, and the decomposed dimension.  Defaults = unsharded.
    device: int = 0
    mesh_devices: int = 1
    shard_dim: int = 1
    # Write-first dats staged anyway (segmented chains: their home copies
    # hold earlier-segment results the download would otherwise clobber).
    warm: Tuple[str, ...] = ()

    # -- derived views -------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Per-kind op counts (uploads count only item-bearing staging ops)."""
        c = {"uploads": 0, "downloads": 0, "computes": 0, "carries": 0,
             "elisions": 0, "evictions": 0, "prefetches": 0,
             "pin_uploads": 0, "pin_writebacks": 0,
             "home_fetches": 0, "home_spills": 0,
             "halo_packs": 0, "halo_exchanges": 0, "halo_unpacks": 0}
        for op in self.ops:
            if isinstance(op, Upload):
                if op.items:
                    c["uploads"] += 1
            elif isinstance(op, Download):
                c["downloads"] += 1
            elif isinstance(op, Compute):
                c["computes"] += 1
            elif isinstance(op, CarryEdge):
                c["carries"] += 1
            elif isinstance(op, Elide):
                c["elisions"] += 1
            elif isinstance(op, Evict):
                c["evictions"] += 1
            elif isinstance(op, Prefetch):
                c["prefetches"] += 1
            elif isinstance(op, PinUpload):
                c["pin_uploads"] += 1
            elif isinstance(op, WritebackPinned):
                c["pin_writebacks"] += 1
            elif isinstance(op, FetchHome):
                c["home_fetches"] += 1
            elif isinstance(op, SpillHome):
                c["home_spills"] += 1
            elif isinstance(op, HaloPack):
                c["halo_packs"] += 1
            elif isinstance(op, HaloExchange):
                c["halo_exchanges"] += 1
            elif isinstance(op, HaloUnpack):
                c["halo_unpacks"] += 1
        return c

    def totals(self) -> Dict[str, int]:
        """Modelled byte totals (cold caches, no prefetch hits)."""
        up_raw = up_wire = dn_raw = dn_wire = edge = flops = 0
        disk_read = disk_written = 0
        halo_bytes = halo_messages = 0
        for op in self.ops:
            if isinstance(op, (Upload, PinUpload)):
                up_raw += op.raw
                up_wire += op.wire
            elif isinstance(op, (Download, WritebackPinned)):
                dn_raw += op.raw
                dn_wire += op.wire
            elif isinstance(op, CarryEdge):
                edge += op.nbytes
            elif isinstance(op, Compute):
                flops += op.flops
            elif isinstance(op, FetchHome):
                disk_read += op.raw
            elif isinstance(op, SpillHome):
                disk_written += op.raw
            elif isinstance(op, HaloExchange):
                halo_bytes += op.nbytes
                halo_messages += op.messages
        return {"uploaded": up_raw, "uploaded_wire": up_wire,
                "downloaded": dn_raw, "downloaded_wire": dn_wire,
                "edge_bytes": edge, "flops": flops,
                "disk_read": disk_read, "disk_written": disk_written,
                "halo_bytes": halo_bytes, "halo_messages": halo_messages}

    # -- JSON -----------------------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        meta = {
            f.name: getattr(self, f.name)
            for f in fields(self) if f.name != "ops"
        }
        ops = [{"op": op.kind, **{f.name: getattr(op, f.name)
                                  for f in fields(op)}} for op in self.ops]
        return json.dumps({"version": PLAN_JSON_VERSION, "meta": meta,
                           "ops": ops}, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "Plan":
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise PlanError(f"plan document is not valid JSON "
                            f"(truncated export?): {e}") from e
        if not isinstance(doc, dict):
            raise PlanError(
                f"plan document must be a JSON object, got "
                f"{type(doc).__name__}")
        # v2 documents load fine: every v3 addition (device/mesh_devices/
        # shard_dim/warm meta, halo ops) defaults to the unsharded case.
        if doc.get("version") not in (2, PLAN_JSON_VERSION):
            raise PlanError(
                f"unsupported plan version {doc.get('version')!r} "
                f"(expected 2 or {PLAN_JSON_VERSION})")
        for key in ("meta", "ops"):
            if key not in doc:
                raise PlanError(f"plan document has no {key!r} section")
        if not isinstance(doc["meta"], dict):
            raise PlanError("plan 'meta' section must be a JSON object")
        meta = {k: _tuplify(v) for k, v in doc["meta"].items()}
        ops: List[PlanOp] = []
        for i, entry in enumerate(doc["ops"]):
            if not isinstance(entry, dict) or "op" not in entry:
                raise PlanError(
                    f"op {i}: not an op object (missing 'op' field): "
                    f"{entry!r}")
            entry = dict(entry)
            kind = entry.pop("op")
            op_cls = OP_TYPES.get(kind)
            if op_cls is None:
                raise PlanError(
                    f"op {i}: unknown op kind {kind!r} "
                    f"(known: {', '.join(sorted(OP_TYPES))})")
            want = {f.name for f in fields(op_cls)}
            got = set(entry)
            if got != want:
                missing = ", ".join(sorted(want - got)) or "-"
                extra = ", ".join(sorted(got - want)) or "-"
                raise PlanError(
                    f"op {i} ({kind!r}): field mismatch — missing: "
                    f"{missing}; unexpected: {extra}")
            ops.append(op_cls(**{k: _tuplify(v) for k, v in entry.items()}))
        want_meta = {f.name for f in fields(cls)} - {"ops"}
        required = {f.name for f in fields(cls)
                    if f.default is MISSING
                    and f.default_factory is MISSING} - {"ops"}
        extra_meta = set(meta) - want_meta
        missing_meta = required - set(meta)
        if extra_meta or missing_meta:
            raise PlanError(
                f"plan meta field mismatch — missing: "
                f"{', '.join(sorted(missing_meta)) or '-'}; unexpected: "
                f"{', '.join(sorted(extra_meta)) or '-'}")
        return cls(ops=tuple(ops), **meta)


def _tuplify(v):
    """JSON arrays -> tuples, recursively (plan fields are tuple-typed)."""
    if isinstance(v, list):
        return tuple(_tuplify(x) for x in v)
    return v


def plans_to_json(plans: Sequence[Plan], indent: Optional[int] = None) -> str:
    """Serialise several chains' plans (a whole queued step) as one document."""
    return json.dumps([json.loads(p.to_json()) for p in plans], indent=indent)


def plans_from_json(text: str) -> List[Plan]:
    try:
        docs = json.loads(text)
    except json.JSONDecodeError as e:
        raise PlanError(f"plan-list document is not valid JSON "
                        f"(truncated export?): {e}") from e
    if not isinstance(docs, list):
        raise PlanError("plan-list document must be a JSON array of plans")
    return [Plan.from_json(json.dumps(doc)) for doc in docs]


def chain_sig_hash(info: ChainInfo) -> str:
    """Stable structural identity of a chain (names/ranges/stencils/modes) —
    survives JSON round-trips and process boundaries, unlike the replay-safe
    ``plan_signature`` which hashes kernel closures and object identities."""
    return hashlib.sha1(repr(chain_signature(info)).encode()).hexdigest()


# -- the planner ------------------------------------------------------------------


def build_plan(
    info: ChainInfo,
    sched: TileSchedule,
    *,
    num_slots: int,
    cyclic: bool = False,
    prefetch: bool = False,
    spill_home: bool = False,
    keep_live: FrozenSet[str] = frozenset(),
    warm: FrozenSet[str] = frozenset(),
    pinned_names: FrozenSet[str] = frozenset(),
    codec_spec=None,
    flops_per_point: Optional[int] = None,
    slot_bytes: int = 0,
    pinned_bytes: int = 0,
    halo: Optional[HaloSpec] = None,
) -> Plan:
    """Lower one analysed+scheduled chain to its instruction stream.

    Pure: consumes the dependency analysis (``info``) and skewed tile
    schedule (``sched``) plus the planning-relevant config knobs; touches no
    data.  Op order is the three-stream submission order of Algorithm 1 —
    with ≥2 slots tile t+1's upload is issued before tile t's compute
    (pipelined staging); a 1-slot pool runs strictly in order.

    ``spill_home`` (the HostModel's verdict that home copies oversubscribe
    host RAM) adds the fourth stream: every staged upload is preceded by a
    ``FetchHome`` of the same rows (disk -> host ahead of host -> device) and
    every download is followed by a ``SpillHome`` (host -> disk once the rows
    are retired).  Pinned datasets are exempt — pinning declares them small
    and hot, i.e. host-resident for the whole run.

    ``halo`` (sharded execution, :class:`~repro.core.mesh.HaloSpec`) places
    the paper's §5.2 one-accumulated-depth-per-chain exchange at the head of
    the stream — ``HaloPack`` -> ``HaloExchange`` -> ``HaloUnpack`` on the
    network stream, gating the chain's first staged upload — and stamps the
    plan with its device position on the mesh."""
    td = info.tiled_dim
    num_tiles = sched.num_tiles
    early_submit = num_slots >= 2
    codecs = resolve_codecs(codec_spec, tuple(info.datasets))

    row_bytes: Dict[str, int] = {}
    ratios: Dict[str, float] = {}
    for name, dat in info.datasets.items():
        other = 1
        for d, s in enumerate(dat.padded_shape):
            if d != td:
                other *= s
        row_bytes[name] = other * dat.dtype.itemsize
        ratios[name] = float(codecs[name].nominal_ratio(dat.dtype))

    def nbytes(name: str, lo: int, hi: int) -> int:
        return max(0, hi - lo) * row_bytes[name]

    def wire(name: str, nb: int) -> int:
        return max(1, int(nb / ratios[name])) if nb else 0

    tile_origins = tuple(
        tuple(sorted((name, iv.lo) for name, iv in t.footprint.items()
                     if not iv.empty))
        for t in sched.tiles
    )

    ops: List[PlanOp] = []

    # -- the halo exchange (device mesh, once per chain) ---------------------
    if halo is not None and halo.num_devices > 1 and halo.messages:
        ops.append(HaloPack(names=halo.names, nbytes=halo.nbytes))
        ops.append(HaloExchange(depth=halo.depth, messages=halo.messages,
                                nbytes=halo.nbytes))
        ops.append(HaloUnpack(names=halo.names, nbytes=halo.nbytes))

    # -- pinned residency (whole-array, cached across chains) ----------------
    if pinned_names:
        entries = tuple((name, int(info.datasets[name].nbytes))
                        for name in sorted(pinned_names))
        ops.append(PinUpload(
            entries=entries,
            raw=sum(nb for _, nb in entries),
            wire=sum(wire(name, nb) for name, nb in entries)))

    # -- per-tile op builders -------------------------------------------------
    def upload_op(t: int) -> Upload:
        tile = sched.tiles[t]
        items: List[Item] = []
        for name, pieces in tile.upload.items():
            if name in pinned_names:
                continue            # whole-array resident: never staged
            if name in info.write_first and name not in warm:
                # §4.1: write-first data never uploads — except rows the chain
                # reads before any write reaches them (cold halo skirts).
                # ``warm`` overrides the elision: a segmented chain's earlier
                # segment already landed real data home (e.g. halo-mirror
                # columns), which this segment's full-width download would
                # clobber with zero-initialised slot content if not staged.
                cold = info.cold.get(name, [])
                pieces = tuple(
                    p for iv in pieces
                    for p in (iv.clamp(clo, chi) for clo, chi in cold)
                    if not p.empty)
            for iv in pieces:
                if not iv.empty:
                    items.append((name, iv.lo, iv.hi))
        raw = sum(nbytes(n, lo, hi) for n, lo, hi in items)
        return Upload(
            tile=t, slot=t % num_slots, items=tuple(items), raw=raw,
            wire=sum(wire(n, nbytes(n, lo, hi)) for n, lo, hi in items))

    def compute_op(t: int) -> Compute:
        tile = sched.tiles[t]
        tile_bytes = tile_flops = 0
        writes: Dict[str, List[Tuple[int, int]]] = {}
        pinned_written: List[str] = []
        for k, box in enumerate(tile.loop_ranges):
            if box is None:
                continue
            npts = 1
            for a, b in box:
                npts *= b - a
            lp = info.loops[k]
            full_pts = 1
            for a, b in lp.range_:
                full_pts *= b - a
            frac = npts / full_pts
            tile_bytes += int(lp.bytes_moved() * frac)
            tile_flops += int(lp.flops(flops_per_point) * frac)
            lo_w, hi_w = box[td]
            for arg in lp.args:
                if not arg.mode.writes:
                    continue
                nm = arg.dat.name
                if nm in pinned_names:
                    if nm not in pinned_written:
                        pinned_written.append(nm)
                else:
                    writes.setdefault(nm, []).append((lo_w, hi_w))
        return Compute(
            tile=t, slot=t % num_slots, nbytes=tile_bytes, flops=tile_flops,
            writes=tuple(sorted((nm, tuple(_merge(ivs)))
                                for nm, ivs in writes.items())),
            pinned_writes=tuple(pinned_written))

    def carry_op(t: int) -> Optional[CarryEdge]:
        if t + 1 >= num_tiles:
            return None
        tile = sched.tiles[t]
        next_org = dict(tile_origins[t + 1])
        items: List[Item] = []
        for name, iv in tile.edge_to_next.items():
            if iv.empty or name not in next_org or name in pinned_names:
                continue
            items.append((name, iv.lo, iv.hi))
        if not items:
            return None
        return CarryEdge(
            tile=t, slot=t % num_slots, dst_slot=(t + 1) % num_slots,
            items=tuple(items),
            nbytes=sum(nbytes(n, lo, hi) for n, lo, hi in items))

    def retire_ops(t: int) -> Tuple[Optional[Elide], Optional[Download]]:
        tile = sched.tiles[t]
        elide_items: List[Item] = []
        dl_items: List[Item] = []
        for name, pieces in tile.download.items():
            if name in pinned_names or name in info.read_only:
                continue    # never written / flushed once at chain end
            if cyclic and name in info.write_first and name not in keep_live:
                # §4.1 Cyclic: dead temporaries stay on device — no traffic,
                # but the residency books must balance.
                elide_items.extend(
                    (name, iv.lo, iv.hi) for iv in pieces if not iv.empty)
                continue
            dl_items.extend((name, iv.lo, iv.hi) for iv in pieces if not iv.empty)
        el = dl = None
        if elide_items:
            el = Elide(tile=t, slot=t % num_slots, items=tuple(elide_items),
                       rows=sum(hi - lo for _, lo, hi in elide_items))
        if dl_items:
            raw = sum(nbytes(n, lo, hi) for n, lo, hi in dl_items)
            dl = Download(
                tile=t, slot=t % num_slots, items=tuple(dl_items), raw=raw,
                wire=sum(wire(n, nbytes(n, lo, hi)) for n, lo, hi in dl_items))
        return el, dl

    def staged_upload(t: int) -> List[PlanOp]:
        out: List[PlanOp] = []
        up = upload_op(t)
        if spill_home and up.items:
            out.append(FetchHome(tile=t, items=up.items, raw=up.raw))
        if t >= num_slots:
            out.append(Evict(tile=t, slot=t % num_slots))
        out.append(up)
        return out

    def retire_tail(t: int, dl: Optional[Download]) -> List[PlanOp]:
        if dl is None:
            return []
        out: List[PlanOp] = [dl]
        if spill_home:
            out.append(SpillHome(tile=t, items=dl.items, raw=dl.raw))
        return out

    # -- assembly: Algorithm 1's submission order -----------------------------
    ops.extend(staged_upload(0))
    for t in range(num_tiles):
        if early_submit and t + 1 < num_tiles:
            ops.extend(staged_upload(t + 1))
        ops.append(compute_op(t))
        el, dl = retire_ops(t)
        if early_submit:
            c = carry_op(t)
            if c:
                ops.append(c)
            if el:
                ops.append(el)
            ops.extend(retire_tail(t, dl))
        else:
            if el:
                ops.append(el)
            ops.extend(retire_tail(t, dl))
            c = carry_op(t)
            if c:
                ops.append(c)
            if t + 1 < num_tiles:
                ops.extend(staged_upload(t + 1))
        if prefetch and t == num_tiles - 1:
            first = sched.tiles[0]
            pf: List[Tuple[str, Rows]] = []
            pf_wire = 0
            for name, pieces in first.upload.items():
                if name in info.write_first or name in pinned_names:
                    continue
                live = tuple((iv.lo, iv.hi) for iv in pieces if not iv.empty)
                if not live:
                    continue
                pf.append((name, live))
                pf_wire += sum(wire(name, nbytes(name, lo, hi))
                               for lo, hi in live)
            ops.append(Prefetch(items=tuple(pf), wire=pf_wire))

    # -- chain-end pinned flush ----------------------------------------------
    flushed = sorted(pinned_names & info.modified)
    if flushed:
        entries = []
        for name in flushed:
            rows = tuple((lo, hi) for lo, hi in info.written.get(name, []))
            nb = sum(nbytes(name, lo, hi) for lo, hi in rows)
            entries.append((name, rows, nb, wire(name, nb)))
        ops.append(WritebackPinned(
            entries=tuple(entries),
            raw=sum(e[2] for e in entries),
            wire=sum(e[3] for e in entries)))

    return Plan(
        num_tiles=num_tiles, num_slots=num_slots, tiled_dim=td,
        early_submit=early_submit, cyclic=bool(cyclic),
        prefetch=bool(prefetch), spill_home=bool(spill_home),
        slot_bytes=int(slot_bytes),
        pinned_bytes=int(pinned_bytes), loop_bytes=info.loop_bytes(),
        sig_hash=chain_sig_hash(info),
        row_bytes=tuple(sorted(row_bytes.items())),
        codec_names=tuple(sorted((n, codecs[n].name) for n in info.datasets)),
        codec_ratios=tuple(sorted(ratios.items())),
        keep_live=tuple(sorted(keep_live)),
        tile_origins=tile_origins,
        ops=tuple(ops),
        device=halo.device if halo is not None else 0,
        mesh_devices=halo.num_devices if halo is not None else 1,
        shard_dim=halo.shard_dim if halo is not None else 1,
        warm=tuple(sorted(warm)),
    )


# -- human-readable rendering ------------------------------------------------------


def _mb(nb: float) -> str:
    if nb >= 1e9:
        return f"{nb / 1e9:.2f} GB"
    if nb >= 1e6:
        return f"{nb / 1e6:.2f} MB"
    if nb >= 1e3:
        return f"{nb / 1e3:.1f} kB"
    return f"{int(nb)} B"


def _items_str(items: Sequence[Item], limit: int = 4) -> str:
    parts = [f"{n}[{lo}:{hi})" for n, lo, hi in items[:limit]]
    if len(items) > limit:
        parts.append(f"+{len(items) - limit} more")
    return " ".join(parts) if parts else "-"


def format_plan(plan: Plan, hw=None, title: str = "plan") -> str:
    """Per-tile op listing with modelled bytes; with ``hw``, the modelled
    makespan (ledger-interpreted, cold caches) is appended.

    Every op line carries its stable index (``#N`` = position in
    ``plan.ops``): the same N the drift audit (:mod:`repro.obs.audit`)
    reports as ``op #N``, traced spans carry in their ``op`` arg, and
    :mod:`repro.core.verify` diagnostics cite as ``op N``."""
    tot = plan.totals()
    codec_set = sorted({c for _, c in plan.codec_names})
    lines = [
        f"{title}: {plan.num_tiles} tiles x {plan.num_slots} slots"
        f" ({'pipelined' if plan.early_submit else 'in-order'}),"
        f" tiled dim {plan.tiled_dim},"
        f" slot {_mb(plan.slot_bytes)}"
        + (f", pinned {_mb(plan.pinned_bytes)}" if plan.pinned_bytes else "")
        + f", codec {'/'.join(codec_set)}"
        + (", cyclic" if plan.cyclic else "")
        + (", prefetch" if plan.prefetch else "")
        + (", disk tier (host oversubscribed)" if plan.spill_home else "")
        + (f", device {plan.device}/{plan.mesh_devices}"
           f" (shard dim {plan.shard_dim})" if plan.mesh_devices > 1 else "")
        + (f", warm {' '.join(plan.warm)}" if plan.warm else "")
        + (f", keep-live {' '.join(plan.keep_live)}"
           if plan.keep_live else ""),
    ]
    cur_tile = None
    for idx, op in enumerate(plan.ops):
        t = getattr(op, "tile", None)
        if t is not None and t != cur_tile:
            cur_tile = t
            lines.append(f"  tile {t} -> slot {t % plan.num_slots}")
        n_before = len(lines)
        if isinstance(op, HaloPack):
            names = " ".join(op.names[:4]) + (
                f" +{len(op.names) - 4} more" if len(op.names) > 4 else "")
            lines.append(f"  halo-pack   {len(op.names)} dats ({names})"
                         f"  {_mb(op.nbytes)}")
        elif isinstance(op, HaloExchange):
            lines.append(f"  halo-exchange depth {op.depth},"
                         f" {op.messages} msgs, {_mb(op.nbytes)} (net)")
        elif isinstance(op, HaloUnpack):
            names = " ".join(op.names[:4]) + (
                f" +{len(op.names) - 4} more" if len(op.names) > 4 else "")
            lines.append(f"  halo-unpack {len(op.names)} dats ({names})"
                         f"  {_mb(op.nbytes)}")
        elif isinstance(op, PinUpload):
            names = " ".join(n for n, _ in op.entries)
            lines.append(f"  pin-upload {names}  {_mb(op.raw)}"
                         f" (wire {_mb(op.wire)})")
        elif isinstance(op, Upload):
            if op.items:
                lines.append(f"    upload   {_items_str(op.items)}"
                             f"  {_mb(op.raw)} (wire {_mb(op.wire)})")
        elif isinstance(op, Compute):
            w = _items_str([(n, r[0][0], r[-1][1]) for n, r in op.writes if r])
            lines.append(f"    compute  {_mb(op.nbytes)} touched,"
                         f" {op.flops / 1e6:.2f} MFLOP, writes {w}")
        elif isinstance(op, CarryEdge):
            lines.append(f"    carry -> slot {op.dst_slot}"
                         f"  {_items_str(op.items)}  {_mb(op.nbytes)}")
        elif isinstance(op, Elide):
            lines.append(f"    elide    {_items_str(op.items)}"
                         f"  ({op.rows} rows, no traffic)")
        elif isinstance(op, Download):
            lines.append(f"    download {_items_str(op.items)}"
                         f"  {_mb(op.raw)} (wire {_mb(op.wire)})")
        elif isinstance(op, FetchHome):
            lines.append(f"    fetch-home  {_items_str(op.items)}"
                         f"  {_mb(op.raw)} (disk -> host)")
        elif isinstance(op, SpillHome):
            lines.append(f"    spill-home  {_items_str(op.items)}"
                         f"  {_mb(op.raw)} (host -> disk)")
        elif isinstance(op, Evict):
            lines.append(f"    evict    slot {op.slot}")
        elif isinstance(op, Prefetch):
            names = " ".join(n for n, _ in op.items)
            lines.append(f"    prefetch {names or '-'}  (wire {_mb(op.wire)},"
                         f" next chain's first tile)")
        elif isinstance(op, WritebackPinned):
            names = " ".join(n for n, _, _, _ in op.entries)
            lines.append(f"  writeback-pinned {names}  {_mb(op.raw)}"
                         f" (wire {_mb(op.wire)})")
        if len(lines) > n_before:
            # Stable op index (position in plan.ops), preserving indentation.
            ln = lines[-1]
            pad = len(ln) - len(ln.lstrip())
            lines[-1] = f"{ln[:pad]}#{idx:<3d} {ln[pad:]}"
    lines.append(
        f"  totals: up {_mb(tot['uploaded'])} (wire {_mb(tot['uploaded_wire'])}),"
        f" down {_mb(tot['downloaded'])} (wire {_mb(tot['downloaded_wire'])}),"
        f" edge {_mb(tot['edge_bytes'])}"
        + (f", disk r/w {_mb(tot['disk_read'])}/{_mb(tot['disk_written'])}"
           if plan.spill_home else "")
        + (f", halo {_mb(tot['halo_bytes'])} in {tot['halo_messages']} msgs"
           if tot["halo_messages"] else ""))
    lines.append(
        "  ops: " + ", ".join(f"{v} {k}" for k, v in plan.counts().items() if v))
    if hw is not None:
        from .interp import simulate_plan  # function-level: avoids a cycle

        res = simulate_plan(plan, hw)
        bw = plan.loop_bytes / res.makespan / 1e9 if res.makespan else 0.0
        who = (f"device {plan.device}, {hw.name}"
               if plan.mesh_devices > 1 else hw.name)
        lines.append(f"  modelled makespan ({who}): "
                     f"{res.makespan * 1e3:.3f} ms"
                     f"  ({bw:.1f} GB/s avg over {_mb(plan.loop_bytes)}"
                     f" useful bytes)")
    return "\n".join(lines)
