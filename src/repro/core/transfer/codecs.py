"""Pluggable compression codecs for the host<->device transfer path.

Following "Compression-Based Optimizations for Out-of-Core GPU Stencil
Computation" (Shen et al., 2022), every staged footprint can be encoded
before it crosses the slow link and decoded on the other side; the *wire*
bytes (encoded size) are what the transfer ledger charges, so modelled
makespans reflect compressed traffic while the data plane stays real.

Built-ins:

===============  ==============================================================
``identity``     no-op; wire bytes == raw bytes (the default, bit-exact)
``fp16``         lossy IEEE half down-cast of float data (2x on fp32)
``bf16``         lossy bfloat16 down-cast via round-to-nearest-even bit
                 truncation (2x on fp32, keeps fp32's exponent range)
``shuffle-rle``  lossless byte-shuffle (group bytes by significance plane)
                 + run-length coding; wins on smooth fields, can expand on
                 noise — the achieved ratio is reported either way
===============  ==============================================================

Codecs are stateless singletons in a string-keyed registry mirroring the
backend registry: ``register_codec`` / ``get_codec`` / ``available_codecs``.
Non-float arrays pass through the lossy down-cast codecs unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple, Union

import numpy as np


class Codec:
    """Encode/decode one staged array region.

    ``encode`` returns ``(payload, meta)``; ``decode(payload, meta)`` must
    return an array of the original dtype/shape.  ``wire_bytes`` is the size
    the link actually carries.  ``nominal_ratio`` is the dtype-level estimate
    used by ``simulate_only`` runs, where there is no data to compress.
    """

    name: str = "?"
    lossless: bool = True

    def encode(self, arr: np.ndarray) -> Tuple[Any, Dict]:
        raise NotImplementedError

    def decode(self, payload: Any, meta: Dict) -> np.ndarray:
        raise NotImplementedError

    @staticmethod
    def wire_bytes(payload: Any) -> int:
        return int(payload.nbytes if hasattr(payload, "nbytes") else len(payload))

    def nominal_ratio(self, dtype: np.dtype) -> float:
        return 1.0

    def roundtrip(self, arr: np.ndarray) -> Tuple[np.ndarray, int, int]:
        """Encode+decode ``arr``; returns ``(decoded, raw_bytes, wire_bytes)``.

        This is what the transfer engine runs on the staging path: the decoded
        array is what lands on the far side, so lossy codecs really lose bits.
        """
        arr = np.asarray(arr)
        payload, meta = self.encode(arr)
        return self.decode(payload, meta), int(arr.nbytes), self.wire_bytes(payload)


class IdentityCodec(Codec):
    name = "identity"
    lossless = True

    def encode(self, arr):
        return arr, {}

    def decode(self, payload, meta):
        return payload

    def roundtrip(self, arr):
        arr = np.asarray(arr)
        return arr, int(arr.nbytes), int(arr.nbytes)


def _bf16_encode(f32: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even truncation of fp32 to its top 16 bits.

    NaNs are special-cased: the rounding add would carry a NaN mantissa into
    the exponent (0x7FFFFFFF -> 0x8000, i.e. -0.0), silently swallowing a
    diverged simulation.  They map to the signed quiet NaN instead.
    """
    f32 = np.ascontiguousarray(f32, dtype=np.float32)
    u = f32.view(np.uint32)
    rounding = np.uint32(0x7FFF) + ((u >> np.uint32(16)) & np.uint32(1))
    enc = ((u + rounding) >> np.uint32(16)).astype(np.uint16)
    nan = np.isnan(f32)
    if nan.any():
        qnan = ((u >> np.uint32(16)) & np.uint16(0x8000)) | np.uint16(0x7FC0)
        enc = np.where(nan, qnan.astype(np.uint16), enc)
    return enc


def _bf16_decode(enc: np.ndarray) -> np.ndarray:
    return (enc.astype(np.uint32) << np.uint32(16)).view(np.float32)


class DowncastCodec(Codec):
    """Lossy float down-cast (``fp16`` / ``bf16``); non-floats pass through."""

    lossless = False

    def __init__(self, name: str):
        self.name = name

    def encode(self, arr):
        meta = {"dtype": arr.dtype.str, "shape": arr.shape}
        if arr.dtype.kind != "f" or arr.dtype.itemsize <= 2:
            return arr, {**meta, "passthrough": True}
        if self.name == "fp16":
            return arr.astype(np.float16), meta
        return _bf16_encode(arr.astype(np.float32)), meta

    def decode(self, payload, meta):
        if meta.get("passthrough"):
            return payload
        dtype = np.dtype(meta["dtype"])
        if self.name == "fp16":
            return payload.astype(dtype)
        return _bf16_decode(payload).astype(dtype)

    def nominal_ratio(self, dtype):
        dtype = np.dtype(dtype)
        if dtype.kind != "f" or dtype.itemsize <= 2:
            return 1.0
        return dtype.itemsize / 2.0


class ShuffleRLECodec(Codec):
    """Byte-shuffle + run-length coding, lossless.

    The shuffle transposes the (n_elements, itemsize) byte matrix so each
    significance plane is contiguous; smooth fields then expose long runs in
    the exponent/high-mantissa planes.  Runs are stored as (length, value)
    uint8 pairs (long runs split at 255), so the worst case doubles the size —
    the achieved ratio is whatever it is, and is reported honestly.
    """

    name = "shuffle-rle"
    lossless = True

    def encode(self, arr):
        arr = np.ascontiguousarray(arr)
        meta = {"dtype": arr.dtype.str, "shape": arr.shape}
        itemsize = arr.dtype.itemsize
        flat = np.frombuffer(arr.tobytes(), dtype=np.uint8)
        if flat.size == 0:
            return np.zeros(0, np.uint8), meta
        shuffled = flat.reshape(-1, itemsize).T.ravel()
        # Vectorised RLE over the shuffled byte stream.
        change = np.flatnonzero(shuffled[1:] != shuffled[:-1]) + 1
        starts = np.concatenate(([0], change))
        lengths = np.diff(np.concatenate((starts, [shuffled.size])))
        values = shuffled[starts]
        # Split runs longer than 255 into full chunks + remainder in [1, 255].
        reps = (lengths + 254) // 255
        out_values = np.repeat(values, reps).astype(np.uint8)
        out_lengths = np.full(out_values.size, 255, dtype=np.uint8)
        last = np.cumsum(reps) - 1
        out_lengths[last] = (lengths - (reps - 1) * 255).astype(np.uint8)
        return np.concatenate((out_lengths, out_values)), meta

    def decode(self, payload, meta):
        dtype = np.dtype(meta["dtype"])
        shape = tuple(meta["shape"])
        n = payload.size // 2
        lengths = payload[:n].astype(np.intp)
        values = payload[n:]
        flat = np.repeat(values, lengths)
        itemsize = dtype.itemsize
        unshuffled = flat.reshape(itemsize, -1).T.reshape(-1)
        return np.frombuffer(unshuffled.tobytes(), dtype=dtype).reshape(shape)


# -- registry ---------------------------------------------------------------------

_CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Register a codec instance under its ``name``."""
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    codec = _CODECS.get(name)
    if codec is None:
        raise ValueError(
            f"unknown codec {name!r}; available: {', '.join(available_codecs())}")
    return codec


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_CODECS))


register_codec(IdentityCodec())
register_codec(DowncastCodec("fp16"))
register_codec(DowncastCodec("bf16"))
register_codec(ShuffleRLECodec())


CodecSpec = Union[str, Dict[str, str], None]


def resolve_codecs(spec: CodecSpec, dat_names: Sequence[str]) -> Dict[str, Codec]:
    """Materialise a per-dataset codec map from a config spec.

    ``spec`` is a codec name applied to every dataset, or a ``{dat: name}``
    dict with an optional ``"*"`` default (identity if absent), or ``None``
    (identity everywhere).  Dict entries naming datasets a particular chain
    does not touch are simply unused (one spec serves every chain of an app).
    """
    if spec is None:
        spec = "identity"
    if isinstance(spec, str):
        codec = get_codec(spec)
        return {nm: codec for nm in dat_names}
    default = get_codec(spec.get("*", "identity"))
    return {nm: get_codec(spec[nm]) if nm in spec else default for nm in dat_names}
