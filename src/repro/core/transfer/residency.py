"""Fast-memory residency management for the out-of-core executor.

Replaces the executor's ad-hoc ``t % num_slots`` arithmetic with an explicit,
checkable model of what occupies fast memory:

* **LRU slot pool** — ``acquire()`` hands out the least-recently-used slot;
  with tiles arriving in order this degenerates to the paper's round-robin,
  but the invariant is now *enforced*: a slot may not be reused while it
  still holds dirty rows that were neither written back, carried to the next
  slot by an edge copy, nor elided (§4.1 Cyclic).
* **Dirty-range tracking** — per-slot, per-dataset merged row intervals
  written on device but not yet home.  Edge copies ``carry`` responsibility
  forward; downloads ``writeback``; Cyclic ``elide``s.  ``end_chain``
  asserts nothing dirty survives — the executor bug-detector the inline
  code never had.
* **Pinned datasets** — small/hot datasets kept device-resident *across*
  chains (keyed by dataset identity + version), skipping per-tile staging
  entirely; written pinned data flushes home once per chain.
* **Capacity accounting** — ``check_fit`` is the single place fast-memory
  budget is enforced; both the real execution path and the executor's
  MemoryError chain-splitting logic consult it.

The manager works in grid-row intervals along the tiled dimension (byte
accounting stays in the executor, which knows row byte-widths).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# One interval algebra for the whole runtime: the dependency analyser owns
# the merged-half-open-list helpers; only intersection is new here.
from ..dependency import _merge, _subtract

Intervals = List[Tuple[int, int]]  # merged, half-open


def _intersect(a: Intervals, b: Intervals) -> Intervals:
    out: Intervals = []
    for lo, hi in a:
        for blo, bhi in b:
            ilo, ihi = max(lo, blo), min(hi, bhi)
            if ihi > ilo:
                out.append((ilo, ihi))
    return _merge(out)


@dataclass
class Slot:
    """One fast-memory staging slot (arrays are executor-owned)."""

    index: int
    arrays: Dict[str, Any] = field(default_factory=dict)
    origins: Dict[str, int] = field(default_factory=dict)
    # Guards functional read-modify-write of ``arrays`` entries: the upload
    # worker and the main thread's edge copy touch disjoint *regions* but the
    # same dict slot, so the compose step must be atomic per entry.
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    dirty: Dict[str, Intervals] = field(default_factory=dict)
    used: bool = False   # handed out at least once (reuse == eviction)

    def dirty_rows(self) -> int:
        return sum(hi - lo for ivs in self.dirty.values() for lo, hi in ivs)


class ResidencyError(RuntimeError):
    """A residency invariant was violated (an executor bug, not user error)."""


class ResidencyManager:
    """LRU slot pool + dirty tracking + pinned cache + capacity accounting."""

    def __init__(self, capacity_bytes: float, num_slots: int,
                 pinned: frozenset = frozenset()):
        self.capacity_bytes = float(capacity_bytes)
        self.num_slots = int(num_slots)
        self.pinned = frozenset(pinned)
        self._lru: "OrderedDict[int, Slot]" = OrderedDict()
        # name -> (dataset id, dataset version, device array, origin row)
        self._pinned_cache: Dict[str, Tuple[int, int, Any, int]] = {}
        # Pending home accesses this chain: name -> [(lo, hi, handle)].
        # Uploads *read* home rows, downloads *write* them; either side must
        # wait on earlier-submitted overlapping accesses of the other kind.
        self._home_writes: Dict[str, List[Tuple[int, int, Any]]] = {}
        self._home_reads: Dict[str, List[Tuple[int, int, Any]]] = {}
        self.stats: Dict[str, float] = {
            "acquires": 0, "evictions": 0, "writeback_rows": 0,
            "carried_rows": 0, "elided_rows": 0, "pinned_hits": 0,
            "pinned_uploads": 0, "peak_required_bytes": 0,
            "peak_home_bytes": 0, "host_overflow_bytes": 0,
        }

    # -- capacity accounting (the oracle for BOTH memory tiers) --------------
    # Fast tier: overflow is a hard MemoryError the executor answers by
    # splitting the chain.  Host tier: overflow is *plannable* — the planner
    # answers it with FetchHome/SpillHome ops against the disk-backed store —
    # so ``host_overflow`` returns a verdict instead of raising.
    def required_bytes(self, slot_bytes: int, pinned_bytes: int = 0) -> int:
        return self.num_slots * int(slot_bytes) + int(pinned_bytes)

    def check_fit(self, slot_bytes: int, pinned_bytes: int = 0) -> int:
        """Raise ``MemoryError`` when the plan cannot be fast-memory resident
        (the fast-tier half of the oracle; :meth:`host_overflow` is the host
        tier's)."""
        req = self.required_bytes(slot_bytes, pinned_bytes)
        self.stats["peak_required_bytes"] = max(
            self.stats["peak_required_bytes"], req)
        if req > self.capacity_bytes:
            raise MemoryError(
                f"{self.num_slots} slots x {int(slot_bytes)}B"
                + (f" + {int(pinned_bytes)}B pinned" if pinned_bytes else "")
                + f" exceed fast capacity {int(self.capacity_bytes)}B; "
                f"increase num_tiles")
        return req

    def host_overflow(self, home_bytes: int,
                      host_capacity: Optional[float] = None) -> bool:
        """Host-tier verdict: ``True`` when the chain's dataset home copies
        exceed host RAM, so the planner must emit ``FetchHome``/``SpillHome``
        ops and route the overflow through the disk-backed store."""
        cap = float("inf") if host_capacity is None else float(host_capacity)
        home_bytes = int(home_bytes)
        self.stats["peak_home_bytes"] = max(
            self.stats["peak_home_bytes"], home_bytes)
        over = home_bytes > cap
        if over:
            self.stats["host_overflow_bytes"] = max(
                self.stats["host_overflow_bytes"], int(home_bytes - cap))
        return over

    # -- chain lifecycle ------------------------------------------------------
    def begin_chain(self, num_slots: Optional[int] = None) -> List[Slot]:
        """(Re)build the slot pool for one chain; returns the slots."""
        n = self.num_slots if num_slots is None else int(num_slots)
        self._lru = OrderedDict((i, Slot(index=i)) for i in range(n))
        self._home_writes = {}
        self._home_reads = {}
        return list(self._lru.values())

    def acquire(self) -> Slot:
        """Hand out the least-recently-used slot for the next tile.

        Reuse of a previously-used slot is an *eviction*: its dirty rows must
        already have been written back, carried forward, or elided — enforcing
        Algorithm 1's download-before-reuse ordering.
        """
        if not self._lru:
            raise ResidencyError("acquire() before begin_chain()")
        idx, slot = next(iter(self._lru.items()))
        # A pool of one never *evicts* — the single slot's contents continue
        # into the next tile (edge copies are slot-internal), so carried
        # dirty rows are legitimate there.
        if len(self._lru) > 1 and slot.dirty_rows():  # refuse before touching LRU state
            raise ResidencyError(
                f"slot {slot.index} reused while rows are still dirty "
                f"(no writeback/carry/elide): "
                f"{ {n: ivs for n, ivs in slot.dirty.items() if ivs} }")
        self._lru.move_to_end(idx)
        self.stats["acquires"] += 1
        if slot.used:   # a reuse discards the previous tile's residency
            self.stats["evictions"] += 1
        slot.used = True
        return slot

    def end_chain(self) -> None:
        """Assert the chain retired every dirty row it produced."""
        leaked = {
            (s.index, n): ivs
            for s in self._lru.values() for n, ivs in s.dirty.items() if ivs
        }
        if leaked:
            raise ResidencyError(
                f"chain finished with dirty rows never written back: {leaked}")
        self._lru = OrderedDict()
        self._home_writes = {}
        self._home_reads = {}

    # -- dirty-range tracking -------------------------------------------------
    def mark_dirty(self, slot: Slot, name: str, lo: int, hi: int) -> None:
        if hi <= lo:
            return
        slot.dirty[name] = _merge(slot.dirty.get(name, []) + [(lo, hi)])

    def carry(self, src: Slot, dst: Slot, name: str, lo: int, hi: int) -> None:
        """An edge copy moved rows [lo, hi) of ``name`` to the next slot:
        responsibility for their eventual writeback moves with them."""
        if hi <= lo:
            return
        moved = _intersect(src.dirty.get(name, []), [(lo, hi)])
        if not moved:
            return
        src.dirty[name] = _subtract(src.dirty.get(name, []), moved)
        dst.dirty[name] = _merge(dst.dirty.get(name, []) + moved)
        self.stats["carried_rows"] += sum(b - a for a, b in moved)

    def writeback(self, slot: Slot, name: str, lo: int, hi: int,
                  handle: Any = None) -> None:
        """A download of rows [lo, hi) was submitted: they are no longer the
        slot's responsibility.  ``handle`` (if any) is recorded so a later
        upload reading the same home rows can wait for the write to land."""
        if hi <= lo:
            return
        cleared = _intersect(slot.dirty.get(name, []), [(lo, hi)])
        slot.dirty[name] = _subtract(slot.dirty.get(name, []), [(lo, hi)])
        self.stats["writeback_rows"] += sum(b - a for a, b in cleared)
        if handle is not None:
            self._home_writes.setdefault(name, []).append((lo, hi, handle))

    def elide(self, slot: Slot, name: str, lo: int, hi: int) -> None:
        """§4.1 Cyclic: rows [lo, hi) are a dead temporary — clean without
        traffic (the elision is the optimisation; the bookkeeping stays)."""
        if hi <= lo:
            return
        cleared = _intersect(slot.dirty.get(name, []), [(lo, hi)])
        slot.dirty[name] = _subtract(slot.dirty.get(name, []), [(lo, hi)])
        self.stats["elided_rows"] += sum(b - a for a, b in cleared)

    def home_conflicts(self, name: str, lo: int, hi: int) -> List[Any]:
        """Handles of pending home writes overlapping rows [lo, hi)."""
        return [h for (wlo, whi, h) in self._home_writes.get(name, ())
                if wlo < hi and lo < whi and h is not None]

    def note_home_read(self, name: str, lo: int, hi: int, handle: Any) -> None:
        """An upload was submitted that reads home rows [lo, hi)."""
        if hi > lo and handle is not None:
            self._home_reads.setdefault(name, []).append((lo, hi, handle))

    def home_read_conflicts(self, name: str, lo: int, hi: int) -> List[Any]:
        """Handles of pending home reads overlapping rows [lo, hi).

        The submission order is upload(t+1) *before* download(t), so a
        download writing rows an earlier-queued upload still has to read must
        wait for that staging read — the mirror of :meth:`home_conflicts`."""
        return [h for (rlo, rhi, h) in self._home_reads.get(name, ())
                if rlo < hi and lo < rhi and h is not None]

    # -- pinned datasets ------------------------------------------------------
    def pinned_lookup(self, dat) -> Optional[Tuple[Any, int]]:
        """Device-resident (array, origin) for ``dat`` if still valid."""
        ent = self._pinned_cache.get(dat.name)
        if ent is None:
            return None
        dat_id, version, array, origin = ent
        if dat_id != id(dat) or version != getattr(dat, "version", 0):
            return None
        self.stats["pinned_hits"] += 1
        return array, origin

    def pinned_store(self, dat, array: Any, origin: int) -> None:
        self._pinned_cache[dat.name] = (
            id(dat), getattr(dat, "version", 0), array, origin)
        self.stats["pinned_uploads"] += 1

    def pinned_update(self, dat, array: Any) -> None:
        """Refresh the cached device array after tiles modified it."""
        ent = self._pinned_cache.get(dat.name)
        if ent is not None:
            self._pinned_cache[dat.name] = (ent[0], ent[1], array, ent[3])

    def pinned_mark_flushed(self, dat) -> None:
        """Home copy now matches the device copy (post chain-end download)."""
        ent = self._pinned_cache.get(dat.name)
        if ent is not None:
            self._pinned_cache[dat.name] = (
                ent[0], getattr(dat, "version", 0), ent[2], ent[3])

    def pinned_bytes(self) -> int:
        return sum(getattr(e[2], "nbytes", 0) for e in self._pinned_cache.values())
