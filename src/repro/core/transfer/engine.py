"""Asynchronous transfer engine: upload/download queues on worker threads.

Algorithm 1 runs three streams — compute, upload, download — and the paper's
whole argument is that host<->device traffic must overlap compute.  The
executor previously performed every transfer synchronously inline and only
*modelled* the overlap through the ledger; this engine makes the data plane
genuinely concurrent: one background worker per direction drains a FIFO
queue of staging tasks (slice + codec + copy), double-buffered against the
slot pool, while the main thread computes.

``mode="sync"`` executes every task inline at submit time — the deterministic
fallback for tests and the default.  Both modes produce bit-identical data:
tasks touch disjoint regions and functional array updates commute, so
threading changes wall-clock behaviour only.

Tasks return ``(raw_bytes, wire_bytes)``; the engine accumulates per-direction
byte/time stats (including queue-wait: submit-to-start latency) that the
executor folds into :class:`~repro.core.executor.ChainStats` and benchmarks
report as the ``transfer`` section.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ...obs.metrics import Histogram

UP = "up"
DOWN = "down"
# The third lane (tiered host storage): disk<->host traffic — FetchHome
# prefetches of tile t+2's home rows and SpillHome retirements — runs on its
# own worker so it overlaps tile t+1's host->device upload AND tile t's
# compute.  One queue serves both directions of disk I/O (a spinning or
# queued-flash store serialises them anyway).
DISK = "disk"


class TransferError(RuntimeError):
    """A transfer task failed on a worker thread (original exception chained)."""


def _task_label(direction: str) -> str:
    return {UP: "upload", DOWN: "download", DISK: "disk"}.get(direction, direction)


class TransferHandle:
    """Completion token for one submitted transfer task."""

    __slots__ = ("direction", "result", "error", "t_submit", "t_start", "t_end",
                 "_event")

    def __init__(self, direction: str):
        self.direction = direction
        self.result: Optional[Tuple[int, int]] = None
        self.error: Optional[BaseException] = None
        self.t_submit = time.perf_counter()
        self.t_start = 0.0
        self.t_end = 0.0
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.t_start - self.t_submit)

    def wait(self) -> Tuple[int, int]:
        self._event.wait()
        if self.error is not None:
            raise TransferError(
                f"{_task_label(self.direction)} task failed: {self.error}"
            ) from self.error
        return self.result


class TransferEngine:
    """Owns the upload/download queues; ``submit`` returns a handle.

    ``deps`` are handles the task must wait for before running (used for the
    rare home-copy conflict: an upload reading rows a still-pending download
    is writing back).  In sync mode deps are already complete by construction.
    """

    MODES = ("sync", "threaded")

    def __init__(self, mode: str = "sync"):
        if mode not in self.MODES:
            raise ValueError(f"unknown transfer mode {mode!r}; one of {self.MODES}")
        self.mode = mode
        self._queues: Dict[str, "queue.Queue"] = {}
        self._workers: Dict[str, threading.Thread] = {}
        self._pending: List[TransferHandle] = []
        self._lock = threading.Lock()
        self.stats: Dict[str, float] = {
            "tasks_up": 0, "tasks_down": 0, "tasks_disk": 0,
            "bytes_up_raw": 0, "bytes_up_wire": 0,
            "bytes_down_raw": 0, "bytes_down_wire": 0,
            "bytes_disk_raw": 0, "bytes_disk_wire": 0,
            "queue_wait_s": 0.0, "busy_s": 0.0,
        }
        # Per-lane latency distributions from the handle timestamps every
        # task already records: queue-wait (submit -> start) and service
        # (start -> end).  Lazily keyed by direction on first task.
        self.lane_hist: Dict[str, Dict[str, Histogram]] = {}

    # -- submission ----------------------------------------------------------
    def submit(self, direction: str, fn: Callable[[], Tuple[int, int]],
               deps: Sequence[TransferHandle] = ()) -> TransferHandle:
        assert direction in (UP, DOWN, DISK), direction
        handle = TransferHandle(direction)
        if self.mode == "sync":
            self._run(handle, fn, deps)
            if handle.error is not None:
                raise TransferError(
                    f"{_task_label(direction)} task failed: {handle.error}"
                ) from handle.error
            return handle
        with self._lock:
            self._pending.append(handle)
        self._worker_for(direction).put((handle, fn, tuple(deps)))
        return handle

    def _worker_for(self, direction: str) -> "queue.Queue":
        q = self._queues.get(direction)
        if q is None:
            q = queue.Queue()
            self._queues[direction] = q
            t = threading.Thread(
                target=self._worker_loop, args=(q,),
                name=f"transfer-{direction}", daemon=True)
            self._workers[direction] = t
            t.start()
        return q

    def _worker_loop(self, q: "queue.Queue") -> None:
        while True:
            item = q.get()
            if item is None:
                return
            handle, fn, deps = item
            self._run(handle, fn, deps)

    def _run(self, handle: TransferHandle, fn, deps) -> None:
        try:
            for d in deps:
                d._event.wait()  # dep *completion*, not success: the failure
                # surfaces from the dep's own handle at drain
            handle.t_start = time.perf_counter()
            raw, wire = fn()
            handle.result = (int(raw), int(wire))
        except BaseException as e:  # noqa: BLE001 — must cross the thread
            handle.error = e
        finally:
            handle.t_end = time.perf_counter()
            self._account(handle)
            handle._event.set()

    def _account(self, handle: TransferHandle) -> None:
        with self._lock:
            st = self.stats
            st["queue_wait_s"] += handle.queue_wait_s
            st["busy_s"] += max(0.0, handle.t_end - handle.t_start)
            lh = self.lane_hist.get(handle.direction)
            if lh is None:
                lh = self.lane_hist[handle.direction] = {
                    "queue_wait": Histogram(), "service": Histogram()}
            lh["queue_wait"].observe(handle.queue_wait_s)
            lh["service"].observe(max(0.0, handle.t_end - handle.t_start))
            if handle.result is not None:
                raw, wire = handle.result
                st[f"tasks_{handle.direction}"] += 1
                st[f"bytes_{handle.direction}_raw"] += raw
                st[f"bytes_{handle.direction}_wire"] += wire

    def lane_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-lane queue-wait / service-time histogram snapshots
        (``{"up": {"queue_wait": {...}, "service": {...}}, ...}``)."""
        with self._lock:
            return {lane: {k: h.snapshot() for k, h in hists.items()}
                    for lane, hists in self.lane_hist.items()}

    # -- synchronisation -----------------------------------------------------
    def drain(self) -> None:
        """Wait for every outstanding task; re-raise the first failure."""
        if self.mode == "sync":
            return
        with self._lock:
            pending, self._pending = self._pending, []
        first_error = None
        for h in pending:
            h._event.wait()
            if h.error is not None and first_error is None:
                first_error = h
        if first_error is not None:
            raise TransferError(
                f"{_task_label(first_error.direction)} task failed: "
                f"{first_error.error}") from first_error.error

    def close(self) -> None:
        """Stop worker threads (they are daemons, so this is optional)."""
        for direction, q in list(self._queues.items()):
            q.put(None)
            self._workers[direction].join(timeout=5)
        self._queues.clear()
        self._workers.clear()

    # -- stats ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.stats)

    @staticmethod
    def delta(after: Dict[str, float], before: Dict[str, float]) -> Dict[str, float]:
        return {k: after[k] - before.get(k, 0) for k in after}
