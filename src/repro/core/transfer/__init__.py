"""repro.core.transfer — asynchronous transfer engine, residency management,
and pluggable compression codecs for the out-of-core data plane."""
from .codecs import (
    Codec,
    DowncastCodec,
    IdentityCodec,
    ShuffleRLECodec,
    available_codecs,
    get_codec,
    register_codec,
    resolve_codecs,
)
from .engine import TransferEngine, TransferError, TransferHandle
from .residency import ResidencyError, ResidencyManager, Slot

__all__ = [
    "Codec", "IdentityCodec", "DowncastCodec", "ShuffleRLECodec",
    "register_codec", "get_codec", "available_codecs", "resolve_codecs",
    "TransferEngine", "TransferError", "TransferHandle",
    "ResidencyManager", "ResidencyError", "Slot",
]
