"""Tile execution engine: runs a chain's loops over slot-resident arrays.

Loops execute under one ``jax.jit`` per *tile signature* (the pattern of
active loops and their static box sizes).  Interior tiles share a signature,
so a chain compiles O(3) times regardless of tile count: tiled-dim starts and
slot origins enter as traced int32 scalars and all slices are
``lax.dynamic_slice`` / ``lax.dynamic_update_slice``.

This is the moral equivalent of Algorithm 1 line 8 ("adjust base pointers of
datasets for virtual position"): the kernel addresses global grid
coordinates; the engine rebases them into slot-local offsets.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .dependency import ChainInfo
from .loop import AccessMode, Accessor, ParallelLoop
from .tiling import TilePlan, TileSchedule


class _SliceAccessor(Accessor):
    """Accessor over slot arrays for one loop's iteration box."""

    def __init__(self, loop, box_sizes, td, start_td, origins, slots, halos):
        self._loop = loop
        self._sizes = box_sizes
        self.shape = tuple(box_sizes)
        self._td = td
        self._start_td = start_td          # traced: box start in grid coords
        self._origins = origins            # traced: per-dat slot origin
        self._slots = slots
        self._halos = halos                # per-dat halo_lo tuple
        self._args = {a.dat.name: a for a in loop.args}

    def coords(self):
        """Global grid coordinates over the box, broadcast to full box shape."""
        lp = self._loop
        nd = lp.block.ndim
        out = []
        for d in range(nd):
            start = self._start_td if d == self._td else lp.range_[d][0]
            ar = start + jnp.arange(self._sizes[d], dtype=jnp.int32)
            shape = [1] * nd
            shape[d] = self._sizes[d]
            out.append(jnp.broadcast_to(ar.reshape(shape), self.shape))
        return tuple(out)

    def __call__(self, name: str, offset: Tuple[int, ...] = None):
        lp = self._loop
        nd = lp.block.ndim
        if offset is None:
            offset = (0,) * nd
        arr = self._slots[name]
        halo_lo = self._halos[name]
        idx = []
        for d in range(nd):
            if d == self._td:
                idx.append(self._start_td + offset[d] - self._origins[name])
            else:
                idx.append(lp.range_[d][0] + offset[d] + halo_lo[d])
        return lax.dynamic_slice(arr, tuple(idx), self._sizes)


class TileEngine:
    """Compiles & caches tile functions for one chain."""

    def __init__(self, chain: ChainInfo):
        self.chain = chain
        self.td = chain.tiled_dim
        self.halos = {
            name: tuple(h[0] for h in dat.halo) for name, dat in chain.datasets.items()
        }
        self._cache: Dict[Tuple, callable] = {}

    # -- signature ----------------------------------------------------------
    def _signature(self, tile: TilePlan) -> Tuple:
        sig = []
        for box in tile.loop_ranges:
            if box is None:
                sig.append(None)
            else:
                sig.append(tuple(b - a for a, b in box))
        return tuple(sig)

    # -- tile function construction ------------------------------------------
    def _build(self, sig: Tuple):
        chain, td, halos = self.chain, self.td, self.halos

        def tile_fn(slots, starts, origins):
            reds = {}
            slots = dict(slots)
            for k, lp in enumerate(chain.loops):
                sizes = sig[k]
                if sizes is None:
                    continue
                acc = _SliceAccessor(lp, sizes, td, starts[k], origins, slots, halos)
                out = lp.kernel(acc)
                if not isinstance(out, dict):
                    raise TypeError(f"kernel of {lp.name!r} must return a dict")
                for arg in lp.args:
                    if not arg.mode.writes:
                        continue
                    name = arg.dat.name
                    if name not in out:
                        raise KeyError(f"kernel of {lp.name!r} did not produce {name!r}")
                    vals = jnp.asarray(out[name], dtype=arg.dat.dtype)
                    if vals.shape != sizes:
                        raise ValueError(
                            f"kernel of {lp.name!r}: {name!r} shape {vals.shape} "
                            f"!= box {sizes}"
                        )
                    idx = []
                    for d in range(lp.block.ndim):
                        if d == td:
                            idx.append(starts[k] - origins[name])
                        else:
                            idx.append(lp.range_[d][0] + halos[name][d])
                    if arg.mode is AccessMode.INC:
                        cur = lax.dynamic_slice(slots[name], tuple(idx), sizes)
                        vals = cur + vals
                    slots[name] = lax.dynamic_update_slice(slots[name], vals, tuple(idx))
                for rspec in lp.reductions:
                    if rspec.name not in out:
                        raise KeyError(
                            f"kernel of {lp.name!r} did not produce reduction "
                            f"{rspec.name!r}"
                        )
                    contrib = out[rspec.name]
                    if rspec.name in reds:
                        reds[rspec.name] = rspec.combine(reds[rspec.name], contrib)
                    else:
                        reds[rspec.name] = contrib
            return slots, reds

        return jax.jit(tile_fn)

    def run_tile(
        self,
        tile: TilePlan,
        slots: Dict[str, jax.Array],
        origins: Dict[str, int],
    ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        sig = self._signature(tile)
        fn = self._cache.get(sig)
        if fn is None:
            fn = self._build(sig)
            self._cache[sig] = fn
        starts = {
            k: jnp.int32(box[self.td][0])
            for k, box in enumerate(tile.loop_ranges)
            if box is not None
        }
        origins_t = {name: jnp.int32(v) for name, v in origins.items()}
        return fn(slots, starts, origins_t)

    @property
    def num_compiles(self) -> int:
        return len(self._cache)
