"""Dependency analysis over lazy loop chains.

This is the runtime analysis at the heart of the paper (§3): given the
recorded chain of parallel loops — iteration ranges, datasets, stencils,
access modes — classify every dataset and derive the skew slope that makes
left-to-right tile execution legal.

Classification (drives the transfer-elision optimisations of §4.1):
  * ``read_only``   — never written in the chain: never downloaded.
  * ``write_first`` — first access is a pure WRITE: never uploaded, and under
    the (unsafe, opt-in) Cyclic optimisation not downloaded either.
  * ``modified``    — written at least once: must be downloaded (unless
    write_first ∧ cyclic).

Skew slope: a single conservative slope σ = max over all (loop, read-arg)
stencil extents along the tiled dimension.  With per-loop shifts
``shift_k = (n-1-k)·σ`` both flow (RAW) and anti (WAR) dependencies between
any pair of loops in the chain are satisfied for left-to-right tiles — see
the inline proof in :mod:`repro.core.tiling`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

from .dataset import Dataset
from .loop import AccessMode, ParallelLoop


def _merge(intervals: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Merge a list of half-open (lo, hi) intervals."""
    ivs = sorted((lo, hi) for lo, hi in intervals if hi > lo)
    out: List[Tuple[int, int]] = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _subtract(a: List[Tuple[int, int]], b: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """a \\ b for merged interval lists."""
    out: List[Tuple[int, int]] = []
    for lo, hi in a:
        cur = lo
        for blo, bhi in b:
            if bhi <= cur or blo >= hi:
                continue
            if blo > cur:
                out.append((cur, blo))
            cur = max(cur, bhi)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


@dataclass
class ChainInfo:
    """Everything the tiler/executor needs to know about one loop chain."""

    loops: List[ParallelLoop]
    datasets: Dict[str, Dataset]
    read_only: Set[str]
    write_first: Set[str]
    modified: Set[str]
    skew_slope: int
    tiled_dim: int
    # Per-dat merged interval lists along the tiled dim (grid coords):
    #   written[d] — rows some loop writes during the chain (downloads are
    #     clipped to this: never ship unwritten rows home);
    #   cold[d]    — rows READ before any write reaches them (program order):
    #     for write-first dats these still must upload (halo skirts etc.).
    written: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    cold: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    # Per-loop max |read offset| along the tiled dim — drives the per-loop
    # skew (loops that don't read along the tiled dim add no skew; on 3-D
    # chains where 2/3 of the sweeps are y/z this shrinks the chain's total
    # skew by ~4x vs the uniform n*sigma slope).
    loop_extents: List[int] = field(default_factory=list)

    @property
    def num_loops(self) -> int:
        return len(self.loops)

    def accessed_bytes(self) -> int:
        """Home-copy bytes of every dataset the chain touches (for capacity
        decisions: this is what would have to be resident without tiling)."""
        return sum(d.nbytes for d in self.datasets.values())

    def loop_bytes(self) -> int:
        """Paper's 'useful bytes' metric summed over the chain."""
        return sum(lp.bytes_moved() for lp in self.loops)


def analyze_chain(loops: Sequence[ParallelLoop], tiled_dim: int = 0) -> ChainInfo:
    """Classify datasets and compute the skew slope for ``loops``."""
    if not loops:
        raise ValueError("empty chain")
    block = loops[0].block
    for lp in loops:
        if lp.block is not block:
            raise ValueError(
                f"chain mixes blocks ({lp.block.name!r} vs {block.name!r}); "
                "multi-block chains must be split per block"
            )

    datasets: Dict[str, Dataset] = {}
    first_mode: Dict[str, AccessMode] = {}
    modified: Set[str] = set()
    ever_read: Set[str] = set()
    slope = 0
    loop_extents: List[int] = []

    for lp in loops:
        ext = 0
        for arg in lp.args:
            nm = arg.dat.name
            datasets.setdefault(nm, arg.dat)
            if nm not in first_mode:
                first_mode[nm] = arg.mode
            if arg.mode.writes:
                modified.add(nm)
            if arg.mode.reads:
                ever_read.add(nm)
                e = arg.stencil.max_abs_extent(tiled_dim)
                slope = max(slope, e)
                ext = max(ext, e)
        loop_extents.append(ext)

    read_only = {nm for nm in datasets if nm not in modified}
    write_first = {nm for nm, m in first_mode.items() if m is AccessMode.WRITE}

    # Order-aware row analysis along the tiled dim.  The skewed schedule
    # preserves producer-before-consumer, so untiled program order is the
    # right order to decide "read before written" (cold) per row.
    written: Dict[str, List[Tuple[int, int]]] = {nm: [] for nm in datasets}
    cold: Dict[str, List[Tuple[int, int]]] = {nm: [] for nm in datasets}
    for lp in loops:
        lo_r, hi_r = lp.range_[tiled_dim]
        for arg in lp.args:
            if not arg.mode.reads:
                continue
            nm = arg.dat.name
            mn, mx = arg.stencil.extent(tiled_dim)
            blo, bhi = arg.dat.bounds(tiled_dim)
            read_iv = [(max(lo_r + mn, blo), min(hi_r + mx, bhi))]
            cold[nm] = _merge(cold[nm] + _subtract(read_iv, written[nm]))
        for arg in lp.args:
            if arg.mode.writes:
                written[arg.dat.name] = _merge(written[arg.dat.name] + [(lo_r, hi_r)])

    return ChainInfo(
        loops=list(loops),
        datasets=datasets,
        read_only=read_only,
        write_first=write_first,
        modified=modified,
        skew_slope=slope,
        tiled_dim=tiled_dim,
        written=written,
        cold=cold,
        loop_extents=loop_extents,
    )


def chain_signature(info: ChainInfo) -> Tuple:
    """A structural fingerprint of a chain: used by speculative prefetching
    (§4.1) to guess whether the next chain 'looks like' the previous one, and
    by the engine's jit cache."""
    return tuple(
        (
            lp.name,
            lp.range_,
            tuple((a.dat.name, a.stencil.name, a.mode.value) for a in lp.args),
        )
        for lp in info.loops
    )


# -- plan-cache keys -------------------------------------------------------------
#
# ``chain_signature`` is structural only — good enough for the prefetch guess,
# but NOT for replaying a cached plan: the engine's jit'd tile functions close
# over the chain's kernel callables, and applications re-record kernels every
# timestep as fresh closures whose captured constants (dt, RK coefficients,
# sweep direction strings) may change.  ``kernel_fingerprint`` hashes the code
# object plus captured/default values so a changed constant forces a re-plan;
# captured values that aren't plain data (datasets, app objects) hash by type —
# the documented kernel contract is that such captures are static config.

_PRIMITIVES = (bool, int, float, str, bytes, type(None))


def _fp_value(v, depth: int = 0) -> Tuple:
    if depth > 6:
        # Past the recursion cap, fail toward *identity*: equality here would
        # let two distinct deep values share a cached plan (stale replay).
        return ("deep", id(v))
    if isinstance(v, _PRIMITIVES):
        return ("v", v)
    if isinstance(v, (tuple, list)):
        return ("t", tuple(_fp_value(x, depth + 1) for x in v))
    if isinstance(v, dict):
        return ("d", tuple(sorted(
            (repr(k), _fp_value(x, depth + 1)) for k, x in v.items())))
    try:
        import numpy as _np
        if isinstance(v, _np.generic):
            return ("v", v.item())
        arr = None
        if isinstance(v, _np.ndarray):
            arr = v
        elif (type(v).__module__.partition(".")[0] in ("jax", "jaxlib")
              and hasattr(v, "shape") and hasattr(v, "dtype")):
            arr = _np.asarray(v)
        if arr is not None:
            # Content-hash captured arrays: hashing by type alone would let
            # the plan cache replay a kernel whose coefficients changed.
            raw = _np.ascontiguousarray(arr).tobytes()
            if len(raw) <= 4096:
                return ("a", arr.dtype.str, arr.shape, raw)
            import hashlib
            return ("a", arr.dtype.str, arr.shape,
                    hashlib.sha1(raw).hexdigest())
    except Exception:  # pragma: no cover
        pass
    if callable(v) and hasattr(v, "__code__"):
        return ("f", kernel_fingerprint(v, depth + 1))
    try:  # frozen dataclasses (Stencil, HardwareModel), enums, etc.
        return ("h", hash(v), type(v).__qualname__)
    except TypeError:
        # Unhashable object: identity-fingerprint.  id() is stable while the
        # object lives (apps capture `self` once, so steps still cache-hit);
        # a *different* instance forces a re-plan — the safe direction.
        return ("o", f"{type(v).__module__}.{type(v).__qualname__}", id(v))


def _code_fp(code, depth: int = 0) -> Tuple:
    """Fingerprint a code object by value.  ``co_code`` references constants
    and globals by *index*, so co_consts/co_names must be hashed too — two
    lambdas on one source line differing only in a literal would otherwise
    collide.  Nested code objects (inner functions) recurse."""
    consts = tuple(
        _code_fp(c, depth + 1) if hasattr(c, "co_code") else _fp_value(c, depth + 1)
        for c in code.co_consts)
    return (code.co_filename, code.co_firstlineno, code.co_code,
            code.co_names, consts)


def kernel_fingerprint(fn, depth: int = 0) -> Tuple:
    """Value-level identity of a kernel callable (code + captured constants)."""
    import functools as _functools

    if isinstance(fn, _functools.partial):
        return ("p", kernel_fingerprint(fn.func, depth + 1),
                _fp_value(tuple(fn.args), depth), _fp_value(fn.keywords or {}, depth))
    code = getattr(fn, "__code__", None)
    if code is None:  # callable object: type + instance identity (stateful
        # callables with different state must not share a cached plan)
        return ("o", f"{type(fn).__module__}.{type(fn).__qualname__}", id(fn))
    cells = []
    for cell in getattr(fn, "__closure__", None) or ():
        try:
            cells.append(_fp_value(cell.cell_contents, depth))
        except ValueError:  # unassigned cell
            cells.append(("unset",))
    defaults = tuple(_fp_value(v, depth)
                     for v in (getattr(fn, "__defaults__", None) or ()))
    kwdefaults = _fp_value(getattr(fn, "__kwdefaults__", None) or {}, depth)
    return ("k", _code_fp(code, depth), tuple(cells), defaults, kwdefaults)


def loop_kernel_fingerprint(lp: ParallelLoop) -> Tuple:
    """Kernel fingerprint memoised on the loop object — each recorded loop's
    kernel is walked once, not once per flush plus once per inference."""
    fp = lp.__dict__.get("_kernel_fp")
    if fp is None:
        fp = kernel_fingerprint(lp.kernel)
        lp.__dict__["_kernel_fp"] = fp
    return fp


def plan_signature(loops: Sequence[ParallelLoop], tiled_dim: int = 0) -> Tuple:
    """Replay-safe fingerprint of a chain: structure + dataset identity +
    kernel fingerprints.  Two chains with equal plan signatures execute
    identically through a cached plan (analysis, schedule, compiled tiles)."""
    return (tiled_dim,) + tuple(
        (
            lp.name,
            lp.range_,
            tuple((a.dat.name, id(a.dat), a.stencil.points, a.mode.value)
                  for a in lp.args),
            tuple((r.name, r.op) for r in lp.reductions),
            loop_kernel_fingerprint(lp),
        )
        for lp in loops
    )


def shared_plan_signature(loops: Sequence[ParallelLoop], tiled_dim: int = 0) -> Tuple:
    """Tenant-neutral variant of ``plan_signature`` for cross-session plan
    sharing (the serving layer's shared cache).

    ``plan_signature`` keys dataset identity by ``id(a.dat)`` — correct for a
    single session (the same Dataset object means the same buffer), but it
    makes two tenants running the *same* app on *separate* datasets miss each
    other's plans by construction.  Here datasets are keyed structurally
    (name, block extents, halo, dtype): two chains with equal shared
    signatures have isomorphic data layouts and value-identical kernels, so
    one chain's plan replays soundly for the other once its ``ChainInfo`` is
    rebound to the new tenant's datasets (the engine and Plan IR reference
    datasets by name only).

    Kernels that capture non-data objects (app instances, other sessions'
    state) fingerprint by identity inside ``loop_kernel_fingerprint`` and so
    never match across tenants — the safe direction."""
    return (tiled_dim,) + tuple(
        (
            lp.name,
            lp.range_,
            tuple((a.dat.name, tuple(a.dat.block.size), tuple(a.dat.halo),
                   a.dat.dtype.str, a.stencil.points, a.mode.value)
                  for a in lp.args),
            tuple((r.name, r.op) for r in lp.reductions),
            loop_kernel_fingerprint(lp),
        )
        for lp in loops
    )
