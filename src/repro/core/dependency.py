"""Dependency analysis over lazy loop chains.

This is the runtime analysis at the heart of the paper (§3): given the
recorded chain of parallel loops — iteration ranges, datasets, stencils,
access modes — classify every dataset and derive the skew slope that makes
left-to-right tile execution legal.

Classification (drives the transfer-elision optimisations of §4.1):
  * ``read_only``   — never written in the chain: never downloaded.
  * ``write_first`` — first access is a pure WRITE: never uploaded, and under
    the (unsafe, opt-in) Cyclic optimisation not downloaded either.
  * ``modified``    — written at least once: must be downloaded (unless
    write_first ∧ cyclic).

Skew slope: a single conservative slope σ = max over all (loop, read-arg)
stencil extents along the tiled dimension.  With per-loop shifts
``shift_k = (n-1-k)·σ`` both flow (RAW) and anti (WAR) dependencies between
any pair of loops in the chain are satisfied for left-to-right tiles — see
the inline proof in :mod:`repro.core.tiling`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

from .dataset import Dataset
from .loop import AccessMode, ParallelLoop


def _merge(intervals: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Merge a list of half-open (lo, hi) intervals."""
    ivs = sorted((lo, hi) for lo, hi in intervals if hi > lo)
    out: List[Tuple[int, int]] = []
    for lo, hi in ivs:
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def _subtract(a: List[Tuple[int, int]], b: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """a \\ b for merged interval lists."""
    out: List[Tuple[int, int]] = []
    for lo, hi in a:
        cur = lo
        for blo, bhi in b:
            if bhi <= cur or blo >= hi:
                continue
            if blo > cur:
                out.append((cur, blo))
            cur = max(cur, bhi)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


@dataclass
class ChainInfo:
    """Everything the tiler/executor needs to know about one loop chain."""

    loops: List[ParallelLoop]
    datasets: Dict[str, Dataset]
    read_only: Set[str]
    write_first: Set[str]
    modified: Set[str]
    skew_slope: int
    tiled_dim: int
    # Per-dat merged interval lists along the tiled dim (grid coords):
    #   written[d] — rows some loop writes during the chain (downloads are
    #     clipped to this: never ship unwritten rows home);
    #   cold[d]    — rows READ before any write reaches them (program order):
    #     for write-first dats these still must upload (halo skirts etc.).
    written: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    cold: Dict[str, List[Tuple[int, int]]] = field(default_factory=dict)
    # Per-loop max |read offset| along the tiled dim — drives the per-loop
    # skew (loops that don't read along the tiled dim add no skew; on 3-D
    # chains where 2/3 of the sweeps are y/z this shrinks the chain's total
    # skew by ~4x vs the uniform n*sigma slope).
    loop_extents: List[int] = field(default_factory=list)

    @property
    def num_loops(self) -> int:
        return len(self.loops)

    def accessed_bytes(self) -> int:
        """Home-copy bytes of every dataset the chain touches (for capacity
        decisions: this is what would have to be resident without tiling)."""
        return sum(d.nbytes for d in self.datasets.values())

    def loop_bytes(self) -> int:
        """Paper's 'useful bytes' metric summed over the chain."""
        return sum(lp.bytes_moved() for lp in self.loops)


def analyze_chain(loops: Sequence[ParallelLoop], tiled_dim: int = 0) -> ChainInfo:
    """Classify datasets and compute the skew slope for ``loops``."""
    if not loops:
        raise ValueError("empty chain")
    block = loops[0].block
    for lp in loops:
        if lp.block is not block:
            raise ValueError(
                f"chain mixes blocks ({lp.block.name!r} vs {block.name!r}); "
                "multi-block chains must be split per block"
            )

    datasets: Dict[str, Dataset] = {}
    first_mode: Dict[str, AccessMode] = {}
    modified: Set[str] = set()
    ever_read: Set[str] = set()
    slope = 0
    loop_extents: List[int] = []

    for lp in loops:
        ext = 0
        for arg in lp.args:
            nm = arg.dat.name
            datasets.setdefault(nm, arg.dat)
            if nm not in first_mode:
                first_mode[nm] = arg.mode
            if arg.mode.writes:
                modified.add(nm)
            if arg.mode.reads:
                ever_read.add(nm)
                e = arg.stencil.max_abs_extent(tiled_dim)
                slope = max(slope, e)
                ext = max(ext, e)
        loop_extents.append(ext)

    read_only = {nm for nm in datasets if nm not in modified}
    write_first = {nm for nm, m in first_mode.items() if m is AccessMode.WRITE}

    # Order-aware row analysis along the tiled dim.  The skewed schedule
    # preserves producer-before-consumer, so untiled program order is the
    # right order to decide "read before written" (cold) per row.
    written: Dict[str, List[Tuple[int, int]]] = {nm: [] for nm in datasets}
    cold: Dict[str, List[Tuple[int, int]]] = {nm: [] for nm in datasets}
    for lp in loops:
        lo_r, hi_r = lp.range_[tiled_dim]
        for arg in lp.args:
            if not arg.mode.reads:
                continue
            nm = arg.dat.name
            mn, mx = arg.stencil.extent(tiled_dim)
            blo, bhi = arg.dat.bounds(tiled_dim)
            read_iv = [(max(lo_r + mn, blo), min(hi_r + mx, bhi))]
            cold[nm] = _merge(cold[nm] + _subtract(read_iv, written[nm]))
        for arg in lp.args:
            if arg.mode.writes:
                written[arg.dat.name] = _merge(written[arg.dat.name] + [(lo_r, hi_r)])

    return ChainInfo(
        loops=list(loops),
        datasets=datasets,
        read_only=read_only,
        write_first=write_first,
        modified=modified,
        skew_slope=slope,
        tiled_dim=tiled_dim,
        written=written,
        cold=cold,
        loop_extents=loop_extents,
    )


def chain_signature(info: ChainInfo) -> Tuple:
    """A structural fingerprint of a chain: used by speculative prefetching
    (§4.1) to guess whether the next chain 'looks like' the previous one, and
    by the engine's jit cache."""
    return tuple(
        (
            lp.name,
            lp.range_,
            tuple((a.dat.name, a.stencil.name, a.mode.value) for a in lp.args),
        )
        for lp in info.loops
    )
