"""Datasets — grid-resident arrays owned by the runtime (``ops_dat``).

A dataset lives in *slow memory* as its home location; the out-of-core
executor stages footprints of it into *fast memory* (device HBM) per tile.
Since the tiered-storage subsystem (:mod:`repro.core.store`) the home copy is
a pluggable :class:`~repro.core.store.BackingStore` — in-RAM NumPy (``ram``,
the default and the previous behaviour), an ``np.memmap`` over a spill
directory (``mmap``), or codec-compressed chunks on disk behind an LRU cache
(``chunked``) — so the hierarchy no longer stops at host RAM.  Users only
hold opaque handles; data returns to user space through ``fetch`` (which is
also what terminates lazy loop chains, exactly as in OPS).

Migration note: ``Dataset`` is no longer a dataclass; the constructor
signature is unchanged (``block, name, dtype, halo, data=None, version=0``)
plus the new ``store=``.  ``.data`` is now a property returning the live
backing array for ``ram``/``mmap`` homes and raising
:class:`~repro.core.store.StoreError` for ``chunked`` ones — store-agnostic
code uses ``read``/``write``/``read_rows``/``write_rows``/``materialize``.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import numpy as np

from .block import Block
from .store import BackingStore, StoreConfig, make_store


class Dataset:
    """An array defined over a block, with per-dimension halo padding.

    The backing store spans ``[-halo[d][0], size[d] + halo[d][1])`` per dim.
    Index convention throughout the runtime: *grid coordinates* (interior
    starts at 0); array index = grid index + halo_lo.

    ``version`` is bumped on every user-space ``write``; device-side caches
    (the residency manager's pinned arrays, speculative-prefetch captures)
    key on it to notice a changed home copy.
    """

    def __init__(self, block: Block, name: str, dtype,
                 halo: Tuple[Tuple[int, int], ...],
                 data: Optional[np.ndarray] = None, version: int = 0,
                 store: Union[None, str, StoreConfig, BackingStore] = None):
        self.block = block
        self.name = name
        self.dtype = np.dtype(dtype)
        self.halo = tuple(tuple(int(x) for x in h) for h in halo)
        self.version = version
        if len(self.halo) != block.ndim:
            raise ValueError(f"dat {self.name!r}: halo arity mismatch")
        shape = self.padded_shape
        if data is not None:
            if isinstance(store, BackingStore):
                raise ValueError(
                    f"dat {self.name!r}: pass data= or a ready store, not both")
            data = np.asarray(data, dtype=self.dtype)
            if data.shape != shape:
                raise ValueError(
                    f"dat {self.name!r}: data shape {data.shape} != padded {shape}"
                )
        self._store = make_store(store, name=name, shape=shape,
                                 dtype=self.dtype, data=data)

    @classmethod
    def from_store(cls, block: Block, name: str, store: BackingStore,
                   halo: Union[int, Tuple[Tuple[int, int], ...]] = 1,
                   dtype=None) -> "Dataset":
        """Wrap an existing backing store (e.g. a reopened ``MmapStore``) as
        a dataset; shape/dtype are validated against block + halo."""
        if isinstance(halo, int):
            halo = tuple((halo, halo) for _ in range(block.ndim))
        return cls(block=block, name=name,
                   dtype=store.dtype if dtype is None else dtype,
                   halo=halo, store=store)

    def __repr__(self) -> str:
        return (f"Dataset(name={self.name!r}, block={self.block.name!r}, "
                f"dtype={self.dtype.str}, halo={self.halo}, "
                f"store={self._store.kind!r}, version={self.version})")

    # -- the backing store ---------------------------------------------------
    @property
    def store(self) -> BackingStore:
        return self._store

    @property
    def data(self) -> np.ndarray:
        """The live home array (``ram``/``mmap``); raises for ``chunked``."""
        return self._store.as_array()

    def materialize(self) -> np.ndarray:
        """The whole padded array — a live view for RAM-resident stores, a
        fresh assembly for ``chunked`` (checkpointing / ``fetch_raw``)."""
        return self._store.materialize()

    def flush_store(self) -> int:
        """Persist dirty home state to disk; returns disk bytes written."""
        return self._store.flush()

    def store_stats(self) -> dict:
        return dict(self._store.stats)

    def close(self) -> None:
        self._store.close()

    # -- geometry -----------------------------------------------------------
    @property
    def ndim(self) -> int:
        return self.block.ndim

    @property
    def padded_shape(self) -> Tuple[int, ...]:
        return tuple(
            self.block.size[d] + self.halo[d][0] + self.halo[d][1]
            for d in range(self.block.ndim)
        )

    def bounds(self, dim: int) -> Tuple[int, int]:
        """Grid-coordinate extent of the backing array along ``dim``."""
        return -self.halo[dim][0], self.block.size[dim] + self.halo[dim][1]

    @property
    def nbytes(self) -> int:
        """Logical (uncompressed) home-copy size; what capacity planning and
        the host-tier oracle count, independent of at-rest compression."""
        return self._store.nbytes

    # -- host-side access (grid coordinates) --------------------------------
    def _to_index(self, grid_slices: Tuple[slice, ...]) -> Tuple[slice, ...]:
        idx = []
        for d, sl in enumerate(grid_slices):
            h = self.halo[d][0]
            idx.append(slice(sl.start + h, sl.stop + h))
        return tuple(idx)

    def _rows_index(self, dim: int, lo: int, hi: int) -> Tuple[slice, ...]:
        idx = [slice(None)] * self.ndim
        idx[dim] = slice(lo + self.halo[dim][0], hi + self.halo[dim][0])
        return tuple(idx)

    def read(self, grid_box: Tuple[Tuple[int, int], ...]) -> np.ndarray:
        """Read a grid-coordinate box from the slow-memory home copy."""
        return self._store.read(
            self._to_index(tuple(slice(a, b) for a, b in grid_box)))

    def write(self, grid_box: Tuple[Tuple[int, int], ...], values: np.ndarray) -> None:
        """User-space write: bumps ``version`` so device-side caches notice.

        An empty box is a no-op and does NOT bump the version — a spurious
        bump would invalidate pinned-dataset caching for zero actual change.
        """
        grid_box = tuple(grid_box)
        if any(b <= a for a, b in grid_box):
            return
        self._store.write(
            self._to_index(tuple(slice(a, b) for a, b in grid_box)), values)
        self.version += 1

    # -- runtime-internal access (no version bump) ---------------------------
    def read_region(self, index: Tuple[slice, ...]) -> np.ndarray:
        """Array-index-space read (may be a view for ``ram``/``mmap``)."""
        return self._store.read(tuple(index))

    def write_region(self, index: Tuple[slice, ...], values) -> None:
        """Array-index-space write.  Runtime-internal: executor downloads
        land home without a version bump (the device copy was the truth)."""
        self._store.write(tuple(index), values)

    def read_rows(self, dim: int, lo: int, hi: int) -> np.ndarray:
        """Rows ``[lo, hi)`` (grid coords) along ``dim``, full other dims —
        the staging-slab shape the out-of-core executor moves."""
        return self._store.read(self._rows_index(dim, lo, hi))

    def write_rows(self, dim: int, lo: int, hi: int, values) -> None:
        self._store.write(self._rows_index(dim, lo, hi), values)

    def prefetch_rows(self, dim: int, lo: int, hi: int) -> int:
        """Disk→host fetch of rows ``[lo, hi)`` (FetchHome's data plane);
        returns disk bytes read (0 for RAM-resident stores)."""
        return self._store.prefetch(self._rows_index(dim, lo, hi))

    def spill_rows(self, dim: int, lo: int, hi: int) -> int:
        """Host→disk retirement of rows ``[lo, hi)`` (SpillHome's data
        plane); returns disk bytes written (0 for RAM-resident stores)."""
        return self._store.spill(self._rows_index(dim, lo, hi))

    def interior(self) -> np.ndarray:
        """Interior view (no halos) — the usual thing users fetch."""
        return self.read(self.block.full_range())


def make_dataset(
    block: Block,
    name: str,
    halo: int | Tuple[Tuple[int, int], ...] = 1,
    dtype=np.float32,
    init: Optional[np.ndarray] = None,
    store: Union[None, str, StoreConfig, BackingStore] = None,
) -> Dataset:
    """Convenience constructor; scalar halo means the same pad on every face.

    ``store`` selects the home tier: ``None``/``"ram"`` (default), ``"mmap"``,
    ``"chunked"``, a :class:`~repro.core.store.StoreConfig`, or a ready
    :class:`~repro.core.store.BackingStore`."""
    if isinstance(halo, int):
        halo = tuple((halo, halo) for _ in range(block.ndim))
    dat = Dataset(block=block, name=name, dtype=np.dtype(dtype), halo=halo,
                  store=store)
    if init is not None:
        init = np.asarray(init, dtype=dat.dtype)
        if init.shape == dat.padded_shape:
            dat.write_region(tuple(slice(None) for _ in range(dat.ndim)), init)
        elif init.shape == block.size:
            dat.write(block.full_range(), init)
        else:
            raise ValueError(
                f"init shape {init.shape} matches neither padded {dat.padded_shape} "
                f"nor interior {block.size}"
            )
    return dat
