"""Datasets — grid-resident arrays owned by the runtime (``ops_dat``).

A dataset lives in *slow memory* (host DRAM, represented as a NumPy array)
as its home location; the out-of-core executor stages footprints of it into
*fast memory* (device HBM) per tile.  Users only hold opaque handles; data
returns to user space through ``fetch`` (which is also what terminates lazy
loop chains, exactly as in OPS).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from .block import Block


@dataclass
class Dataset:
    """An array defined over a block, with per-dimension halo padding.

    The backing array spans ``[-halo[d][0], size[d] + halo[d][1])`` per dim.
    Index convention throughout the runtime: *grid coordinates* (interior
    starts at 0); array index = grid index + halo_lo.
    """

    block: Block
    name: str
    dtype: np.dtype
    halo: Tuple[Tuple[int, int], ...]
    data: np.ndarray = field(repr=False, default=None)
    # Bumped on every user-space ``write``; device-side caches (the residency
    # manager's pinned arrays) key on it to notice a changed home copy.
    version: int = field(default=0, compare=False)

    def __post_init__(self) -> None:
        if len(self.halo) != self.block.ndim:
            raise ValueError(f"dat {self.name!r}: halo arity mismatch")
        shape = self.padded_shape
        if self.data is None:
            self.data = np.zeros(shape, dtype=self.dtype)
        else:
            self.data = np.asarray(self.data, dtype=self.dtype)
            if self.data.shape != shape:
                raise ValueError(
                    f"dat {self.name!r}: data shape {self.data.shape} != padded {shape}"
                )

    # -- geometry -----------------------------------------------------------
    @property
    def ndim(self) -> int:
        return self.block.ndim

    @property
    def padded_shape(self) -> Tuple[int, ...]:
        return tuple(
            self.block.size[d] + self.halo[d][0] + self.halo[d][1]
            for d in range(self.block.ndim)
        )

    def bounds(self, dim: int) -> Tuple[int, int]:
        """Grid-coordinate extent of the backing array along ``dim``."""
        return -self.halo[dim][0], self.block.size[dim] + self.halo[dim][1]

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes)

    # -- host-side access (grid coordinates) --------------------------------
    def _to_index(self, grid_slices: Tuple[slice, ...]) -> Tuple[slice, ...]:
        idx = []
        for d, sl in enumerate(grid_slices):
            h = self.halo[d][0]
            idx.append(slice(sl.start + h, sl.stop + h))
        return tuple(idx)

    def read(self, grid_box: Tuple[Tuple[int, int], ...]) -> np.ndarray:
        """Read a grid-coordinate box from the slow-memory home copy."""
        return self.data[self._to_index(tuple(slice(a, b) for a, b in grid_box))]

    def write(self, grid_box: Tuple[Tuple[int, int], ...], values: np.ndarray) -> None:
        self.data[self._to_index(tuple(slice(a, b) for a, b in grid_box))] = values
        self.version += 1

    def interior(self) -> np.ndarray:
        """Interior view (no halos) — the usual thing users fetch."""
        return self.read(self.block.full_range())


def make_dataset(
    block: Block,
    name: str,
    halo: int | Tuple[Tuple[int, int], ...] = 1,
    dtype=np.float32,
    init: Optional[np.ndarray] = None,
) -> Dataset:
    """Convenience constructor; scalar halo means the same pad on every face."""
    if isinstance(halo, int):
        halo = tuple((halo, halo) for _ in range(block.ndim))
    dat = Dataset(block=block, name=name, dtype=np.dtype(dtype), halo=halo)
    if init is not None:
        init = np.asarray(init, dtype=dat.dtype)
        if init.shape == dat.padded_shape:
            dat.data[...] = init
        elif init.shape == block.size:
            dat.write(block.full_range(), init)
        else:
            raise ValueError(
                f"init shape {init.shape} matches neither padded {dat.padded_shape} "
                f"nor interior {block.size}"
            )
    return dat
