"""Pallas version compat: element-offset overlapping block windows.

Newer JAX exposes per-dimension ``Element`` indexing (overlapping halo
windows via element offsets in the index map); older releases (e.g. 0.4.x)
spell the same thing as a whole-spec ``indexing_mode=pl.Unblocked()`` with
element-granular block shapes and index maps.  ``overlapping_spec`` builds
the right ``BlockSpec`` for either.
"""
from __future__ import annotations

from jax.experimental import pallas as pl

try:  # newest exports
    from jax.experimental.pallas import Element
except ImportError:  # pragma: no cover - version fallback
    try:
        from jax._src.pallas.core import Element
    except ImportError:
        Element = None


def overlapping_spec(block_shape, index_map) -> pl.BlockSpec:
    """BlockSpec whose ``block_shape`` and ``index_map`` are in *elements*."""
    if Element is not None:
        return pl.BlockSpec(tuple(Element(b) for b in block_shape), index_map)
    return pl.BlockSpec(tuple(block_shape), index_map,
                        indexing_mode=pl.Unblocked())
