"""Public jit'd wrappers for the Pallas kernels: padding, block sizing, VMEM
budgeting, and interpret-mode selection (interpret on CPU, compiled on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import chain2d as _chain2d
from . import stencil2d as _stencil2d
from . import stencil3d as _stencil3d

# Conservative VMEM working-set budget per block (bytes): v5e has ~128 MiB
# VMEM; with double-buffered input+output blocks keep each block well under.
_VMEM_BUDGET = 4 << 20


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_rows(h_rows: int, row_bytes: int, halo: int, budget: int) -> int:
    """Largest power-of-two row count whose window fits the VMEM budget."""
    bm = 1 << int(np.log2(max(1, budget // max(1, row_bytes))))
    bm = max(8, min(bm, 512))
    while bm > 8 and (bm + 2 * halo) * row_bytes > budget:
        bm //= 2
    return bm


def _pad_rows(x: jax.Array, interior: int, halo: int, bm: int, axis: int = 0):
    """Pad the interior row count to a multiple of bm (zeros; discarded)."""
    rem = interior % bm
    if rem == 0:
        return x, interior
    pad = bm - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), interior + pad


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _stencil2d_jit(x, coeffs, block_rows, interpret):
    H = x.shape[0] - 2
    xp, Hp = _pad_rows(x, H, 1, block_rows)
    out = _stencil2d.stencil2d_pallas(
        xp, coeffs, block_rows=block_rows, interpret=interpret
    )
    return out[:H]


def stencil2d(x, coeffs, *, block_rows: Optional[int] = None,
              interpret: Optional[bool] = None):
    """5-point stencil sweep. x: (H+2, W+2) padded; returns (H, W)."""
    x = jnp.asarray(x)
    coeffs = jnp.asarray(coeffs, dtype=jnp.float32)
    H, Wp = x.shape[0] - 2, x.shape[1]
    if block_rows is None:
        block_rows = _pick_block_rows(H, Wp * x.dtype.itemsize, 1, _VMEM_BUDGET)
    block_rows = min(block_rows, H)
    if interpret is None:
        interpret = _default_interpret()
    return _stencil2d_jit(x, coeffs, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("block_z", "interpret"))
def _stencil3d_jit(x, coeffs, block_z, interpret):
    D = x.shape[0] - 2
    xp, Dp = _pad_rows(x, D, 1, block_z)
    out = _stencil3d.stencil3d_pallas(xp, coeffs, block_z=block_z, interpret=interpret)
    return out[:D]


def stencil3d(x, coeffs, *, block_z: Optional[int] = None,
              interpret: Optional[bool] = None):
    """7-point stencil sweep. x: (D+2, H+2, W+2) padded; returns (D, H, W)."""
    x = jnp.asarray(x)
    coeffs = jnp.asarray(coeffs, dtype=jnp.float32)
    D = x.shape[0] - 2
    plane_bytes = x.shape[1] * x.shape[2] * x.dtype.itemsize
    if block_z is None:
        block_z = _pick_block_rows(D, plane_bytes, 1, _VMEM_BUDGET)
    block_z = min(block_z, D)
    if interpret is None:
        interpret = _default_interpret()
    return _stencil3d_jit(x, coeffs, block_z, interpret)


@functools.partial(jax.jit, static_argnames=("steps", "block_rows", "interpret"))
def _chain2d_jit(x, coeffs, steps, block_rows, interpret):
    H = x.shape[0] - 2 * steps
    xp, Hp = _pad_rows(x, H, steps, block_rows)
    out = _chain2d.chain2d_pallas(
        xp, coeffs, steps=steps, block_rows=block_rows, interpret=interpret
    )
    return out[:H]


def chain2d(x, coeffs, steps: int, *, block_rows: Optional[int] = None,
            interpret: Optional[bool] = None):
    """K fused 5-point sweeps. x: (H+2K, W+2K) padded; returns (H, W)."""
    x = jnp.asarray(x)
    coeffs = jnp.asarray(coeffs, dtype=jnp.float32)
    H, Wp = x.shape[0] - 2 * steps, x.shape[1]
    if block_rows is None:
        block_rows = _pick_block_rows(H, Wp * x.dtype.itemsize, steps, _VMEM_BUDGET)
    block_rows = min(block_rows, H)
    if interpret is None:
        interpret = _default_interpret()
    return _chain2d_jit(x, coeffs, steps, block_rows, interpret)


# -- declarative star-sweep kernels (the "pallas" backend's fast path) -----------
#
# These build Accessor-kernels for the runtime DSL that also *declare* what
# they compute via a ``pallas_op`` tag: the pallas backend routes tagged loops
# through the Pallas kernels above; every other backend just executes the
# generic accessor formula.  Coefficients are baked in as Python floats so the
# kernel fingerprint (and hence the chain-plan cache) sees coefficient changes.


def star2d_kernel(src: str, dst: str, coeffs):
    """5-point star sweep kernel: dst = c0*src + cx*(±dim0) + cy*(±dim1)."""
    c0, cx, cy = (float(c) for c in coeffs)

    def kernel(acc):
        return {dst: c0 * acc(src)
                + cx * (acc(src, (1, 0)) + acc(src, (-1, 0)))
                + cy * (acc(src, (0, 1)) + acc(src, (0, -1)))}

    kernel.pallas_op = ("stencil2d", src, dst, (c0, cx, cy))
    return kernel


def star3d_kernel(src: str, dst: str, coeffs):
    """7-point star sweep kernel: dst = c0*src + cz/cx/cy * (±each dim)."""
    c0, cz, cx, cy = (float(c) for c in coeffs)

    def kernel(acc):
        return {dst: c0 * acc(src)
                + cz * (acc(src, (1, 0, 0)) + acc(src, (-1, 0, 0)))
                + cx * (acc(src, (0, 1, 0)) + acc(src, (0, -1, 0)))
                + cy * (acc(src, (0, 0, 1)) + acc(src, (0, 0, -1)))}

    kernel.pallas_op = ("stencil3d", src, dst, (c0, cz, cx, cy))
    return kernel
