"""3-D 7-point star stencil sweep as a Pallas TPU kernel.

Blocks are z-slabs: (bz + 2h, Hp, Wp) input windows -> (bz, H, W) outputs.
Within a slab the y/x plane stays whole (the lane/sublane dims map to x/y on
TPU; the stencil only needs ±1 neighbours so the 2-D plane arithmetic
vectorises on the VPU while z-neighbours come from adjacent VMEM rows).

u'[k,i,j] = c0*u[kij] + cz*(u[k±1]) + cx*(u[i±1]) + cy*(u[j±1])
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import overlapping_spec


def _kernel(x_ref, c_ref, o_ref, *, halo: int):
    u = x_ref[...].astype(jnp.float32)
    h = halo
    c0, cz, cx, cy = c_ref[0], c_ref[1], c_ref[2], c_ref[3]
    D0, D1, D2 = u.shape
    core = u[h:-h, h:-h, h:-h]
    zm = u[h - 1:D0 - h - 1, h:-h, h:-h]
    zp = u[h + 1:D0 - h + 1, h:-h, h:-h]
    xm = u[h:-h, h - 1:D1 - h - 1, h:-h]
    xp = u[h:-h, h + 1:D1 - h + 1, h:-h]
    ym = u[h:-h, h:-h, h - 1:D2 - h - 1]
    yp = u[h:-h, h:-h, h + 1:D2 - h + 1]
    o_ref[...] = (
        c0 * core + cz * (zm + zp) + cx * (xm + xp) + cy * (ym + yp)
    ).astype(o_ref.dtype)


def stencil3d_pallas(
    x: jax.Array,
    coeffs: jax.Array,
    *,
    block_z: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """7-point stencil on ``x`` (padded by 1 per side); returns (D,H,W)."""
    halo = 1
    Dp, Hp, Wp = x.shape
    D, H, W = Dp - 2 * halo, Hp - 2 * halo, Wp - 2 * halo
    bz = min(block_z, D)
    assert D % bz == 0, (D, bz)
    return pl.pallas_call(
        functools.partial(_kernel, halo=halo),
        out_shape=jax.ShapeDtypeStruct((D, H, W), x.dtype),
        grid=(D // bz,),
        in_specs=[
            overlapping_spec(
                (bz + 2 * halo, Hp, Wp),
                lambda i: (i * bz, 0, 0),
            ),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bz, H, W), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(x, coeffs)
