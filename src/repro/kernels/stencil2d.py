"""2-D 5-point star stencil sweep as a Pallas TPU kernel.

The grid is cut into row-slabs; each slab (+1-cell halo) is staged into VMEM
by the Pallas pipeline (overlapping windows via per-dimension ``Element``
indexing) and the weighted star update runs on the VPU.  Lane dimension (W)
stays whole per block — stencil width is tiny compared to the 128-lane
register shape, so only the sublane (row) dimension is tiled.

u'[i,j] = c0*u[i,j] + cx*(u[i-1,j]+u[i+1,j]) + cy*(u[i,j-1]+u[i,j+1])
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import overlapping_spec


def _kernel(x_ref, c_ref, o_ref, *, halo: int):
    u = x_ref[...].astype(jnp.float32)
    c0 = c_ref[0]
    cx = c_ref[1]
    cy = c_ref[2]
    h = halo
    core = u[h:-h, h:-h]
    up = u[h - 1:-h - 1, h:-h]
    dn = u[h + 1:u.shape[0] - h + 1, h:-h]
    lf = u[h:-h, h - 1:-h - 1]
    rt = u[h:-h, h + 1:u.shape[1] - h + 1]
    o_ref[...] = (c0 * core + cx * (up + dn) + cy * (lf + rt)).astype(o_ref.dtype)


def stencil2d_pallas(
    x: jax.Array,
    coeffs: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Apply the 5-point stencil to ``x`` (padded by 1 halo cell per side).

    Args:
      x: (H+2, W+2) padded input.
      coeffs: (3,) [c0, cx, cy] float32.
    Returns:
      (H, W) updated interior.
    """
    halo = 1
    Hp, Wp = x.shape
    H, W = Hp - 2 * halo, Wp - 2 * halo
    bm = min(block_rows, H)
    # grid must cover H exactly; ops.py pads rows to a multiple of bm.
    assert H % bm == 0, (H, bm)
    grid = (H // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, halo=halo),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        grid=grid,
        in_specs=[
            overlapping_spec(
                (bm + 2 * halo, Wp),
                lambda i: (i * bm, 0),
            ),
            pl.BlockSpec((3,), lambda i: (0,)),  # coefficients, replicated
        ],
        out_specs=pl.BlockSpec((bm, W), lambda i: (i, 0)),
        interpret=interpret,
    )(x, coeffs)
