"""Fused K-sweep stencil chain — the paper's idea at the VMEM level.

A loop-chain of K 5-point sweeps executes entirely on a VMEM-resident tile:
the input window carries a K-cell halo (the chain's accumulated skew), all K
sweeps run in registers/VMEM with the halo shrinking by one cell per sweep,
and only the final tile is written back to HBM.  HBM traffic drops from
2·K·N to (1+ε)·2·N — the same transfer-elision the out-of-core executor does
one level up, with Pallas's grid pipeline providing the triple-buffering
(upload next window / compute / write back previous) that Algorithm 1
implements with CUDA streams.

The redundant skirt compute ((bm+2K)/bm per tile) is the classic
overlapped-tiling trade: on TPU the VPU is nowhere near the roofline for
bandwidth-bound stencils, so trading flops for HBM bytes is the right
direction (see EXPERIMENTS.md §Perf for the measured term shift).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import overlapping_spec


def _kernel(x_ref, c_ref, o_ref, *, steps: int, halo: int):
    u = x_ref[...].astype(jnp.float32)
    c0, cx, cy = c_ref[0], c_ref[1], c_ref[2]
    # K sweeps; the valid region shrinks by h per sweep. Slicing with static
    # bounds keeps everything in VMEM/registers — no HBM round-trips.
    for s in range(steps):
        D0, D1 = u.shape
        h = halo
        core = u[h:D0 - h, h:D1 - h]
        up = u[0:D0 - 2 * h, h:D1 - h]
        dn = u[2 * h:D0, h:D1 - h]
        lf = u[h:D0 - h, 0:D1 - 2 * h]
        rt = u[h:D0 - h, 2 * h:D1]
        u = c0 * core + cx * (up + dn) + cy * (lf + rt)
    o_ref[...] = u.astype(o_ref.dtype)


def chain2d_pallas(
    x: jax.Array,
    coeffs: jax.Array,
    *,
    steps: int,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Apply ``steps`` fused 5-point sweeps.

    Args:
      x: (H + 2*steps, W + 2*steps) input padded by ``steps`` halo cells.
      coeffs: (3,) [c0, cx, cy].
    Returns:
      (H, W) result after ``steps`` sweeps.
    """
    halo = 1
    K = steps
    Hp, Wp = x.shape
    H, W = Hp - 2 * K, Wp - 2 * K
    bm = min(block_rows, H)
    assert H % bm == 0, (H, bm)
    return pl.pallas_call(
        functools.partial(_kernel, steps=K, halo=halo),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        grid=(H // bm,),
        in_specs=[
            overlapping_spec(
                (bm + 2 * K, Wp),
                lambda i: (i * bm, 0),
            ),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, W), lambda i: (i, 0)),
        interpret=interpret,
    )(x, coeffs)
