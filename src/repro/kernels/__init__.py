"""Pallas TPU kernels for the stencil hot-spots the paper optimises.

Each kernel has: the ``pl.pallas_call`` implementation (``<name>.py``), a
jit'd public wrapper in :mod:`repro.kernels.ops`, and a pure-jnp oracle in
:mod:`repro.kernels.ref`.  All kernels validate in ``interpret=True`` mode on
CPU (this container) and are written against TPU constraints (VMEM-resident
blocks, overlapping halo windows via per-dim ``Element`` indexing).

``chain2d`` is the TPU-native adaptation of the paper's core idea one level
below HBM: a whole loop-chain executes on a VMEM-resident tile (+K halo)
before anything is written back — cache-blocking tiling where Pallas's grid
pipeline plays the role of the paper's CUDA streams (automatic double
buffering of HBM<->VMEM block transfers).
"""
from .ops import chain2d, star2d_kernel, star3d_kernel, stencil2d, stencil3d

__all__ = ["stencil2d", "stencil3d", "chain2d", "star2d_kernel", "star3d_kernel"]
