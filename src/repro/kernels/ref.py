"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil2d_ref(x: jax.Array, coeffs: jax.Array) -> jax.Array:
    """5-point star on (H+2, W+2) padded input -> (H, W)."""
    u = x.astype(jnp.float32)
    c0, cx, cy = coeffs[0], coeffs[1], coeffs[2]
    out = (
        c0 * u[1:-1, 1:-1]
        + cx * (u[:-2, 1:-1] + u[2:, 1:-1])
        + cy * (u[1:-1, :-2] + u[1:-1, 2:])
    )
    return out.astype(x.dtype)


def stencil3d_ref(x: jax.Array, coeffs: jax.Array) -> jax.Array:
    """7-point star on (D+2, H+2, W+2) padded input -> (D, H, W)."""
    u = x.astype(jnp.float32)
    c0, cz, cx, cy = coeffs[0], coeffs[1], coeffs[2], coeffs[3]
    out = (
        c0 * u[1:-1, 1:-1, 1:-1]
        + cz * (u[:-2, 1:-1, 1:-1] + u[2:, 1:-1, 1:-1])
        + cx * (u[1:-1, :-2, 1:-1] + u[1:-1, 2:, 1:-1])
        + cy * (u[1:-1, 1:-1, :-2] + u[1:-1, 1:-1, 2:])
    )
    return out.astype(x.dtype)


def chain2d_ref(x: jax.Array, coeffs: jax.Array, steps: int) -> jax.Array:
    """K sequential full-grid 5-point sweeps on (H+2K, W+2K) input -> (H, W).

    Float32 accumulation throughout (matching the kernel), cast at the end.
    """
    u = x.astype(jnp.float32)
    c0, cx, cy = coeffs[0], coeffs[1], coeffs[2]
    for _ in range(steps):
        u = (
            c0 * u[1:-1, 1:-1]
            + cx * (u[:-2, 1:-1] + u[2:, 1:-1])
            + cy * (u[1:-1, :-2] + u[1:-1, 2:])
        )
    return u.astype(x.dtype)
