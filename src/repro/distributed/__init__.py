"""Distribution layer: sharding rules, batch specs, gradient compression."""
from .sharding import batch_specs, cache_specs, param_specs

__all__ = ["param_specs", "batch_specs", "cache_specs"]
