"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Scheme (Megatron-TP x ZeRO-FSDP, MaxText-style):
  * ``model`` axis — tensor parallel: attention heads, MLP hidden, vocab,
    MoE expert dim (expert parallelism), Mamba inner channels.
  * ``data`` axis  — batch data-parallel AND FSDP: every 2-D+ parameter also
    shards its non-TP major dim over ``data`` (ZeRO-3; XLA all-gathers
    per-layer on use, reduce-scatters grads).  Optimizer state inherits.
  * ``pod`` axis   — extra data parallelism across pods over DCN (gradient
    all-reduce once per step), or pipeline stages when pipeline mode is on.

Head dims shard over ``model`` only when divisible (GQA kv=1/8 replicate;
kv=16/32 shard) — the rule functions take the mesh and decide.

Long-context decode (batch=1): the batch axes can't shard batch, so KV cache
SEQUENCE dims shard over ``data`` instead — the SPMD partitioner then lowers
softmax/matvec over the sharded length to the distributed flash-decode
pattern (partial max/sum + psum).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n >= mesh.shape[axis]


def param_specs(params: Dict, cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
                tp: bool = True) -> Dict:
    """PartitionSpec tree matching ``params`` (stacked layer dims -> None)."""
    model = "model" if (tp and "model" in mesh.axis_names) else None
    fs = "data" if (fsdp and "data" in mesh.axis_names) else None
    kv_sharded = model if _div(cfg.kv_heads, mesh, "model") else None
    q_sharded = model if _div(cfg.num_heads, mesh, "model") else None
    vocab_sharded = model if _div(cfg.vocab_size, mesh, "model") else None
    dm_fs = fs if _div(cfg.d_model, mesh, "data") else None
    ff_div = lambda f: model if _div(f, mesh, "model") else None

    def base_spec(name: str, leaf) -> Optional[P]:
        nd = leaf.ndim
        if name in ("embed",):
            return P(vocab_sharded, dm_fs)
        if name == "lm_head":
            return P(dm_fs, vocab_sharded)
        if name in ("wq", "w_q"):
            return P(dm_fs, q_sharded, None)
        if name in ("wk", "wv"):
            return P(dm_fs, kv_sharded, None)
        if name == "wo":
            return P(q_sharded, None, dm_fs)
        if name in ("bq",):
            return P(q_sharded, None)
        if name in ("bk", "bv"):
            return P(kv_sharded, None)
        if name == "w_dkv":
            return P(dm_fs, None)
        if name in ("w_uk", "w_uv"):
            return P(None, q_sharded, None)
        if name in ("w_gate", "w_up"):
            if nd == 3 or nd == 4:      # stacked experts (E, d, f) [+layer]
                return P(model, None, None)
            return P(dm_fs, ff_div(leaf.shape[-1]))
        if name == "w_down":
            if nd == 3 or nd == 4:
                return P(model, None, None)
            return P(ff_div(leaf.shape[0]), dm_fs)
        if name == "router":
            return P(None, None)
        if name == "in_proj":
            return P(dm_fs, None)
        if name == "out_proj":
            return P(None, dm_fs)
        if name in ("conv_w", "conv_b", "dt_bias", "a_log", "d_skip", "norm",
                    "ln", "ln1", "ln2", "ln_x", "final_norm", "enc_norm"):
            return P(*([None] * nd))
        return P(*([None] * nd))

    def assign(path, leaf):
        keys = [getattr(p, "key", str(p)) for p in path]
        name = keys[-1]
        # stacked-layer leading dim (blocks/enc_blocks): prepend None
        stacked = any(k in ("blocks", "enc_blocks") for k in keys)
        sp = base_spec(name, leaf if not stacked else _Unstacked(leaf))
        parts = list(sp)
        if stacked:
            parts = [None] + parts
        # pad/truncate defensively to leaf rank
        while len(parts) < leaf.ndim:
            parts.append(None)
        parts = parts[: leaf.ndim]
        # drop shardings that don't divide
        out = []
        for dim, ax in zip(leaf.shape, parts):
            if ax is None:
                out.append(None)
            else:
                sizes = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
                out.append(ax if dim % sizes == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map_with_path(assign, params)


class _Unstacked:
    """Shape/ndim view of a stacked leaf with the layer dim removed."""

    def __init__(self, leaf):
        self.shape = leaf.shape[1:]
        self.ndim = leaf.ndim - 1


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                include_model: bool = False) -> Dict[str, P]:
    """Specs for train/prefill inputs.  ``include_model=True`` spreads the
    batch over the model axis too (pure-DP/FSDP mode for models too small
    to profit from TP)."""
    ba = _batch_axes(mesh)
    if include_model and "model" in mesh.axis_names:
        ba = ba + ("model",)
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    bspec = ba if (ba and global_batch % nb == 0) else ()
    d = {
        "tokens": P(bspec or None, None),
        "labels": P(bspec or None, None),
    }
    if cfg.family == "vlm":
        d["patches"] = P(bspec or None, None, None)
    if cfg.encdec:
        d["enc_inputs"] = P(bspec or None, None, None)
    return d


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> Dict[str, P]:
    """Specs for the serving cache.  batch >= batch-axes size shards batch;
    batch == 1 (long-context) shards the sequence dim over data instead."""
    ba = _batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    batch_ok = ba and batch % nb == 0
    bspec = ba if batch_ok else None
    kv_ok = _div(cfg.kv_heads, mesh, "model")
    kv_sharded = "model" if kv_ok else None
    # the cache SEQ dim takes every axis not otherwise used: `data` when the
    # batch can't shard (long-context batch=1), and `model` when the kv-head
    # count doesn't divide it (GQA kv=1/4/8 on a 16-way axis would otherwise
    # REPLICATE a multi-GB cache per chip); masked softmax over the sharded
    # length lowers to the distributed flash-decode pattern (partial
    # max/sum + psum) automatically.
    seq_axes = []
    if not batch_ok and "data" in mesh.axis_names:
        seq_axes.append("data")
    if not kv_ok and "model" in mesh.axis_names:
        seq_axes.append("model")
    seq_spec = tuple(seq_axes) if seq_axes else None
    h_sharded = "model" if _div(cfg.ssm_heads if cfg.ssm else 0, mesh, "model") else None

    specs: Dict[str, P] = {"len": P()}
    if cfg.family in ("dense", "vlm", "encdec") or (cfg.family == "moe" and not cfg.mla):
        specs["k"] = P(None, bspec, seq_spec, kv_sharded, None)
        specs["v"] = P(None, bspec, seq_spec, kv_sharded, None)
    if cfg.family == "encdec":
        specs["enc_k"] = P(None, bspec, seq_spec, kv_sharded, None)
        specs["enc_v"] = P(None, bspec, seq_spec, kv_sharded, None)
    if cfg.family == "moe" and cfg.mla:
        # MLA compressed cache has no head dim; shard seq over model too.
        mla_seq = tuple(dict.fromkeys(("model",) + tuple(seq_axes)))
        specs["ckv"] = P(None, bspec, mla_seq)
        specs["kr"] = P(None, bspec, mla_seq)
    if cfg.family in ("ssm", "hybrid"):
        specs["ssm"] = P(None, bspec, h_sharded, None, None)
        specs["conv"] = P(None, bspec, None, None)
    if cfg.family == "hybrid":
        kvh = "model" if _div(cfg.kv_heads, mesh, "model") else None
        specs["sk"] = P(None, bspec, seq_spec, kvh, None)
        specs["sv"] = P(None, bspec, seq_spec, kvh, None)
    return specs


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
