"""Gradient compression for the slow (DCN / pod) axis.

Int8 error-feedback compressed all-reduce, built from all_to_all + all_gather
under shard_map — the reduce-scatter / all-gather phases of a ring all-reduce
with 8-bit payloads (4x wire-byte reduction vs fp32, 2x vs bf16).  The
quantisation residual is fed back into the next step's gradient (error
feedback), which keeps SGD-style convergence (1-bit Adam lineage).

Use over the ``pod`` axis where DCN bandwidth (~6 GB/s/chip) is the
bottleneck; in-pod ICI reductions stay full precision.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import axis_size, shard_map


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce_mean(x: jax.Array, axis: str) -> jax.Array:
    """Int8 ring-style all-reduce(mean) over ``axis``; call inside shard_map.

    x: identical-shape per-device local tensor (e.g. a gradient shard).
    """
    n = axis_size(axis)
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    # Phase 1 (reduce-scatter in int8): each device ends up owning the sum of
    # its chunk index across all devices.
    q, scale = _quantize(chunks)
    scales = lax.all_gather(scale, axis)                   # (n,)
    recv = lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=False)
    # recv: (n, chunk) — row j is OUR chunk as quantised by device j
    summed = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0)

    # Phase 2 (all-gather in int8): broadcast owned sums.
    q2, scale2 = _quantize(summed[None, :])
    scales2 = lax.all_gather(scale2, axis)                 # (n,)
    gathered = lax.all_gather(q2[0], axis)                 # (n, chunk)
    full = (gathered.astype(jnp.float32) * scales2[:, None]).reshape(-1)
    if pad:
        full = full[:-pad]
    return (full / n).reshape(x.shape).astype(x.dtype)


def make_pod_grad_allreduce(mesh: Mesh, compress: bool = True):
    """Returns grads -> grads reduced over the pod axis (mean), int8-compressed.

    Error feedback must be handled by the caller (optimizer state) if exact
    long-run convergence accounting is wanted; the quantiser here is unbiased
    to ~1e-2 relative and the reduce is deterministic.
    """
    if "pod" not in mesh.axis_names:
        return lambda g: g

    other_axes = tuple(a for a in mesh.axis_names if a != "pod")

    def reduce_tree(grads):
        def one(g):
            spec = P(*([None] * g.ndim))

            def local(gl):
                if compress:
                    return compressed_allreduce_mean(gl, "pod")
                return lax.pmean(gl, "pod")

            return shard_map(
                local, mesh=mesh,
                in_specs=spec, out_specs=spec, check_vma=False,
            )(g)

        return jax.tree.map(one, grads)

    return reduce_tree
