"""Roofline terms per (arch x shape x mesh) cell, from dry-run artifacts.

    compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = ICI_wire_bytes / ICI_bw + DCN_wire_bytes / DCN_bw

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI; DCN across pods modelled at 6.25 GB/s/chip.

MODEL_FLOPS = 6·N·T (train) / 2·N·T (inference) with N = active params and
T = global tokens; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat and
redundant compute (ratio < 1 when the compiled module does extra work, e.g.
rematerialised layers; > 1 would flag under-counting).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from .hlo_analysis import analyze_hlo_text

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 6.25e9


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the whole cell step (global, all devices)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_terms(hlo_text: str, devices: int, cfg=None, shape=None,
                   microbatch_note: str = "") -> Dict:
    a = analyze_hlo_text(hlo_text, devices)
    compute_s = a["dot_flops"] / PEAK_FLOPS
    memory_s = a["hbm_bytes"] / HBM_BW
    coll_s = a["collective_bytes_ici"] / ICI_BW + a["collective_bytes_dcn"] / DCN_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound_s,
        "dot_flops_per_device": a["dot_flops"],
        "hbm_bytes_per_device": a["hbm_bytes"],
        "ici_bytes": a["collective_bytes_ici"],
        "dcn_bytes": a["collective_bytes_dcn"],
        "collectives": a["collective_op_counts"],
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops_total"] = mf
        out["model_flops_per_device"] = mf / devices
        out["useful_ratio"] = (mf / devices) / max(a["dot_flops"], 1.0)
        # roofline fraction: useful work time over the actual bound
        out["roofline_fraction"] = (mf / devices / PEAK_FLOPS) / max(bound_s, 1e-30)
    return out


def analyze_report_dir(dryrun_dir: str, out_md: Optional[str] = None) -> List[Dict]:
    """Build the full roofline table from reports/dryrun artifacts."""
    from ..configs import get_config
    from ..models.config import SHAPES

    rows = []
    for jpath in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(jpath) as f:
            meta = json.load(f)
        hpath = jpath.replace(".json", ".hlo.txt")
        if not os.path.exists(hpath):
            continue
        cfg = get_config(meta["arch"].replace("-", "_").replace(".", "_"))
        shape = SHAPES[meta["shape"]]
        with open(hpath) as f:
            terms = roofline_terms(f.read(), meta["devices"], cfg, shape)
        rows.append({**meta, **terms, "file": os.path.basename(jpath)})

    if out_md:
        with open(out_md, "w") as f:
            f.write(_to_markdown(rows))
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def _to_markdown(rows: List[Dict]) -> str:
    hdr = ("| cell | mesh | compute | memory | collective | bound | "
           "MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} x {r['shape']} | {r['mesh']} | "
            f"{_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} | "
            f"{_fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r.get('useful_ratio', 0):.2f} | "
            f"{r.get('roofline_fraction', 0) * 100:.1f}% |\n")
    return "".join(lines)


if __name__ == "__main__":
    import sys

    d = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun"
    rows = analyze_report_dir(d, out_md="reports/roofline.md")
    print(_to_markdown(rows))
