"""Post-SPMD HLO parsing with while-loop trip-count correction.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE — a scan-over-layers
model under-reports FLOPs by ~num_layers.  This parser rebuilds per-module
costs from the partitioned HLO text:

  * builds the computation call graph (while bodies/conds, fusions, calls),
  * extracts ``known_trip_count`` from each while's backend_config,
  * propagates execution multipliers from ENTRY (nested loops multiply),
  * dot FLOPs: 2 x |result| x |contracted dims| per dot x multiplier,
  * HBM traffic: per top-level op, operands + results bytes x multiplier
    (fusion internals excluded: a fusion reads its inputs and writes its
    outputs exactly once — the roofline convention),
  * collective wire bytes per op with ring conventions
    (all-gather/reduce-scatter (g-1)/g, all-reduce 2(g-1)/g, all-to-all
    (g-1)/g, collective-permute 1x), bucketed by replica-group size so DCN
    (pod, group 2) and ICI collectives are charged to different links.

Caveats (documented per EXPERIMENTS.md methodology): ``conditional`` branch
bodies are counted once per invocation (upper bound — affects zamba2's
every-6th-layer shared block); elementwise FLOPs are not counted (<2% of any
cell here); convolutions are lowered to dots/elementwise by this model zoo.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\((.*)$"
)
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-_]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-_]+), body=%?([\w\.\-_]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren (operands + attrs)
    comp: str

    @property
    def result_bytes(self) -> float:
        return _shape_bytes(self.type_str)


@dataclass
class HloModule:
    comps: Dict[str, List[Op]]
    entry: str
    symbols: Dict[str, Dict[str, str]]   # comp -> op name -> type_str


def parse_hlo(text: str) -> HloModule:
    comps: Dict[str, List[Op]] = {}
    symbols: Dict[str, Dict[str, str]] = defaultdict(dict)
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = []
            if mc.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(_COMMENT_RE.sub("", line))
        if not mo:
            continue
        name, type_str, opcode, rest = mo.groups()
        op = Op(name, type_str, opcode, rest, cur)
        comps[cur].append(op)
        symbols[cur][name] = type_str
    return HloModule(comps=comps, entry=entry, symbols=dict(symbols))


def _multipliers(mod: HloModule) -> Dict[str, float]:
    """Execution count of each computation, propagated from ENTRY."""
    mult: Dict[str, float] = defaultdict(float)
    mult[mod.entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graph is a DAG)
    for _ in range(64):
        changed = False
        snapshot = dict(mult)
        for comp, ops in mod.comps.items():
            m = snapshot.get(comp, 0.0)
            if m == 0.0:
                continue
            for op in ops:
                if op.opcode == "while":
                    wm = _WHILE_RE.search(op.rest)
                    tm = _TRIP_RE.search(op.rest)
                    n = float(tm.group(1)) if tm else 1.0
                    if wm:
                        cond, body = wm.group(1), wm.group(2)
                        for callee, k in ((body, n), (cond, n + 1)):
                            new = m * k
                            if mult.get(callee, 0.0) < new:
                                mult[callee] = new
                                changed = True
                else:
                    for callee in _CALL_ATTR_RE.findall(op.rest):
                        if callee in mod.comps:
                            if mult.get(callee, 0.0) < m:
                                mult[callee] = m
                                changed = True
        if not changed:
            break
    return dict(mult)


def _operand_names(rest: str) -> List[str]:
    """Operand op-names from the call's argument list (up to the paren close)."""
    depth = 1
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    for part in re.findall(r"%([\w\.\-_]+)", token):
        out.append(part)
    return out


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    dims = _shape_dims(op.type_str)
    result_elems = 1
    for d in dims:
        result_elems *= d
    mcontract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = _operand_names(op.rest)
    contract = 1
    if mcontract and operands:
        lhs_type = symbols.get(operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        for idx in (int(i) for i in mcontract.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


def _group_size(op: Op, total_devices: int) -> int:
    m = _GROUPS_RE.search(op.rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(op.rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


_WIRE_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "all-gather-start": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-reduce-start": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
    "collective-permute-start": lambda g: 1.0,
}


def _param_usage_bytes(mod: HloModule, comp: str) -> Dict[int, float]:
    """For a fused computation: bytes actually READ from each parameter.

    A parameter consumed only by dynamic-slice/gather ops costs the slice
    bytes, not the whole buffer (the stacked-layer weights threaded through a
    scan are the canonical case: the body reads one layer, not all L)."""
    ops = mod.comps.get(comp, [])
    param_idx: Dict[str, int] = {}
    for op in ops:
        if op.opcode == "parameter":
            m = re.match(r"(\d+)", op.rest)
            if m:
                param_idx[op.name] = int(m.group(1))
    usage: Dict[int, float] = {}
    for pname, idx in param_idx.items():
        total = 0.0
        sliced_only = True
        for op in ops:
            if op.opcode == "parameter":
                continue
            refs = _operand_names(op.rest)
            if pname not in refs:
                continue
            if op.opcode in ("dynamic-slice", "gather", "slice"):
                total += op.result_bytes
            elif op.opcode == "dynamic-update-slice" and refs and refs[0] == pname:
                # writes into the buffer; the read side is the update operand
                total += 0.0
            else:
                sliced_only = False
                break
        if sliced_only:
            usage[idx] = total
    return usage


def analyze_hlo_text(text: str, total_devices: int, dcn_group_size: int = 2,
                     breakdown: bool = False) -> Dict:
    """Scan-corrected per-device cost summary of one compiled module."""
    mod = parse_hlo(text)
    mult = _multipliers(mod)
    top_hbm: List[Tuple[float, str, str, float, str]] = []

    # computations reached via `fusion(..) calls=` — their internal ops are
    # excluded from the HBM-traffic sum (counted at the call site).
    fused: set = set()
    for comp, ops in mod.comps.items():
        for op in ops:
            if op.opcode == "fusion":
                for callee in _CALL_ATTR_RE.findall(op.rest):
                    fused.add(callee)

    dot_flops = 0.0
    hbm_bytes = 0.0
    coll = defaultdict(float)        # opcode -> wire bytes
    coll_ici = 0.0
    coll_dcn = 0.0
    n_coll = defaultdict(int)
    for comp, ops in mod.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        symbols = mod.symbols.get(comp, {})
        in_fused = comp in fused
        for op in ops:
            if op.opcode == "dot":
                dot_flops += m * _dot_flops(op, symbols)
            if in_fused:
                continue
            if op.opcode in ("parameter", "constant", "tuple", "get-tuple-element",
                             "bitcast", "while", "call", "conditional", "reshape",
                             "transpose", "copy-start", "copy-done"):
                continue
            # HBM traffic: operands + result (fusion-boundary convention),
            # slice-aware: dynamic-slice/gather read only what they produce.
            operands = _operand_names(op.rest)
            if op.opcode in ("dynamic-slice", "gather", "slice"):
                ob = op.result_bytes
            elif op.opcode == "dynamic-update-slice":
                upd = (_shape_bytes(symbols.get(operands[1], ""))
                       if len(operands) > 1 else op.result_bytes)
                hbm_bytes += m * 2 * upd
                continue
            elif op.opcode == "fusion":
                callees = _CALL_ATTR_RE.findall(op.rest)
                usage = _param_usage_bytes(mod, callees[0]) if callees else {}
                ob = 0.0
                for i, nm in enumerate(operands):
                    full = _shape_bytes(symbols.get(nm, ""))
                    ob += min(usage.get(i, full), full) if i in usage else full
                # in-place scatter fusions: a DUS-rooted fusion writes only
                # the update slice, not the whole aliased buffer.
                wb = op.result_bytes
                if callees:
                    root_dus = [
                        fop for fop in mod.comps.get(callees[0], [])
                        if fop.opcode == "dynamic-update-slice"
                    ]
                    if root_dus:
                        fsym = mod.symbols.get(callees[0], {})
                        upd = 0.0
                        for fop in root_dus:
                            onames = _operand_names(fop.rest)
                            if len(onames) > 1:
                                upd += _shape_bytes(fsym.get(onames[1], ""))
                        if upd:
                            wb = min(wb, upd)
                hbm_bytes += m * (ob + wb)
                if breakdown:
                    top_hbm.append((m * (ob + wb), op.opcode,
                                    op.type_str[:48], m, comp[:36]))
                continue
            else:
                ob = sum(_shape_bytes(symbols.get(nm, "")) for nm in operands)
            hbm_bytes += m * (ob + op.result_bytes)
            if breakdown:
                top_hbm.append((m * (ob + op.result_bytes), op.opcode,
                                op.type_str[:48], m, comp[:36]))
            if op.opcode in COLLECTIVES:
                g = _group_size(op, total_devices)
                wire = op.result_bytes * _WIRE_FACTOR[op.opcode](g)
                coll[op.opcode] += m * wire
                n_coll[op.opcode] += 1
                if g <= dcn_group_size:
                    coll_dcn += m * wire
                else:
                    coll_ici += m * wire
    if breakdown:
        return {
            "dot_flops": dot_flops,
            "hbm_bytes": hbm_bytes,
            "top_hbm": sorted(top_hbm, reverse=True)[:15],
            "collective_wire_bytes": dict(coll),
            "collective_bytes_ici": coll_ici,
            "collective_bytes_dcn": coll_dcn,
            "collective_op_counts": dict(n_coll),
            "num_computations": len(mod.comps),
        }
    return {
        "dot_flops": dot_flops,
        "hbm_bytes": hbm_bytes,
        "collective_wire_bytes": dict(coll),
        "collective_bytes_ici": coll_ici,
        "collective_bytes_dcn": coll_dcn,
        "collective_op_counts": dict(n_coll),
        "num_computations": len(mod.comps),
    }
