"""Post-compile analysis: HLO parsing (scan-corrected costs) and roofline."""
from .hlo_analysis import analyze_hlo_text
from .roofline import roofline_terms

__all__ = ["analyze_hlo_text", "roofline_terms"]
